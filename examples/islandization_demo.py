"""Visual/inspectable demo of Octree-based Islandization (paper Fig. 9):
prints island composition, BFS rounds, and the Hub-Cache schedule for a
small cloud; renders islands as ASCII (xy projection).

    PYTHONPATH=src python examples/islandization_demo.py
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SAMPLERS, build_schedule, islandize
from repro.core.pipeline import LPCNConfig, data_structuring
from repro.data.synthetic import make_cloud


def main():
    rng = np.random.default_rng(3)
    xyz = jnp.asarray(make_cloud(rng, 512))
    key = jax.random.PRNGKey(0)
    # samplers / neighbor methods are registry-resolved by name — swap
    # any of them (or register your own via repro.engine.register_sampler)
    print(f"registered samplers: {SAMPLERS.names()}")
    cfg = LPCNConfig(n_centers=128, k=16, island_size=16,
                     sampler="fps", neighbor="pointacc")
    cidx, nbr = data_structuring(cfg, xyz, key)
    centers = xyz[cidx]

    isl = islandize(centers, 8, capacity=32, key=key)
    sched = build_schedule(isl, nbr, cfg.cache_capacity)

    members = np.asarray(isl.members)
    rounds = np.asarray(isl.round_of)
    c = np.asarray(centers)
    print("island | size | hub idx | BFS rounds (inside->outside)")
    for h in range(members.shape[0]):
        row = members[h][members[h] >= 0]
        if len(row) == 0:
            continue
        print(f"  {h:4d} | {len(row):4d} | {row[0]:7d} | "
              f"{rounds[row].tolist()}")

    # ASCII map: island id per center, xy projection
    grid = [[" "] * 64 for _ in range(24)]
    assign = np.full(c.shape[0], -1)
    for h in range(members.shape[0]):
        for m in members[h][members[h] >= 0]:
            assign[m] = h
    for i, (x, y, _z) in enumerate(c):
        gx = int((x + 1) / 2 * 63)
        gy = int((y + 1) / 2 * 23)
        grid[gy][gx] = chr(ord("A") + assign[i] % 26) \
            if assign[i] >= 0 else "."
    print("\nxy projection (letter = island):")
    for row in reversed(grid):
        print("".join(row))

    slot = np.asarray(sched.reuse_slot)
    live = (slot >= 0).mean()
    print(f"\ncached positions: {live:.1%} of all (subset, k) slots")


if __name__ == "__main__":
    main()
