"""Quickstart: one L-PCN building block, end to end.

Shows the paper's full story on one cloud: DS -> Octree-based
Islandization -> Hub-based Scheduling -> islandized Feature Computation,
with the workload report and the exactness check against the
traditional path.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LPCNConfig, init_mlp, lpcn_block
from repro.data.synthetic import make_cloud


def main():
    rng = np.random.default_rng(0)
    xyz = jnp.asarray(make_cloud(rng, 1024))
    key = jax.random.PRNGKey(0)

    # DGCNN(c)-style block: activation at block end -> exact reuse
    mlp = init_mlp(key, [3 + 3, 64, 128], activation="block_end")
    cfg = LPCNConfig(n_centers=512, k=32, mode="lpcn",
                     island_size=32, cache_capacity_x=2.0,
                     compensation="linear")

    out = lpcn_block(cfg, mlp, xyz, xyz, key, with_report=True)
    r = out.report.concrete()
    print(f"islands used:        {r.n_islands_used}")
    print(f"feature fetches:     {r.lpcn_fetches} / {r.baseline_fetches} "
          f"(saving {r.fetch_saving:.1%})")
    print(f"MLP point-evals:     {r.lpcn_mlp_evals} / "
          f"{r.baseline_mlp_evals} (saving {r.compute_saving:.1%})")

    # exactness vs the traditional path (paper §VI-E, block-end case)
    cfg_t = LPCNConfig(n_centers=512, k=32, mode="traditional")
    ref = lpcn_block(cfg_t, mlp, xyz, xyz, key)
    err = float(jnp.abs(out.features - ref.features).max())
    print(f"max |islandized - traditional| = {err:.2e}  (exact reuse)")
    assert err < 1e-3


if __name__ == "__main__":
    main()
