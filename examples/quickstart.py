"""Quickstart: the batched engine API, end to end.

Shows the paper's full story through ``repro.engine``: a padded batch of
clouds runs DS -> Octree-based Islandization -> Hub-based Scheduling ->
islandized Feature Computation -> logits in ONE jitted executable, with
swappable FC backends ("reference" jnp oracle vs "pallas" TPU kernels)
and the workload report + exactness check against the traditional path.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.data.synthetic import make_cloud

# DGCNN(c)-style single block: activation at block end -> exact reuse
SPEC = engine.PCNSpec(
    name="dgcnn_quickstart",
    blocks=(engine.BlockSpec(1024, 32, (64, 128), kind="edge",
                             sampler="all"),),
    head_dims=(64,),
    n_classes=10,
    activation="block_end",
)


def main():
    rng = np.random.default_rng(0)
    xyz = jnp.asarray(np.stack([make_cloud(rng, 1024) for _ in range(4)]))
    key = jax.random.PRNGKey(0)

    params = engine.init(key, SPEC)                    # typed pytree
    batch = engine.Batch.make(xyz, key=key)
    isl_kw = dict(island_size=32, cache_capacity_x=2.0)

    # one compiled executable per (spec, mode, backend) — the serving path
    run = {
        ("traditional", "reference"): jax.jit(partial(
            engine.apply, spec=SPEC, mode="traditional",
            fc_backend="reference", isl_kw=isl_kw)),
        ("lpcn", "pallas"): jax.jit(partial(
            engine.apply, spec=SPEC, mode="lpcn", fc_backend="pallas",
            isl_kw=isl_kw)),
    }

    # lpcn/reference logits + workload report (stacked over the batch)
    logits, rep = engine.apply_with_reports(params, batch, spec=SPEC,
                                            isl_kw=isl_kw)
    print(f"batched logits: {tuple(logits.shape)}  (B clouds -> B logits)")
    fetches = int(rep.lpcn_fetches.sum())
    base = int(rep.baseline_fetches.sum())
    evals = int(rep.lpcn_mlp_evals.sum())
    base_e = int(rep.baseline_mlp_evals.sum())
    print(f"feature fetches:     {fetches} / {base} "
          f"(saving {1 - fetches / base:.1%})")
    print(f"MLP point-evals:     {evals} / {base_e} "
          f"(saving {1 - evals / base_e:.1%})")

    # exactness vs the traditional path (paper §VI-E, block-end case)
    ref = run["traditional", "reference"](params, batch)
    err = float(jnp.abs(logits - ref).max())
    print(f"max |islandized - traditional| = {err:.2e}  (exact reuse)")
    assert err < 1e-3

    # backend agreement: pallas kernels vs the jnp oracle
    pal = run["lpcn", "pallas"](params, batch)
    kerr = float(jnp.abs(logits - pal).max())
    print(f"max |pallas - reference|       = {kerr:.2e}")
    assert kerr < 1e-4


if __name__ == "__main__":
    main()
