"""End-to-end driver: train a small PointNet++ classifier on synthetic
clouds for a few hundred steps, then evaluate under the islandized
execution mode (the paper's deployment scenario: train exact, serve with
the Islandization Unit).

    PYTHONPATH=src python examples/train_pointnet2.py [--steps 200]
"""
import argparse
import sys
import time
sys.path.insert(0, "src")
sys.path.insert(0, ".")          # for `benchmarks` when run from the root

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.accuracy import _forward, _gen_task, _model_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    xtr, ytr = _gen_task(128, 256, seed=1)
    xte, yte = _gen_task(64, 256, seed=2)
    key = jax.random.PRNGKey(0)
    params = _model_init(key, "block_end")

    fwd = jax.jit(jax.vmap(
        lambda p, x: _forward(p, x, "traditional", key,
                              activation="block_end"),
        in_axes=(None, 0)))

    def loss_fn(p, xs, ys):
        lp = jax.nn.log_softmax(fwd(p, xs))
        return -jnp.mean(lp[jnp.arange(ys.shape[0]), ys])

    vg = jax.jit(jax.value_and_grad(loss_fn))
    lr = 3e-3
    t0 = time.time()
    n = xtr.shape[0]
    for step in range(args.steps):
        i = (step * args.batch) % n
        loss, g = vg(params, xtr[i:i + args.batch], ytr[i:i + args.batch])
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        if step % 25 == 0:
            print(f"step {step:4d}  loss {float(loss):.4f}  "
                  f"({time.time()-t0:.0f}s)", flush=True)

    for mode in ("traditional", "lpcn"):
        f = jax.jit(jax.vmap(
            lambda p, x: _forward(p, x, mode, key,
                                  activation="block_end"),
            in_axes=(None, 0)))
        acc = float((jnp.argmax(f(params, xte), -1) == yte).mean())
        print(f"test accuracy [{mode:12s}]: {acc:.3f}")


if __name__ == "__main__":
    main()
