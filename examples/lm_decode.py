"""Serve a small LM with batched requests: batched greedy decode with a
KV cache across three architecture families (dense / SSM / hybrid) —
demonstrating the unified serve_step over the model zoo.

    PYTHONPATH=src python examples/lm_decode.py
"""
import sys
import time
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.lm import model_zoo as zoo
from repro.lm import steps as steps_mod


def main():
    rng = np.random.default_rng(0)
    for arch in ("olmo-1b", "mamba2-2.7b", "recurrentgemma-2b"):
        cfg = get_config(arch, reduced=True)
        key = jax.random.PRNGKey(0)
        params = zoo.init(key, cfg)
        B, gen = 4, 12
        cache = zoo.make_cache(cfg, params, B, 64)
        decode = jax.jit(steps_mod.make_decode_step(cfg),
                         donate_argnums=(2,))
        tok = jnp.asarray(rng.integers(0, cfg.vocab, (B,)), jnp.int32)
        t0 = time.time()
        toks = []
        for pos in range(gen):
            tok, _logits, cache = decode(params, tok, cache,
                                         jnp.int32(pos))
            toks.append(np.asarray(tok))
        dt = time.time() - t0
        print(f"{arch:20s} generated {B}x{gen} tokens in {dt:5.2f}s "
              f"({B*gen/dt:6.1f} tok/s)  sample: "
              f"{np.stack(toks,1)[0][:8].tolist()}")


if __name__ == "__main__":
    main()
