"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = modeled
accelerator frame latency in µs where applicable, else wall-clock of the
measurement; derived = the figure's headline metric).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only SECTION]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _emit(rows, name, us, derived, **meta):
    """meta (e.g. backend=..., batch=...) is recorded in the JSON output
    alongside the CSV fields."""
    rows.append((name, us, derived, meta))
    print(f"{name},{us:.1f},{derived}", flush=True)


# ---- Fig. 4(b): overlap-vs-distance motivation study -----------------------

def bench_overlap_study(rows, quick: bool):
    import jax
    import jax.numpy as jnp
    from repro.core.pipeline import LPCNConfig, data_structuring
    from repro.core.workload import overlap_histogram
    from repro.data.synthetic import make_cloud
    rng = np.random.default_rng(0)
    xyz = jnp.asarray(make_cloud(rng, 1024))
    for sa, (s, k) in {"SA1": (512, 32), "SA2": (128, 64)}.items():
        cfg = LPCNConfig(n_centers=s, k=k)
        t0 = time.time()
        cidx, nbr = data_structuring(cfg, xyz, jax.random.PRNGKey(0))
        hist = overlap_histogram(nbr, xyz[cidx])
        us = (time.time() - t0) * 1e6
        near_mean, near_max = hist["near_0_16"]
        rest_mean, _ = hist["rest"]
        _emit(rows, f"fig4b_overlap_{sa}_top16", us,
              f"mean={near_mean:.3f} max={near_max:.3f} "
              f"rest_mean={rest_mean:.3f}")


# ---- Fig. 15: theoretical workload optimization -----------------------------

def bench_workload_reduction(rows, quick: bool):
    from .workloads import BENCHMARKS, layer_works, totals
    for name, (model, _ds, n) in BENCHMARKS.items():
        if quick and n > 4096:
            continue
        t0 = time.time()
        lw = layer_works(model, n)
        t = totals(lw)
        us = (time.time() - t0) * 1e6
        _emit(rows, f"fig15_workload_{name}", us,
              f"fetch_saving={t['fetch_saving']:.3f} "
              f"mem_saving={t['mem_saving']:.3f} "
              f"compute_saving={t['compute_saving']:.3f}")


# ---- Fig. 16: speedup over the four DS-accelerator baselines ---------------

def bench_speedup_baselines(rows, quick: bool):
    from .perfmodel import speedup
    from .workloads import BENCHMARKS, layer_works
    for name, (model, _ds, n) in BENCHMARKS.items():
        if quick and n > 4096:
            continue
        lw = layer_works(model, n)
        for method in ("pointacc", "hgpcn", "edgepc", "crescent"):
            s = speedup(method, lw)
            us = s["lpcn_ms"] * 1e3
            _emit(rows, f"fig16_{method}_{name}", us,
                  f"speedup={s['speedup']:.2f} "
                  f"dsu_frac={s['dsu_frac_baseline']:.2f} "
                  f"islu_frac={s['islu_frac']:.4f}")


# ---- Fig. 17: FC speedup vs GDPCA / Mesorasi --------------------------------

def bench_fc_speedup(rows, quick: bool):
    from .perfmodel import (fc_speedup_gdpca, fc_speedup_lpcn,
                            fc_speedup_mesorasi)
    from .workloads import BENCHMARKS, layer_works
    for name, (model, _ds, n) in BENCHMARKS.items():
        if quick and n > 4096:
            continue
        t0 = time.time()
        lw = layer_works(model, n)
        us = (time.time() - t0) * 1e6
        _emit(rows, f"fig17_fc_{name}", us,
              f"gdpca={fc_speedup_gdpca(lw):.2f} "
              f"lpcn={fc_speedup_lpcn(lw):.2f} "
              f"mesorasi_onchip={fc_speedup_mesorasi(lw, on_chip=True):.2f} "
              f"mesorasi_offchip="
              f"{fc_speedup_mesorasi(lw, on_chip=False):.2f}")


# ---- Fig. 18/19: large-scale PCNs (PointNeXt / PointVector) ----------------

def bench_large_scale(rows, quick: bool):
    from .perfmodel import fc_speedup_mesorasi, frame_latency
    from .workloads import LARGE_SCALE, layer_works, totals
    for name, (model, _ds, n) in LARGE_SCALE.items():
        if quick and n > 8192:
            continue
        t0 = time.time()
        # FractalCloud setting: block-based approximate DS (morton-strided
        # sampling + window gather) — also the only tractable DS at 65k+
        lw = layer_works(model, n, neighbor="edgepc", sampler="morton")
        t = totals(lw)
        # FractalCloud = block DS + Mesorasi delayed-aggregation FC;
        # L-PCN plug-in replaces the FC optimization
        base = frame_latency("crescent", lw, "traditional")
        ours = frame_latency("crescent", lw, "lpcn")
        mes_fc_speed = fc_speedup_mesorasi(lw, on_chip=False)
        fractal = base["dsu"] + base["fcu"] / max(mes_fc_speed, 1e-9)
        us = (time.time() - t0) * 1e6
        _emit(rows, f"fig18_19_{name}", us,
              f"fetch_saving={t['fetch_saving']:.3f} "
              f"compute_saving={t['compute_saving']:.3f} "
              f"speedup_vs_fractalcloud="
              f"{fractal / max(ours['total'], 1):.2f}")


# ---- Fig. 20: accuracy ------------------------------------------------------

def bench_accuracy(rows, quick: bool):
    from .accuracy import run_accuracy
    t0 = time.time()
    res = run_accuracy(quick=quick)
    us = (time.time() - t0) * 1e6
    for name, accs in res.items():
        _emit(rows, f"fig20_accuracy_{name}", us,
              " ".join(f"{k}={v:.3f}" for k, v in accs.items()))


# ---- Fig. 22: sensitivity ---------------------------------------------------

def bench_sensitivity(rows, quick: bool):
    from .perfmodel import speedup
    from .workloads import layer_works, totals
    sizes = [16, 32] if quick else [8, 16, 32, 64]
    caps = [2.0] if quick else [1.0, 2.0, 4.0]
    for isz in sizes:
        for cx in caps:
            t0 = time.time()
            lw = layer_works("pointnet2_c", 1024,
                             {"island_size": isz,
                              "island_capacity": 2 * isz,
                              "cache_capacity_x": cx})
            t = totals(lw)
            s = speedup("pointacc", lw)
            us = (time.time() - t0) * 1e6
            _emit(rows, f"fig22_sens_isz{isz}_cap{cx}", us,
                  f"fetch_saving={t['fetch_saving']:.3f} "
                  f"compute_saving={t['compute_saving']:.3f} "
                  f"speedup={s['speedup']:.2f}")


# ---- engine: batched serving path (repro.engine), per FC backend -----------

def bench_engine(rows, quick: bool):
    """Wall-clock of the jitted batch-first engine on pointnet2_c:
    compile once, then time steady-state batches per backend x mode, on a
    full batch AND a ragged (padded, n_valid-masked) batch — the delta is
    the masking overhead later perf PRs track."""
    import jax
    import jax.numpy as jnp
    from functools import partial
    from dataclasses import replace as _replace
    from repro import engine
    from repro.data.synthetic import make_cloud
    from repro.models import MODEL_ZOO

    _, spec = MODEL_ZOO["pointnet2_c"]
    batch, n = (2, 256) if quick else (4, 1024)
    if quick:
        from repro.models.common import BlockSpec
        spec = _replace(spec, blocks=(
            BlockSpec(64, 16, (32, 64)), BlockSpec(16, 16, (64, 128))))
    params = engine.init(jax.random.PRNGKey(0), spec)
    rng = np.random.default_rng(0)
    xyz = jnp.asarray(np.stack([make_cloud(rng, n) for _ in range(batch)]))
    # ragged config: clouds at 100% / ~75% / ~60% ... of n, cycled over
    # the batch (padding content = repeated rows; fully masked)
    ragged_sizes = [max(int(n * frac), 1) for frac, _ in
                    zip((1.0, 0.75, 0.6, 0.9) * batch, range(batch))]
    ragged_in = engine.Batch.make(
        xyz, key=jax.random.PRNGKey(2),
        n_valid=jnp.asarray(ragged_sizes, jnp.int32))
    batch_in = engine.Batch.make(xyz, key=jax.random.PRNGKey(1))
    configs = [("full", batch_in, [n] * batch),
               ("ragged", ragged_in, ragged_sizes)]
    for backend in ("reference", "pallas"):
        for mode in ("traditional", "lpcn"):
            f = jax.jit(partial(engine.apply, spec=spec, mode=mode,
                                fc_backend=backend))
            for tag, b_in, sizes in configs:
                f(params, b_in).block_until_ready()      # compile
                reps = 2 if quick else 5
                t0 = time.time()
                for _ in range(reps):
                    out = f(params, b_in)
                out.block_until_ready()
                us = (time.time() - t0) / reps * 1e6
                _emit(rows, f"engine_{spec.name}_{mode}_{backend}_{tag}",
                      us, f"clouds_per_s={batch / (us / 1e6):.1f}",
                      backend=backend, batch=batch, mode=mode, n_points=n,
                      ragged=(tag == "ragged"),
                      n_valid={"sizes": sizes,
                               "mean": float(np.mean(sizes)),
                               "min": int(min(sizes)),
                               "max": int(max(sizes))})


# ---- fc_kernel: vmap-of-kernels vs natively batched grid (A/B) --------------

def bench_fc_kernel(rows, quick: bool):
    """Three-way A/B of the two FC kernels on identical inputs: (a) the
    old path (jax.vmap of the single-cloud kernel), (b) the batched grid
    on the VMEM-budget *heuristic* plan, (c) the batched grid on the
    *autotuned* plan (``repro.launch.autotune`` winner, pulled from the
    plan store on the default resolution path).  Mechanism note: vmap's
    pallas batching rule also folds B into one pallas_call, but with the
    unplanned per-cloud body — hardcoded ts=8 / one island per step,
    unaligned lanes, no weight-resident index maps or dimension
    semantics; the ``per_cloud_dispatches`` field records the *logical*
    per-cloud program count of that schedule.

    Every batched row records the plan *actually resolved during its
    trace* (``plans.capture()``) — ``tile`` / ``tile_provenance`` are
    observed, not requested, and an autotuned row that silently fell
    back to the heuristic raises instead of mislabeling the
    measurement.  Winners tuned here persist to the plan store; the
    ``*_speedup_curve`` summary rows record autotuned-vs-vmap as a
    function of B.

    Timing: all variants of a cell are traced and warmed up front,
    then timed in alternating passes (min-of-reps per pass, min across
    passes), so slow drift in background host load cancels out of the
    reported ratios instead of penalizing whichever variant ran
    last."""
    import contextlib
    import jax
    import jax.numpy as jnp
    from repro.kernels import plans
    from repro.kernels.gather_mlp.ops import gather_mlp, gather_mlp_batched
    from repro.kernels.hub_reuse.ops import hub_reuse, hub_reuse_batched
    from repro.launch import autotune

    rng = np.random.default_rng(0)
    reps = 3 if quick else 7
    # parity cells (batched within a few % of vmap) need the min-of-N
    # estimate close to the true floor on both sides of the ratio, so
    # quick mode leans on extra alternating passes instead of long reps
    passes = 6 if quick else 3
    tune_reps = 5 if quick else 7
    tune_budget = 18 if quick else 40
    # always two batch sizes: the A/B's headline is how the gap scales
    # with B (the batched grid amortizes weights/tiling over all B clouds)
    batches = [2, 4] if quick else [2, 8]
    sk = (64, 8) if quick else (512, 32)

    plans.configure(plans.default_path())
    store = plans.active_store()

    def timed(f, *args):
        jax.block_until_ready(f(*args))                # compile + warmup
        best = float("inf")
        for _ in range(reps):
            t0 = time.time()
            out = f(*args)
            jax.block_until_ready(out)
            best = min(best, time.time() - t0)
        return best * 1e6

    def _static_footprint(f, *args):
        """The kernel linter's static VMEM prediction for the traced
        call — recorded next to the measured time so bench results and
        static predictions can be cross-checked offline."""
        from repro.analysis import pallas_call_sites
        sites = pallas_call_sites(jax.make_jaxpr(f)(*args))
        return dict(static_vmem_bytes=[s.footprint_bytes for s in sites])

    def traced_variant(kernel, b, fn, args, expect):
        """Trace and warm a fresh jitted batched call, observing the
        tile plan its trace resolves; raise if the observed provenance
        is not the one this row claims (a silent fallback would
        mislabel the A/B).  Timing happens afterwards, interleaved
        with the other variants — the resolved plan is baked into the
        returned executable, so later store/bypass toggles can't
        change what it runs."""
        ctx = plans.bypass if expect == "heuristic" else contextlib.nullcontext
        # fresh closure per variant: jax's trace cache is keyed on
        # function identity, and a shared fn would let this trace reuse
        # the other variant's jaxpr — plan already baked in, capture
        # would see nothing
        f = jax.jit(lambda *a, _fn=fn: _fn(*a))
        with ctx(), plans.capture() as cap:
            jax.block_until_ready(f(*args))
            sf = _static_footprint(f, *args)
        used = [r["plan"] for r in cap
                if r["kernel"] == kernel and r["dims"].get("b") == b]
        if not used:
            raise RuntimeError(
                f"fc_kernel: no batched tile plan observed for {kernel} "
                f"b={b}")
        plan = used[-1]
        if plan["provenance"] != expect:
            raise RuntimeError(
                f"fc_kernel: batched {kernel} b={b} row ran a "
                f"{plan['provenance']!r} plan — expected {expect!r} "
                f"(silent fallback would mislabel the A/B)")
        return f, plan, sf

    def interleave(variants):
        """min-of-reps per variant, re-measured over alternating
        passes: each pass times every variant back to back, so slow
        drift in host load lands on all of them instead of on
        whichever variant happened to run last."""
        best = [float("inf")] * len(variants)
        for _ in range(passes):
            for i, (f, args) in enumerate(variants):
                best[i] = min(best[i], timed(f, *args))
        return best

    curve = {"gather_mlp": [], "hub_reuse": []}
    for b in batches:
        s, k = sk
        d, dc, hd, f = 35, 3, 64, 128
        raw = jnp.asarray(rng.normal(size=(b, s, k, d)), jnp.float32)
        ctr = jnp.asarray(rng.normal(size=(b, s, dc)), jnp.float32)
        w1 = jnp.asarray(rng.normal(size=(d, hd)) * 0.1, jnp.float32)
        w2 = jnp.asarray(rng.normal(size=(hd, f)) * 0.1, jnp.float32)
        b1 = jnp.zeros((hd,), jnp.float32)
        b2 = jnp.zeros((f,), jnp.float32)
        mask = jnp.asarray(rng.integers(0, 2, (b, s, k)), jnp.int32)
        gdims = {"b": b, "s": s, "k": k, "d": d, "dc": dc, "h": hd, "f": f}
        autotune.ensure_plan("gather_mlp", gdims, store=store,
                             budget=tune_budget, reps=tune_reps)
        gargs = (raw, ctr, mask)
        f_v = jax.jit(jax.vmap(
            lambda r, c, m: gather_mlp(r, c, w1, b1, w2, b2, mask=m)))
        gfn = (lambda r, c, m:
               gather_mlp_batched(r, c, w1, b1, w2, b2, mask=m))
        f_h, plan_h, sf_h = traced_variant(
            "gather_mlp", b, gfn, gargs, expect="heuristic")
        f_a, plan_a, sf_a = traced_variant(
            "gather_mlp", b, gfn, gargs, expect="autotuned")
        us_v, us_h, us_a = interleave(
            [(f_v, gargs), (f_h, gargs), (f_a, gargs)])
        shapes = {"s": s, "k": k, "d": d, "h": hd, "f": f}
        _emit(rows, f"fc_kernel_gather_mlp_vmap_b{b}", us_v,
              f"per_cloud_dispatches={b}", dispatch="vmap",
              per_cloud_dispatches=b, batch=b, shapes=shapes)
        _emit(rows, f"fc_kernel_gather_mlp_batched_b{b}", us_h,
              f"pallas_calls=1 speedup_vs_vmap={us_v / max(us_h, 1e-9):.2f}",
              dispatch="batched_grid", per_cloud_dispatches=1, batch=b,
              shapes=shapes, tile=plan_h, grid=[b, plan_h["grid_tiles"]],
              tile_provenance=plan_h["provenance"], **sf_h)
        _emit(rows, f"fc_kernel_gather_mlp_autotuned_b{b}", us_a,
              f"pallas_calls=1 speedup_vs_vmap={us_v / max(us_a, 1e-9):.2f} "
              f"speedup_vs_heuristic={us_h / max(us_a, 1e-9):.2f}",
              dispatch=("vmap_variant" if plan_a.get("variant") == "vmap"
                        else "batched_grid"),
              per_cloud_dispatches=(b if plan_a.get("variant") == "vmap"
                                    else 1), batch=b,
              shapes=shapes, tile=plan_a, grid=[b, plan_a["grid_tiles"]],
              tile_provenance=plan_a["provenance"], **sf_a)
        curve["gather_mlp"].append((b, us_v / max(us_a, 1e-9)))

        # quick mode shrinks the per-island dims but keeps the full
        # island count: the batched grid's edge over vmap is weight /
        # scheduling amortization ACROSS islands, and below ~16 islands
        # the cell degenerates to parity — not a workload the paper's
        # hub-sharing premise describes
        hn, c, m = (16, 32, 16) if quick else (16, 64, 32)
        pool = jnp.asarray(rng.normal(size=(b, hn, c, d)), jnp.float32)
        slot = jnp.asarray(rng.integers(-1, c, (b, hn, m, k)), jnp.int32)
        comp = jnp.asarray(rng.normal(size=(b, hn, m, f)) * 0.01,
                           jnp.float32)
        live = jnp.asarray(rng.integers(0, 2, (b, hn, m, k)), jnp.int32)
        hdims = {"b": b, "hn": hn, "c": c, "m": m, "k": k, "d": d,
                 "h": hd, "f": f}
        autotune.ensure_plan("hub_reuse", hdims, store=store,
                             budget=tune_budget, reps=tune_reps)
        hargs = (pool, slot, comp, live)
        f_v = jax.jit(jax.vmap(
            lambda p, sl, cp, lv: hub_reuse(p, sl, cp, w1, b1, w2, b2,
                                            live=lv)))
        hfn = (lambda p, sl, cp, lv:
               hub_reuse_batched(p, sl, cp, w1, b1, w2, b2, live=lv))
        f_h, plan_h, sf_h = traced_variant(
            "hub_reuse", b, hfn, hargs, expect="heuristic")
        f_a, plan_a, sf_a = traced_variant(
            "hub_reuse", b, hfn, hargs, expect="autotuned")
        us_v, us_h, us_a = interleave(
            [(f_v, hargs), (f_h, hargs), (f_a, hargs)])
        shapes = {"hn": hn, "c": c, "m": m, "k": k, "d": d, "h": hd, "f": f}
        _emit(rows, f"fc_kernel_hub_reuse_vmap_b{b}", us_v,
              f"per_cloud_dispatches={b}", dispatch="vmap",
              per_cloud_dispatches=b, batch=b, shapes=shapes)
        _emit(rows, f"fc_kernel_hub_reuse_batched_b{b}", us_h,
              f"pallas_calls=1 speedup_vs_vmap={us_v / max(us_h, 1e-9):.2f}",
              dispatch="batched_grid", per_cloud_dispatches=1, batch=b,
              shapes=shapes, tile=plan_h, grid=[b, plan_h["grid_tiles"]],
              tile_provenance=plan_h["provenance"], **sf_h)
        _emit(rows, f"fc_kernel_hub_reuse_autotuned_b{b}", us_a,
              f"pallas_calls=1 speedup_vs_vmap={us_v / max(us_a, 1e-9):.2f} "
              f"speedup_vs_heuristic={us_h / max(us_a, 1e-9):.2f}",
              dispatch=("vmap_variant" if plan_a.get("variant") == "vmap"
                        else "batched_grid"),
              per_cloud_dispatches=(b if plan_a.get("variant") == "vmap"
                                    else 1), batch=b,
              shapes=shapes, tile=plan_a, grid=[b, plan_a["grid_tiles"]],
              tile_provenance=plan_a["provenance"], **sf_a)
        curve["hub_reuse"].append((b, us_v / max(us_a, 1e-9)))

    for kern, pts in curve.items():
        _emit(rows, f"fc_kernel_{kern}_speedup_curve", 0.0,
              " ".join(f"b{bb}={sv:.2f}" for bb, sv in pts),
              curve=[{"batch": bb, "autotuned_speedup_vs_vmap": sv}
                     for bb, sv in pts])

    # ---- whole-model A/B: engine.apply, vmap vs heuristic vs autotuned -----
    from dataclasses import replace as _replace
    from functools import partial
    from repro import engine
    from repro.data.synthetic import make_cloud
    from repro.engine import BlockSpec
    from repro.models import MODEL_ZOO, dgcnn

    def engine_provenances(cap):
        return sorted({r["plan"]["provenance"] for r in cap
                       if r["dims"].get("b") is not None})

    # per-model point counts: the composite ratio only resolves the FC
    # dispatch effect when the FC stage is a non-trivial share of the
    # model — dgcnn's edge convolutions dominate at any n, but
    # pointnet2's structure stage swamps tiny FC cells, so its quick
    # config keeps n (and the block widths) large enough for the A/B
    # to measure the kernels rather than octree noise
    pn_n = 384 if quick else 512
    dg_n = 128 if quick else 512
    model_specs = {
        "pointnet2_c": (pn_n, _replace(MODEL_ZOO["pointnet2_c"][1], blocks=(
            BlockSpec(pn_n // 4, 16, (32, 64)),
            BlockSpec(pn_n // 8, 16, (64, 96))))),
        "dgcnn_c": (dg_n, _replace(dgcnn.with_points(dgcnn.DGCNN_C, dg_n),
                                   blocks=(
            BlockSpec(dg_n, 8, (24,), kind="edge", sampler="all"),
            BlockSpec(dg_n, 8, (32,), kind="edge", sampler="all")))),
    }
    for mname, (n, spec) in model_specs.items():
        params = engine.init(jax.random.PRNGKey(0), spec)
        for bsz in batches:
            xyz = jnp.asarray(np.stack(
                [make_cloud(rng, n) for _ in range(bsz)]))
            b_in = engine.Batch.make(xyz, key=jax.random.PRNGKey(1))
            autotune.autotune_model(spec, bsz, n, mode="lpcn", store=store,
                                    budget=tune_budget, reps=tune_reps)
            provs = {}
            g_v = jax.jit(partial(engine.apply, spec=spec, mode="lpcn",
                                  fc_backend="pallas_vmap"))
            jax.block_until_ready(g_v(params, b_in))
            provs["pallas_vmap"] = ["per_cloud"]
            g_h = jax.jit(partial(engine.apply, spec=spec, mode="lpcn",
                                  fc_backend="pallas"))
            with plans.bypass(), plans.capture() as cap:
                jax.block_until_ready(g_h(params, b_in))
            provs["pallas"] = engine_provenances(cap)
            g_a = jax.jit(partial(engine.apply, spec=spec, mode="lpcn",
                                  fc_backend="pallas"))
            with plans.capture() as cap:
                jax.block_until_ready(g_a(params, b_in))
            provs["pallas_autotuned"] = engine_provenances(cap)
            if provs["pallas_autotuned"] != ["autotuned"]:
                raise RuntimeError(
                    f"fc_kernel: engine {mname} b={bsz} autotuned row "
                    f"resolved {provs['pallas_autotuned']} plans — a "
                    f"silent fallback would mislabel the A/B")
            eargs = (params, b_in)
            t = interleave([(g_v, eargs), (g_h, eargs), (g_a, eargs)])
            times = dict(zip(
                ("pallas_vmap", "pallas", "pallas_autotuned"), t))
            us_v = times["pallas_vmap"]
            ratio_h = us_v / max(times["pallas"], 1e-9)
            ratio_a = us_v / max(times["pallas_autotuned"], 1e-9)
            for be, us in times.items():
                _emit(rows, f"fc_kernel_engine_{mname}_{be}_b{bsz}", us,
                      f"speedup_batched_vs_vmap={ratio_h:.2f} "
                      f"speedup_autotuned_vs_vmap={ratio_a:.2f}",
                      model=mname, batch=bsz, n_points=n, backend=be,
                      dispatch=("vmap" if be == "pallas_vmap"
                                else "batched_grid"),
                      tile_provenance=provs[be],
                      per_cloud_dispatches=(bsz if be == "pallas_vmap"
                                            else 1))
    store.save()


# ---- serve: continuous-batching trace replay --------------------------------

def bench_serve(rows, quick: bool):
    """Replays a synthetic ragged trace (Poisson arrivals, log-normal
    sizes) through the continuous-batching layer and records the
    user-facing serving metrics — e2e/queue-wait percentiles,
    throughput, padding waste, dispatch mix, overlap — as a sync-vs-
    async A/B at three offered loads: light (timeouts fire partial
    batches), heavy (batches fill; the headline comparison), and chaos
    (seeded FaultPlan pricing the degraded fallback path).  Each JSON
    row carries the full serve report; the ``serve_async_ab`` row is
    the headline: heavy-load p95 e2e latency and throughput, async vs
    sync, on the identical trace."""
    import jax
    from dataclasses import replace as _replace
    from repro import engine, serve
    from repro.data.synthetic import make_cloud
    from repro.models import MODEL_ZOO

    _, spec = MODEL_ZOO["pointnet2_c"]
    if quick:
        # 256-point clouds with launch-style reduced blocks (centers
        # capped at points//4), not the tiny 64-point spec the other
        # quick benches use: per-batch service must be big enough that
        # overlapping padding/readback with in-flight compute beats
        # the executor handoff cost, or the A/B reads as noise (on
        # tiny batches sync and async are a wash)
        spec = _replace(spec, blocks=tuple(
            _replace(b, n_centers=min(b.n_centers, 64),
                     k=min(b.k, 16)) for b in spec.blocks))
        sizes, n_med, n_req = [256, 384], 256, 16
    else:
        sizes, n_med, n_req = [512, 1024], 512, 64
    eng = engine.PCNEngine(spec, mode="lpcn", fc_backend="reference")
    params = eng.init(jax.random.PRNGKey(0))
    buckets = serve.BucketSet.make(sizes, batch=2 if quick else 4)
    reports: dict[tuple[str, str], dict] = {}
    for dmode, is_sync in (("sync", True), ("async", False)):
        server = serve.PCNServer(eng, params, buckets, timeout_s=0.01,
                                 max_in_flight=4, sync=is_sync)
        for load, rate in (("light", 30.0), ("heavy", 2000.0)):
            server.metrics = serve.ServeMetrics()  # fresh window per load
            events = serve.synthetic_trace(
                n_requests=n_req, rate_hz=rate, n_median=n_med,
                sigma=0.35, n_max=buckets.max_points, seed=1)
            rng = np.random.default_rng(0)
            rids = serve.replay(
                server, events,
                lambda n, i: (np.asarray(make_cloud(rng, n), np.float32),
                              None))
            rep = server.report(load=load, rate_hz=rate)
            assert all(server.ready(r) for r in rids), \
                "unanswered requests"
            reports[dmode, load] = rep
            lat = rep["latency_ms"]["e2e"]
            _emit(rows, f"serve_trace_{spec.name}_{load}_{dmode}",
                  1e3 * lat["mean"],
                  f"p50={lat['p50']:.1f} p95={lat['p95']:.1f} "
                  f"p99={lat['p99']:.1f} rps={rep['throughput_rps']:.1f} "
                  f"waste={rep['padding_waste_pct']:.1f}% "
                  f"overlap={rep['overlap']['overlap_pct']:.0f}%",
                  serve=rep)
        server.close()

        # chaos load: a seeded fault plan fails primary dispatches
        # mid-trace so the row prices the degraded (fallback-retried)
        # path — every request must still be answered, in both modes
        plan = serve.FaultPlan.bernoulli(
            seed=7, n_steps=n_req, p_fail=0.2, p_nan=0.1)
        server = serve.PCNServer(eng, params, buckets, timeout_s=0.01,
                                 faults=plan, max_in_flight=4,
                                 sync=is_sync)
        events = serve.synthetic_trace(
            n_requests=n_req, rate_hz=2000.0, n_median=n_med, sigma=0.35,
            n_max=buckets.max_points, seed=1)
        rng = np.random.default_rng(0)
        rids = serve.replay(
            server, events,
            lambda n, i: (np.asarray(make_cloud(rng, n), np.float32),
                          None))
        rep = server.report(load="chaos", rate_hz=2000.0)
        assert all(server.ready(r) and not server.failed(r)
                   for r in rids), \
            "chaos load: fallback must answer every request"
        server.close()
        reports[dmode, "chaos"] = rep
        lat = rep["latency_ms"]["e2e"]
        _emit(rows, f"serve_trace_{spec.name}_chaos_{dmode}",
              1e3 * lat["mean"],
              f"p50={lat['p50']:.1f} p99={lat['p99']:.1f} "
              f"degraded={rep['faults']['degraded_dispatches']} "
              f"injected={len(rep['fault_plan']['injected'])}",
              serve=rep)

    # headline A/B: same heavy trace, sync vs async dispatch
    hs, ha = reports["sync", "heavy"], reports["async", "heavy"]
    p95_s = hs["latency_ms"]["e2e"]["p95"]
    p95_a = ha["latency_ms"]["e2e"]["p95"]
    _emit(rows, f"serve_async_ab_{spec.name}_heavy", 1e3 * p95_a,
          f"p95_async={p95_a:.1f}ms p95_sync={p95_s:.1f}ms "
          f"rps_async={ha['throughput_rps']:.1f} "
          f"rps_sync={hs['throughput_rps']:.1f} "
          f"speedup={ha['throughput_rps'] / max(hs['throughput_rps'], 1e-9):.2f}x "
          f"overlap={ha['overlap']['overlap_pct']:.0f}% "
          f"depth<={ha['overlap']['inflight_depth_max']}",
          ab={f"{m}_{ld}": {"p95_e2e_ms": r["latency_ms"]["e2e"]["p95"],
                            "throughput_rps": r["throughput_rps"],
                            "overlap_pct": r["overlap"]["overlap_pct"]}
              for (m, ld), r in reports.items()})


# ---- dist: mesh-sharded engine vs single device -----------------------------

_DIST_WORKER = r"""
import json, time
import numpy as np, jax, jax.numpy as jnp
from dataclasses import replace
from functools import partial
from repro import engine
from repro.data.synthetic import make_cloud
from repro.engine import Batch, BlockSpec
from repro.launch.mesh import make_mesh
from repro.models import pointnet2

quick = {quick}
n_dev = len(jax.devices())
B, N = (n_dev, 128) if quick else (2 * n_dev, 512)
spec = replace(pointnet2.POINTNET2_C, blocks=(
    BlockSpec(N // 4, 8, (16, 32)), BlockSpec(N // 8, 8, (32, 48))))
params = engine.init(jax.random.PRNGKey(0), spec)
rng = np.random.default_rng(0)
xyz = jnp.asarray(np.stack([make_cloud(rng, N) for _ in range(B)]))
batch = Batch.make(xyz, key=jax.random.PRNGKey(1))
mesh = make_mesh((n_dev, 1), ("data", "model"))
reps = 3 if quick else 8
out = []
for tag, mesh_arg in (("single_device", None), ("sharded", mesh)):
    f = jax.jit(partial(engine.apply, spec=spec, mode="lpcn",
                        mesh=mesh_arg))
    f(params, batch).block_until_ready()               # compile
    t0 = time.time()
    for _ in range(reps):
        y = f(params, batch)
    y.block_until_ready()
    us = (time.time() - t0) / reps * 1e6
    cps = B / (us / 1e6)
    devs = n_dev if mesh_arg is not None else 1
    out.append(dict(tag=tag, us=us, device_count=n_dev,
                    devices_used=devs,
                    mesh=None if mesh_arg is None else dict(mesh.shape),
                    batch=B, n_points=N, clouds_per_s=cps,
                    clouds_per_s_per_device=cps / devs))
print("DIST_JSON " + json.dumps(out))
"""


def bench_dist(rows, quick: bool):
    """Mesh-sharded engine.apply (batch split over an (n, 1)
    ("data", "model") mesh) vs the single-device fast path on identical
    inputs.  Runs in a subprocess with a forced host platform device
    count — the same trick as tests/test_distributed.py — so the fake
    CPU devices can't leak into this process's jax.  Records device
    count, mesh shape, and absolute + per-device throughput (on a CPU
    host the fake devices share the same cores, so sharded wall-clock is
    a schedule-overhead measurement, not a speedup claim)."""
    import subprocess
    import sys
    n_dev = 4 if quick else 8
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_dev} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run(
        [sys.executable, "-c", _DIST_WORKER.format(quick=quick)],
        env=env, capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(
            f"dist bench worker failed:\nSTDOUT:\n{r.stdout}\n"
            f"STDERR:\n{r.stderr}")
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("DIST_JSON ")][-1]
    for rec in json.loads(line[len("DIST_JSON "):]):
        tag, us = rec.pop("tag"), rec.pop("us")
        _emit(rows, f"dist_engine_{tag}_d{rec['device_count']}", us,
              f"clouds_per_s={rec['clouds_per_s']:.1f} "
              f"per_device={rec['clouds_per_s_per_device']:.1f} "
              f"mesh={rec['mesh']}", **rec)


SECTIONS = {
    "engine": bench_engine,
    "fc_kernel": bench_fc_kernel,
    "serve": bench_serve,
    "dist": bench_dist,
    "overlap": bench_overlap_study,
    "workload": bench_workload_reduction,
    "speedup": bench_speedup_baselines,
    "fc": bench_fc_speedup,
    "large": bench_large_scale,
    "accuracy": bench_accuracy,
    "sensitivity": bench_sensitivity,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="results/bench.json")
    args = ap.parse_args(argv)
    rows: list = []
    print("name,us_per_call,derived")
    for name, fn in SECTIONS.items():
        if args.only and name != args.only:
            continue
        fn(rows, args.quick)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    json.dump([{"name": n, "us": u, "derived": d, **meta}
               for n, u, d, meta in rows],
              open(args.out, "w"), indent=1)


if __name__ == "__main__":
    main()
