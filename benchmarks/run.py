"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = modeled
accelerator frame latency in µs where applicable, else wall-clock of the
measurement; derived = the figure's headline metric).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only SECTION]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _emit(rows, name, us, derived, **meta):
    """meta (e.g. backend=..., batch=...) is recorded in the JSON output
    alongside the CSV fields."""
    rows.append((name, us, derived, meta))
    print(f"{name},{us:.1f},{derived}", flush=True)


# ---- Fig. 4(b): overlap-vs-distance motivation study -----------------------

def bench_overlap_study(rows, quick: bool):
    import jax
    import jax.numpy as jnp
    from repro.core.pipeline import LPCNConfig, data_structuring
    from repro.core.workload import overlap_histogram
    from repro.data.synthetic import make_cloud
    rng = np.random.default_rng(0)
    xyz = jnp.asarray(make_cloud(rng, 1024))
    for sa, (s, k) in {"SA1": (512, 32), "SA2": (128, 64)}.items():
        cfg = LPCNConfig(n_centers=s, k=k)
        t0 = time.time()
        cidx, nbr = data_structuring(cfg, xyz, jax.random.PRNGKey(0))
        hist = overlap_histogram(nbr, xyz[cidx])
        us = (time.time() - t0) * 1e6
        near_mean, near_max = hist["near_0_16"]
        rest_mean, _ = hist["rest"]
        _emit(rows, f"fig4b_overlap_{sa}_top16", us,
              f"mean={near_mean:.3f} max={near_max:.3f} "
              f"rest_mean={rest_mean:.3f}")


# ---- Fig. 15: theoretical workload optimization -----------------------------

def bench_workload_reduction(rows, quick: bool):
    from .workloads import BENCHMARKS, layer_works, totals
    for name, (model, _ds, n) in BENCHMARKS.items():
        if quick and n > 4096:
            continue
        t0 = time.time()
        lw = layer_works(model, n)
        t = totals(lw)
        us = (time.time() - t0) * 1e6
        _emit(rows, f"fig15_workload_{name}", us,
              f"fetch_saving={t['fetch_saving']:.3f} "
              f"mem_saving={t['mem_saving']:.3f} "
              f"compute_saving={t['compute_saving']:.3f}")


# ---- Fig. 16: speedup over the four DS-accelerator baselines ---------------

def bench_speedup_baselines(rows, quick: bool):
    from .perfmodel import speedup
    from .workloads import BENCHMARKS, layer_works
    for name, (model, _ds, n) in BENCHMARKS.items():
        if quick and n > 4096:
            continue
        lw = layer_works(model, n)
        for method in ("pointacc", "hgpcn", "edgepc", "crescent"):
            s = speedup(method, lw)
            us = s["lpcn_ms"] * 1e3
            _emit(rows, f"fig16_{method}_{name}", us,
                  f"speedup={s['speedup']:.2f} "
                  f"dsu_frac={s['dsu_frac_baseline']:.2f} "
                  f"islu_frac={s['islu_frac']:.4f}")


# ---- Fig. 17: FC speedup vs GDPCA / Mesorasi --------------------------------

def bench_fc_speedup(rows, quick: bool):
    from .perfmodel import (fc_speedup_gdpca, fc_speedup_lpcn,
                            fc_speedup_mesorasi)
    from .workloads import BENCHMARKS, layer_works
    for name, (model, _ds, n) in BENCHMARKS.items():
        if quick and n > 4096:
            continue
        t0 = time.time()
        lw = layer_works(model, n)
        us = (time.time() - t0) * 1e6
        _emit(rows, f"fig17_fc_{name}", us,
              f"gdpca={fc_speedup_gdpca(lw):.2f} "
              f"lpcn={fc_speedup_lpcn(lw):.2f} "
              f"mesorasi_onchip={fc_speedup_mesorasi(lw, on_chip=True):.2f} "
              f"mesorasi_offchip="
              f"{fc_speedup_mesorasi(lw, on_chip=False):.2f}")


# ---- Fig. 18/19: large-scale PCNs (PointNeXt / PointVector) ----------------

def bench_large_scale(rows, quick: bool):
    from .perfmodel import fc_speedup_mesorasi, frame_latency
    from .workloads import LARGE_SCALE, layer_works, totals
    for name, (model, _ds, n) in LARGE_SCALE.items():
        if quick and n > 8192:
            continue
        t0 = time.time()
        # FractalCloud setting: block-based approximate DS (morton-strided
        # sampling + window gather) — also the only tractable DS at 65k+
        lw = layer_works(model, n, neighbor="edgepc", sampler="morton")
        t = totals(lw)
        # FractalCloud = block DS + Mesorasi delayed-aggregation FC;
        # L-PCN plug-in replaces the FC optimization
        base = frame_latency("crescent", lw, "traditional")
        ours = frame_latency("crescent", lw, "lpcn")
        mes_fc_speed = fc_speedup_mesorasi(lw, on_chip=False)
        fractal = base["dsu"] + base["fcu"] / max(mes_fc_speed, 1e-9)
        us = (time.time() - t0) * 1e6
        _emit(rows, f"fig18_19_{name}", us,
              f"fetch_saving={t['fetch_saving']:.3f} "
              f"compute_saving={t['compute_saving']:.3f} "
              f"speedup_vs_fractalcloud="
              f"{fractal / max(ours['total'], 1):.2f}")


# ---- Fig. 20: accuracy ------------------------------------------------------

def bench_accuracy(rows, quick: bool):
    from .accuracy import run_accuracy
    t0 = time.time()
    res = run_accuracy(quick=quick)
    us = (time.time() - t0) * 1e6
    for name, accs in res.items():
        _emit(rows, f"fig20_accuracy_{name}", us,
              " ".join(f"{k}={v:.3f}" for k, v in accs.items()))


# ---- Fig. 22: sensitivity ---------------------------------------------------

def bench_sensitivity(rows, quick: bool):
    from .perfmodel import speedup
    from .workloads import layer_works, totals
    sizes = [16, 32] if quick else [8, 16, 32, 64]
    caps = [2.0] if quick else [1.0, 2.0, 4.0]
    for isz in sizes:
        for cx in caps:
            t0 = time.time()
            lw = layer_works("pointnet2_c", 1024,
                             {"island_size": isz,
                              "island_capacity": 2 * isz,
                              "cache_capacity_x": cx})
            t = totals(lw)
            s = speedup("pointacc", lw)
            us = (time.time() - t0) * 1e6
            _emit(rows, f"fig22_sens_isz{isz}_cap{cx}", us,
                  f"fetch_saving={t['fetch_saving']:.3f} "
                  f"compute_saving={t['compute_saving']:.3f} "
                  f"speedup={s['speedup']:.2f}")


# ---- engine: batched serving path (repro.engine), per FC backend -----------

def bench_engine(rows, quick: bool):
    """Wall-clock of the jitted batch-first engine on pointnet2_c:
    compile once, then time steady-state batches per backend x mode, on a
    full batch AND a ragged (padded, n_valid-masked) batch — the delta is
    the masking overhead later perf PRs track."""
    import jax
    import jax.numpy as jnp
    from functools import partial
    from dataclasses import replace as _replace
    from repro import engine
    from repro.data.synthetic import make_cloud
    from repro.models import MODEL_ZOO

    _, spec = MODEL_ZOO["pointnet2_c"]
    batch, n = (2, 256) if quick else (4, 1024)
    if quick:
        from repro.models.common import BlockSpec
        spec = _replace(spec, blocks=(
            BlockSpec(64, 16, (32, 64)), BlockSpec(16, 16, (64, 128))))
    params = engine.init(jax.random.PRNGKey(0), spec)
    rng = np.random.default_rng(0)
    xyz = jnp.asarray(np.stack([make_cloud(rng, n) for _ in range(batch)]))
    # ragged config: clouds at 100% / ~75% / ~60% ... of n, cycled over
    # the batch (padding content = repeated rows; fully masked)
    ragged_sizes = [max(int(n * frac), 1) for frac, _ in
                    zip((1.0, 0.75, 0.6, 0.9) * batch, range(batch))]
    ragged_in = engine.Batch.make(
        xyz, key=jax.random.PRNGKey(2),
        n_valid=jnp.asarray(ragged_sizes, jnp.int32))
    batch_in = engine.Batch.make(xyz, key=jax.random.PRNGKey(1))
    configs = [("full", batch_in, [n] * batch),
               ("ragged", ragged_in, ragged_sizes)]
    for backend in ("reference", "pallas"):
        for mode in ("traditional", "lpcn"):
            f = jax.jit(partial(engine.apply, spec=spec, mode=mode,
                                fc_backend=backend))
            for tag, b_in, sizes in configs:
                f(params, b_in).block_until_ready()      # compile
                reps = 2 if quick else 5
                t0 = time.time()
                for _ in range(reps):
                    out = f(params, b_in)
                out.block_until_ready()
                us = (time.time() - t0) / reps * 1e6
                _emit(rows, f"engine_{spec.name}_{mode}_{backend}_{tag}",
                      us, f"clouds_per_s={batch / (us / 1e6):.1f}",
                      backend=backend, batch=batch, mode=mode, n_points=n,
                      ragged=(tag == "ragged"),
                      n_valid={"sizes": sizes,
                               "mean": float(np.mean(sizes)),
                               "min": int(min(sizes)),
                               "max": int(max(sizes))})


SECTIONS = {
    "engine": bench_engine,
    "overlap": bench_overlap_study,
    "workload": bench_workload_reduction,
    "speedup": bench_speedup_baselines,
    "fc": bench_fc_speedup,
    "large": bench_large_scale,
    "accuracy": bench_accuracy,
    "sensitivity": bench_sensitivity,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="results/bench.json")
    args = ap.parse_args(argv)
    rows: list = []
    print("name,us_per_call,derived")
    for name, fn in SECTIONS.items():
        if args.only and name != args.only:
            continue
        fn(rows, args.quick)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    json.dump([{"name": n, "us": u, "derived": d, **meta}
               for n, u, d, meta in rows],
              open(args.out, "w"), indent=1)


if __name__ == "__main__":
    main()
