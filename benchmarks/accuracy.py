"""Fig. 20: end-task accuracy of L-PCN's selective approximation vs.
traditional (exact) and Mesorasi (fully approximate).

We train a small PointNet++ classifier on a synthetic 8-class shape task
with the TRADITIONAL path, then evaluate the same weights under each
execution mode — exactly the paper's setting (the accelerator changes
inference execution, not training).
"""
from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mlp import MLP, apply_mlp, init_mlp
from repro.core.pipeline import (LPCNConfig, data_structuring,
                                 fc_lpcn, fc_traditional)
from repro.core.hub_schedule import build_schedule
from repro.core.islandize import islandize
from repro.data.synthetic import make_cloud
from repro.models.baselines import mesorasi_fc


def _gen_task(n_clouds: int, n_points: int, seed: int):
    """8-class shape task, separable by construction: class k = a fixed
    primitive composition (sphere/box/cylinder × scale), jittered."""
    from repro.data.synthetic import _box, _cylinder, _sphere
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    for i in range(n_clouds):
        cls = i % 8
        kind, big = cls % 4, cls // 4
        scale = 0.9 if big else 0.45
        n1 = n_points // 2
        c = rng.normal(0, 0.05, 3)
        if kind == 0:
            a = _sphere(rng, n1, c, 0.5 * scale)
            b = _sphere(rng, n_points - n1, -c, 0.25 * scale)
        elif kind == 1:
            a = _box(rng, n1, c, np.full(3, scale))
            b = _sphere(rng, n_points - n1, -c, 0.3 * scale)
        elif kind == 2:
            a = _cylinder(rng, n1, c, 0.3 * scale, 1.2 * scale)
            b = _box(rng, n_points - n1, -c, np.full(3, 0.4 * scale))
        else:
            a = _cylinder(rng, n1, c, 0.5 * scale, 0.4 * scale)
            b = _cylinder(rng, n_points - n1, -c, 0.15 * scale,
                          1.5 * scale)
        pts = np.concatenate([a, b])[:n_points]
        pts += 0.01 * rng.normal(size=pts.shape)
        pts -= pts.mean(0)
        pts /= np.abs(pts).max() + 1e-9
        xs.append(pts.astype(np.float32))
        ys.append(cls)
    return (jnp.asarray(np.stack(xs)), jnp.asarray(np.array(ys),
                                                   jnp.int32))


def _model_init(key, activation: str):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mlp1": init_mlp(k1, [6, 32, 64], activation),
        "mlp2": init_mlp(k2, [64 + 3, 64, 128], activation),
        "head": init_mlp(k3, [128, 64, 8], "per_layer"),
    }


def _forward(params, xyz, mode: str, key, comp: str = "linear",
             activation: str = "block_end"):
    cfg1 = LPCNConfig(n_centers=128, k=16, mode=mode, compensation=comp)
    cfg2 = LPCNConfig(n_centers=32, k=16, mode=mode, compensation=comp,
                      island_size=16, cache_capacity_x=2.0)
    k1, k2 = jax.random.split(key)

    def block(cfg, mlp, xyz_in, feats, kk):
        cidx, nbr = data_structuring(cfg, xyz_in, kk)
        centers = xyz_in[cidx]
        cf = feats[cidx]
        if mode == "traditional":
            f = fc_traditional(mlp, xyz_in, feats, nbr, centers, cf, "sa")
        elif mode == "mesorasi":
            f = mesorasi_fc(mlp, xyz_in, feats, nbr, centers, cf, "sa")
        else:
            n_hubs = max(int(cidx.shape[0]) // cfg.island_size, 1)
            isl = islandize(centers, n_hubs, capacity=cfg.island_capacity,
                            key=kk)
            sched = build_schedule(isl, nbr, cfg.cache_capacity)
            f = fc_lpcn(mlp, xyz_in, feats, nbr, centers, isl, sched,
                        cfg, cf)
        return centers, f

    c1, f1 = block(cfg1, params["mlp1"], xyz, xyz, k1)
    c2, f2 = block(cfg2, params["mlp2"], c1, f1, k2)
    g = f2.max(axis=0)
    return apply_mlp(params["head"], g)


def run_accuracy(quick: bool = False) -> dict:
    n_train, n_test = (64, 32) if quick else (160, 64)
    n_points = 256
    xtr, ytr = _gen_task(n_train, n_points, seed=1)
    xte, yte = _gen_task(n_test, n_points, seed=2)
    results = {}
    for act_name, activation in [("block_end", "block_end"),
                                 ("per_layer", "per_layer")]:
        key = jax.random.PRNGKey(0)
        params = _model_init(key, activation)

        fwd_tr = jax.jit(jax.vmap(
            lambda p, x: _forward(p, x, "traditional", key,
                                  activation=activation),
            in_axes=(None, 0)), static_argnums=())

        def loss_fn(p, xs, ys):
            logits = fwd_tr(p, xs)
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(lp[jnp.arange(ys.shape[0]), ys])

        # train with the exact path
        lr = 3e-3
        for epoch in range(4 if quick else 10):
            for i in range(0, n_train, 16):
                g = jax.grad(loss_fn)(params, xtr[i:i + 16],
                                      ytr[i:i + 16])
                params = jax.tree.map(lambda p, gg: p - lr * gg,
                                      params, g)

        accs = {}
        for mode, comp in [("traditional", "linear"),
                           ("lpcn", "linear"), ("lpcn", "mlp"),
                           ("mesorasi", "linear")]:
            fwd = jax.jit(jax.vmap(
                lambda p, x: _forward(p, x, mode, key, comp,
                                      activation), in_axes=(None, 0)))
            pred = jnp.argmax(fwd(params, xte), -1)
            tag = mode if mode != "lpcn" else f"lpcn_{comp}"
            accs[tag] = float((pred == yte).mean())
        results[act_name] = accs
    return results
