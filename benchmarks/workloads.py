"""Shared benchmark machinery: run PCN models on synthetic datasets and
collect per-layer LayerWork records for the perf model."""
from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mlp import init_mlp
from repro.core.pipeline import LPCNConfig, data_structuring
from repro.core.hub_schedule import build_schedule
from repro.core.islandize import islandize
from repro.core.workload import analyze
from repro.data.synthetic import DATASETS, make_cloud
from repro.models import MODEL_ZOO

from .perfmodel import LayerWork

# benchmark name -> (model key, dataset, n_points)
BENCHMARKS = {
    "pointnet2_c@modelnet40": ("pointnet2_c", "modelnet40", 1024),
    "pointnet2_ps@shapenet": ("pointnet2_ps", "shapenet", 2048),
    "pointnet2_s@s3dis": ("pointnet2_s", "s3dis", 4096),
    "dgcnn_c@modelnet40": ("dgcnn_c", "modelnet40", 1024),
    "dgcnn_s@scannet": ("dgcnn_s", "scannet", 8192),
}

LARGE_SCALE = {
    "pointnext_s@s3dis8k": ("pointnext_s", "scannet", 8192),
    "pointnext_s@s3dis64k": ("pointnext_s", "s3dis_large", 65536),
    "pointvector_l@s3dis8k": ("pointvector_l", "scannet", 8192),
}


def scaled_spec(model_key: str, n_points: int):
    """Scale a model spec's center counts to the dataset size."""
    mod, spec = MODEL_ZOO[model_key]
    if model_key.startswith("dgcnn"):
        from repro.models.dgcnn import with_points
        return mod, with_points(spec, n_points)
    # SA stacks: scale n_centers proportionally to the reference input
    ref = {"pointnet2_c": 1024, "pointnet2_ps": 2048, "pointnet2_s": 4096,
           "pointnext_s": 8192, "pointvector_l": 8192}[model_key]
    factor = n_points / ref
    from repro.models.common import BlockSpec
    blocks = tuple(
        BlockSpec(max(int(b.n_centers * factor), 16), b.k, b.mlp_dims,
                  b.radius, b.kind, b.sampler, b.neighbor)
        for b in spec.blocks)
    return mod, replace(spec, blocks=blocks)


def layer_works(model_key: str, n_points: int, isl_kw: dict | None = None,
                neighbor: str = "pointacc", seed: int = 0,
                n_clouds: int = 1, sampler: str | None = None
                ) -> list[LayerWork]:
    """Run the DS + islandization for each layer of the model over
    ``n_clouds`` synthetic clouds and return averaged LayerWork records
    (measured, not estimated)."""
    mod, spec = scaled_spec(model_key, n_points)
    isl_kw = isl_kw or {}
    rng = np.random.default_rng(seed)
    scene = n_points >= 4096
    out: list[LayerWork] = []
    for c in range(n_clouds):
        xyz = jnp.asarray(make_cloud(rng, n_points, scene))
        cur_xyz = xyz
        f_prev = spec.in_feats
        key = jax.random.PRNGKey(seed + c)
        for li, b in enumerate(spec.blocks):
            key, k1, k2 = jax.random.split(key, 3)
            cfg = LPCNConfig(
                n_centers=b.n_centers, k=b.k,
                sampler=(sampler or b.sampler) if b.sampler != "all"
                else b.sampler,
                neighbor=neighbor,
                block_kind=b.kind,
                island_size=isl_kw.get("island_size", 32),
                island_capacity=isl_kw.get("island_capacity", 64),
                cache_capacity_x=isl_kw.get("cache_capacity_x", 2.0),
                hub_select=isl_kw.get("hub_select", "random"))
            cidx, nbr = data_structuring(cfg, cur_xyz, k1)
            centers = cur_xyz[cidx]
            n_hubs = max(int(cidx.shape[0]) // cfg.island_size, 1)
            isl = islandize(centers, n_hubs, capacity=cfg.island_capacity,
                            hub_select=cfg.hub_select, key=k2)
            sched = build_schedule(isl, nbr, cfg.cache_capacity)
            r = analyze(isl, sched, cfg.k).concrete()
            f_in = (3 + f_prev) if b.kind == "sa" else 2 * f_prev
            f_out = b.mlp_dims[-1]
            lw = LayerWork(
                n_points=int(cur_xyz.shape[0]), n_subsets=r.n_subsets,
                k=b.k, f_in=f_in, f_out=f_out,
                base_evals=r.baseline_mlp_evals,
                lpcn_evals=r.lpcn_mlp_evals,
                base_fetches=r.baseline_fetches,
                lpcn_fetches=r.lpcn_fetches)
            if c == 0:
                out.append(lw)
            else:  # running average
                o = out[li]
                for fld in ("base_evals", "lpcn_evals", "base_fetches",
                            "lpcn_fetches"):
                    setattr(o, fld,
                            (getattr(o, fld) * c + getattr(lw, fld))
                            // (c + 1))
            # downsample for next layer (SA) or keep all (edge)
            if b.sampler != "all":
                cur_xyz = centers
            f_prev = f_out
    return out


def totals(layers: list[LayerWork]) -> dict:
    """Frame-level savings.  Overall-memory model (paper's yellow bars):
    feature traffic = fetches x f_in x 4B; layer weights are fetched ONCE
    per frame (on-chip resident during the layer, as in all baselines)."""
    bf = sum(l.base_fetches * l.f_in * 4 for l in layers)
    lf = sum(l.lpcn_fetches * l.f_in * 4 for l in layers)
    bcnt = sum(l.base_fetches for l in layers)
    lcnt = sum(l.lpcn_fetches for l in layers)
    bev = sum(l.base_evals * (l.f_in * l.f_out) for l in layers)
    lev = sum(l.lpcn_evals * (l.f_in * l.f_out) for l in layers)
    wbytes = sum(l.f_in * l.f_out * 4 for l in layers)
    return {
        "fetch_saving": 1 - lcnt / max(bcnt, 1),
        "compute_saving": 1 - lev / max(bev, 1),
        "mem_saving": 1 - (lf + wbytes) / max(bf + wbytes, 1),
    }
