"""Analytical accelerator performance model (the CPU-container stand-in
for the paper's cycle-accurate RTL simulation — DESIGN.md §2).

Every constant is from the paper or the cited baselines:
  * 250 MHz FPGA clock (Arria 10 GX prototypes), 1 GHz ASIC variant
  * FCU: 16×16 systolic array (all baselines, §VI-A) -> 256 MAC/cycle
  * DSU (per baseline §VI-C):
      - PointACC: 16 parallel distance calculators + 32-way bitonic
        ranking -> S·N/16 distance cycles + S·N/32·log(32) sort cycles
      - HgPCN: octree narrows candidates ~8x, then PointACC-style rank
      - EdgePC: Morton-window (W=128) approximate gather
      - Crescent: KD-bucket (2 leaves x 64) approximate gather
  * Islandization Unit: 1,497 cycles/frame (paper Table II) — <1 %
  * off-chip bandwidth: 16 B/cycle (DDR4-class @ 250 MHz = 4 GB/s)
  * GDPCA: Bit-Pragmatic FCU — cycles scale with average nonzero-bit
    fraction of the *delta* inputs (≈ 0.45 of 8-bit baseline per [5]/[34])
  * Mesorasi: FC = PFT build (N evals) + delayed-aggregation gather;
    on-chip: gather overlaps compute; off-chip: PFT refetch serializes.

Latency(frame) = Σ_layers [ DSU(layer) + FCU(layer) ] + IslU
FCU(layer) = max(compute_cycles, fetch_cycles)  (double-buffered overlap)
"""
from __future__ import annotations

from dataclasses import dataclass

CLOCK_FPGA = 250e6
MAC_PER_CYCLE = 256          # 16x16 systolic
BYTES_PER_CYCLE = 16         # off-chip
ISLAND_UNIT_CYCLES = 1497    # paper Table II
FEAT_BYTES = 4


@dataclass
class LayerWork:
    """Measured workload of one PCN layer (from core.workload)."""
    n_points: int            # input cloud size N
    n_subsets: int           # S
    k: int
    f_in: int
    f_out: int
    base_evals: int          # baseline MLP point-evals (= S*K)
    lpcn_evals: int          # islandized MLP point-evals
    base_fetches: int
    lpcn_fetches: int


def mlp_macs(f_in: int, f_out: int, hidden: tuple = ()) -> int:
    dims = [f_in, *hidden, f_out]
    return sum(a * b for a, b in zip(dims[:-1], dims[1:]))


def dsu_cycles(method: str, n: int, s: int, k: int) -> int:
    if method == "pointacc":
        dist = s * n // 16
        sort = s * (n // 32) * 5
        return dist + sort
    if method == "hgpcn":
        cand = max(n // 8, 4 * k)
        return s * 400 // 16 + s * cand // 16 + s * (cand // 32) * 5
    if method == "edgepc":
        w = 128
        return s * w // 16 + s * (w // 32) * 5
    if method == "crescent":
        w = 2 * 64
        return s * w // 16 + s * (w // 32) * 5
    raise ValueError(method)


def fcu_cycles(evals: int, macs_per_point: int, fetches: int,
               f_in: int, weight_bytes: int,
               overlap: bool = True) -> tuple:
    compute = evals * macs_per_point // MAC_PER_CYCLE
    fetch = (fetches * f_in * FEAT_BYTES
             + (-(-evals // 16)) // 16 * weight_bytes) // BYTES_PER_CYCLE
    if overlap:
        return max(compute, fetch), compute, fetch
    return compute + fetch, compute, fetch


def frame_latency(method: str, layers: list[LayerWork],
                  mode: str = "lpcn", hidden: tuple = ()) -> dict:
    """Cycles for one point-cloud frame.  mode: traditional | lpcn."""
    total_dsu = total_fcu = 0
    for L in layers:
        total_dsu += dsu_cycles(method, L.n_points, L.n_subsets, L.k)
        macs = mlp_macs(L.f_in, L.f_out, hidden)
        wbytes = macs * 1  # int8/bf8 weights on-chip-resident per tile
        if mode == "traditional":
            c, _, _ = fcu_cycles(L.base_evals, macs, L.base_fetches,
                                 L.f_in, wbytes)
        else:
            c, _, _ = fcu_cycles(L.lpcn_evals, macs, L.lpcn_fetches,
                                 L.f_in, wbytes)
        total_fcu += c
    isl = ISLAND_UNIT_CYCLES if mode == "lpcn" else 0
    return {"dsu": total_dsu, "fcu": total_fcu, "islu": isl,
            "total": total_dsu + total_fcu + isl}


def speedup(method: str, layers: list[LayerWork],
            hidden: tuple = ()) -> dict:
    base = frame_latency(method, layers, "traditional", hidden)
    ours = frame_latency(method, layers, "lpcn", hidden)
    return {
        "method": method,
        "baseline_cycles": base["total"],
        "lpcn_cycles": ours["total"],
        "speedup": base["total"] / max(ours["total"], 1),
        "dsu_frac_baseline": base["dsu"] / base["total"],
        "islu_frac": ours["islu"] / ours["total"],
        "baseline_ms": base["total"] / CLOCK_FPGA * 1e3,
        "lpcn_ms": ours["total"] / CLOCK_FPGA * 1e3,
    }


# ---- Fig. 17: FC-only speedups (GDPCA / Mesorasi) --------------------------

def fc_speedup_gdpca(layers: list[LayerWork], hidden: tuple = (),
                     nonzero_bit_frac: float = 0.45) -> float:
    """GDPCA: same eval count, Bit-Pragmatic cycles scale with nonzero
    bits of delta-encoded inputs."""
    base = ours = 0
    for L in layers:
        macs = mlp_macs(L.f_in, L.f_out, hidden)
        base += L.base_evals * macs
        ours += int(L.base_evals * macs * nonzero_bit_frac)
    return base / max(ours, 1)


def fc_speedup_lpcn(layers: list[LayerWork], hidden: tuple = ()) -> float:
    base = ours = 0
    for L in layers:
        macs = mlp_macs(L.f_in, L.f_out, hidden)
        base += L.base_evals * macs // MAC_PER_CYCLE
        c, _, _ = fcu_cycles(L.lpcn_evals, macs, L.lpcn_fetches, L.f_in,
                             macs)
        ours += c
    return base / max(ours, 1)


def fc_speedup_mesorasi(layers: list[LayerWork], hidden: tuple = (),
                        on_chip: bool = True) -> float:
    base = ours = 0
    for L in layers:
        macs = mlp_macs(L.f_in, L.f_out, hidden)
        base += L.base_evals * macs // MAC_PER_CYCLE
        evals = L.n_points + L.n_subsets        # PFT + centers
        compute = evals * macs // MAC_PER_CYCLE
        # delayed-aggregation phase: refetch F_out feats for every slot
        refetch = (L.base_evals * L.f_out * FEAT_BYTES) // BYTES_PER_CYCLE
        ours += max(compute, refetch) if on_chip else compute + refetch
    return base / max(ours, 1)
