"""Islandization invariants (paper §IV-A), incl. hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean env: deterministic fallback sampler
    from _hyp import given, settings, strategies as st

from repro.core.islandize import islandize as _islandize
from repro.data.synthetic import make_cloud


def _centers(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(make_cloud(rng, max(n, 16))[:n])


@pytest.mark.parametrize("n_hubs", [2, 4, 8])
def test_partition_property(n_hubs):
    """Every center is in exactly one island OR solo (paper: 'a point
    subset cannot belong to more than one island')."""
    centers = _centers(128)
    out = _islandize(centers, n_hubs, capacity=64,
                        key=jax.random.PRNGKey(0))
    members = np.asarray(out.members)
    solo = np.asarray(out.solo)
    flat = members[members >= 0]
    assert len(set(flat.tolist())) == len(flat)      # no duplicates
    covered = set(flat.tolist()) | set(np.where(solo)[0].tolist())
    assert covered == set(range(128))                # complete


def test_hub_first_and_round_order():
    centers = _centers(128, seed=1)
    out = _islandize(centers, 4, capacity=64,
                        key=jax.random.PRNGKey(1))
    members = np.asarray(out.members)
    rounds = np.asarray(out.round_of)
    hubs = set(np.asarray(out.hub).tolist())
    for h in range(4):
        row = members[h][members[h] >= 0]
        if len(row) == 0:
            continue
        assert row[0] in hubs                        # hub at slot 0
        r = rounds[row]
        assert (np.diff(r) >= 0).all()               # inside-to-outside


def test_islands_spatially_coherent():
    """Mean intra-island distance < mean cross-island distance."""
    centers = _centers(256, seed=2)
    out = _islandize(centers, 8, capacity=64,
                        key=jax.random.PRNGKey(2))
    members = np.asarray(out.members)
    c = np.asarray(centers)
    intra, cross = [], []
    means = []
    for h in range(8):
        row = members[h][members[h] >= 0]
        if len(row) < 2:
            continue
        pts = c[row]
        means.append(pts.mean(0))
        intra.append(np.linalg.norm(pts - pts.mean(0), axis=1).mean())
    means = np.array(means)
    if len(means) > 1:
        cross = np.linalg.norm(means[:, None] - means[None, :],
                               axis=-1)
        cross = cross[cross > 0].mean()
        assert np.mean(intra) < cross


@given(st.integers(1, 6), st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_partition_property_fuzz(n_hubs, seed):
    centers = _centers(64, seed=seed)
    out = _islandize(centers, n_hubs, capacity=32,
                        key=jax.random.PRNGKey(seed))
    members = np.asarray(out.members)
    solo = np.asarray(out.solo)
    flat = members[members >= 0]
    assert len(set(flat.tolist())) == len(flat)
    assert set(flat.tolist()) | set(np.where(solo)[0].tolist()) \
        == set(range(64))


def test_fps_hub_selection_reduces_solo():
    """FPS hub selection (beyond-paper option) preserves the partition
    property.  NOTE: measured across seeds FPS is NOT consistently better
    than the paper's random hubs — FPS picks boundary points, growing
    islands unevenly (hypothesis refuted; EXPERIMENTS.md §Perf notes)."""
    for seed in (3, 4):
        centers = _centers(256, seed=seed)
        out = _islandize(centers, 8, capacity=48, hub_select="fps",
                         key=jax.random.PRNGKey(seed))
        members = np.asarray(out.members)
        flat = members[members >= 0]
        assert len(set(flat.tolist())) == len(flat)
        covered = set(flat.tolist()) | set(
            np.where(np.asarray(out.solo))[0].tolist())
        assert covered == set(range(256))
