"""Plan-cache and autotuner contracts (repro.kernels.plans +
repro.launch.autotune): save→load round-trips bit-identically; a cache
hit adds zero jit compilations beyond the plan's own shapes; stale or
corrupt entries warn and fall back to the heuristic rather than
raising; the same seed and budget pick the same winner; and a stored
plan silently replaces the heuristic on the default engine path with
``provenance == "autotuned"`` and unchanged numerics."""
import json
from dataclasses import replace
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.analysis import compile_cache_size
from repro.data.synthetic import make_cloud
from repro.engine import Batch, BlockSpec
from repro.kernels import plans
from repro.kernels.gather_mlp.ops import gather_mlp_tile_plan
from repro.launch import autotune
from repro.models import pointnet2

# small cells: the tuner never executes kernels under the injected
# timer (only make_jaxpr traces for the lint gate), so these stay fast
GDIMS = {"b": 2, "s": 16, "k": 4, "d": 6, "dc": 3, "h": 8, "f": 16}
HDIMS = {"b": 2, "hn": 4, "c": 8, "m": 4, "k": 4, "d": 6, "h": 8, "f": 16}

SPEC = replace(pointnet2.POINTNET2_C, blocks=(
    BlockSpec(24, 4, (8, 16)), BlockSpec(8, 4, (16, 16))))
N = 48


def cost_model(call, knobs):
    """Deterministic injected timer: never executes the kernel.  Ranks
    candidates by (tile, lanes) so the winner is knowable; knobs=None is
    the vmap baseline."""
    if knobs is None:
        return 1000.0
    return float(knobs["tile"] * 10 + knobs["lanes"])


def _entry(ts=8, lanes=8, mb=8.0):
    return {"ts": ts, "lanes": lanes, "vmem_budget_mb": mb,
            "dimension_semantics": ["parallel", "arbitrary"],
            "provenance": "autotuned", "measured_us": 12.5}


def _batch(spec, sizes, seed=0):
    rng = np.random.default_rng(seed)
    b = len(sizes)
    xyz = jnp.asarray(np.stack([make_cloud(rng, N) for _ in range(b)]))
    return Batch.make(xyz, xyz, key=jax.random.PRNGKey(3),
                      n_valid=jnp.asarray(sizes, jnp.int32))


# ---- store round-trip / corruption ------------------------------------------

def test_save_load_round_trips_bit_identically(tmp_path):
    store = plans.PlanStore()
    store.record("gather_mlp", GDIMS, _entry(ts=16, lanes=128))
    store.record("hub_reuse", HDIMS,
                 {"th": 2, "lanes": 32, "vmem_budget_mb": 4.0,
                  "dimension_semantics": ["arbitrary", "arbitrary"],
                  "provenance": "autotuned", "measured_us": 7.25,
                  "speedup_vs_vmap": 1.5})
    path = store.save(str(tmp_path / "plans.json"))
    loaded = plans.PlanStore.load(path)
    assert loaded.entries == store.entries
    # and a second save of the loaded store produces the same bytes
    path2 = loaded.save(str(tmp_path / "plans2.json"))
    assert open(path).read() == open(path2).read()


def test_corrupt_store_warns_and_degrades(tmp_path):
    p = tmp_path / "plans.json"
    p.write_text("{not json")
    with pytest.warns(RuntimeWarning, match="unreadable"):
        store = plans.PlanStore.load(str(p))
    assert len(store) == 0

    p.write_text(json.dumps({"version": 999, "plans": {}}))
    with pytest.warns(RuntimeWarning, match="version"):
        store = plans.PlanStore.load(str(p))
    assert len(store) == 0


def test_invalid_entries_dropped_not_fatal(tmp_path):
    good_key = plans.plan_key("gather_mlp", GDIMS)
    raw = {"version": plans.VERSION, "plans": {
        good_key: _entry(),
        "gather_mlp|b=1,s=8": {"ts": -3, "provenance": "autotuned"},
        "unknown_kernel|b=1": _entry(),
        "hub_reuse|b=2,hn=4": {"th": 2, "provenance": "heuristic"},
    }}
    p = tmp_path / "plans.json"
    p.write_text(json.dumps(raw))
    with pytest.warns(RuntimeWarning, match="dropping entry"):
        store = plans.PlanStore.load(str(p))
    assert list(store.entries) == [good_key]      # the bad ones degraded
    assert store.lookup("gather_mlp", **GDIMS) is not None


def test_record_rejects_invalid_plans():
    store = plans.PlanStore()
    with pytest.raises(ValueError, match="refusing to record"):
        store.record("gather_mlp", GDIMS, {"ts": 0,
                                           "provenance": "autotuned"})
    with pytest.raises(ValueError, match="provenance"):
        store.record("gather_mlp", GDIMS, _entry() | {"provenance": "guess"})
    with pytest.raises(ValueError, match="unknown kernel"):
        plans.plan_key("conv2d", GDIMS)


# ---- planner resolution: hit / miss / stale / bypass ------------------------

def _plan_for(dims, **kw):
    return gather_mlp_tile_plan(dims["s"], dims["k"], dims["d"], dims["dc"],
                                dims["h"], dims["f"], b=dims["b"], **kw)


def test_store_hit_resolves_autotuned_and_miss_falls_back():
    plans.active_store().record("gather_mlp", GDIMS, _entry(ts=8, lanes=8))
    plan = _plan_for(GDIMS)
    assert plan["provenance"] == "autotuned"
    assert plan["ts"] == 8 and plan["lanes"] == 8
    # a different shape is a miss -> heuristic, silently
    miss = _plan_for(GDIMS | {"s": 32})
    assert miss["provenance"] == "heuristic"
    # an explicit override beats the store hit
    over = _plan_for(GDIMS, ts=4)
    assert over["provenance"] == "override" and over["ts"] == 4


def test_stale_entry_warns_and_falls_back():
    """An entry whose recomputed footprint busts its own recorded budget
    (e.g. the footprint model changed since it was tuned) must not be
    served: the planner warns and uses the heuristic."""
    plans.active_store().record(
        "gather_mlp", GDIMS, _entry(ts=16, lanes=128, mb=0.001))
    with pytest.warns(RuntimeWarning, match="stale tile plan"):
        plan = _plan_for(GDIMS)
    assert plan["provenance"] == "heuristic"


def test_bypass_disables_lookup_and_capture_sees_resolved_plans():
    plans.active_store().record("gather_mlp", GDIMS, _entry(ts=8, lanes=8))
    with plans.capture() as cap, plans.bypass():
        assert not plans.enabled()
        plan = _plan_for(GDIMS)
    assert plans.enabled()
    assert plan["provenance"] == "heuristic"
    assert [r["plan"]["provenance"] for r in cap] == ["heuristic"]
    assert cap[0]["kernel"] == "gather_mlp" and cap[0]["dims"] == GDIMS


# ---- autotune_cell ----------------------------------------------------------

def test_same_seed_and_budget_pick_same_winner():
    s1, s2 = plans.PlanStore(), plans.PlanStore()
    e1 = autotune.autotune_cell("gather_mlp", GDIMS, budget=10, seed=3,
                                store=s1, timer=cost_model)
    e2 = autotune.autotune_cell("gather_mlp", GDIMS, budget=10, seed=3,
                                store=s2, timer=cost_model)
    assert e1 == e2
    assert s1.entries == s2.entries
    h1 = autotune.autotune_cell("hub_reuse", HDIMS, budget=10, seed=3,
                                store=s1, timer=cost_model)
    h2 = autotune.autotune_cell("hub_reuse", HDIMS, budget=10, seed=3,
                                store=s2, timer=cost_model)
    assert h1 == h2


def test_winner_minimizes_cost_and_records_context():
    store = plans.PlanStore()
    entry = autotune.autotune_cell("gather_mlp", GDIMS, budget=32,
                                   store=store, timer=cost_model)
    cands = autotune.candidate_plans("gather_mlp", GDIMS, 32)
    best = min(cost_model(None, c) for c in cands)
    assert cost_model(None, {"tile": entry["ts"],
                             "lanes": entry["lanes"]}) == best
    assert entry["provenance"] == "autotuned"
    assert entry["heuristic_us"] == cost_model(None, cands[0])
    assert entry["vmap_us"] == 1000.0
    assert entry["searched"] == len(cands)
    assert store.lookup("gather_mlp", **GDIMS) == entry


def test_candidates_feasible_deduped_heuristic_first():
    for kernel, dims in (("gather_mlp", GDIMS), ("hub_reuse", HDIMS)):
        cands = autotune.candidate_plans(kernel, dims, 64)
        assert cands, kernel
        h = autotune._heuristic_knobs(kernel, dims)
        assert cands[0]["tile"] == h["tile"]
        assert cands[0]["lanes"] == h["lanes"]
        seen = set()
        for c in cands:
            key = (c["tile"], c["lanes"], c["dimension_semantics"])
            assert key not in seen                   # deduplicated
            seen.add(key)
            assert c["footprint_bytes"] <= int(
                c["vmem_budget_mb"] * 2 ** 20)       # feasible
        assert len(autotune.candidate_plans(kernel, dims, 3)) == 3


def test_ensure_plan_hits_do_not_retune():
    store = plans.PlanStore()
    calls = []

    def counting_timer(call, knobs):
        calls.append(knobs)
        return cost_model(call, knobs)

    e1 = autotune.ensure_plan("gather_mlp", GDIMS, store=store,
                              budget=8, timer=counting_timer)
    n_timed = len(calls)
    assert n_timed > 0
    e2 = autotune.ensure_plan("gather_mlp", GDIMS, store=store,
                              budget=8, timer=counting_timer)
    assert len(calls) == n_timed                     # hit: nothing re-timed
    assert e2 == e1


# ---- engine integration -----------------------------------------------------

def test_model_cells_match_engine_lookups():
    """Cell discovery sees exactly the planner calls engine.apply makes:
    both kernels in lpcn mode, dims carrying the batch size."""
    cells = autotune.model_cells(SPEC, 2, N, mode="lpcn")
    kernels = {k for k, _ in cells}
    assert kernels == {"gather_mlp", "hub_reuse"}
    assert all(d["b"] == 2 for _, d in cells)
    # traditional mode has no reuse stage
    kernels_trad = {k for k, _ in
                    autotune.model_cells(SPEC, 2, N, mode="traditional")}
    assert kernels_trad == {"gather_mlp"}


def test_autotuned_store_serves_engine_with_unchanged_numerics():
    """End to end: tune the model's cells (injected timer), then the
    default engine path resolves only "autotuned" plans and the logits
    match the heuristic run ≤1e-5."""
    params = engine.init(jax.random.PRNGKey(0), SPEC)
    b = _batch(SPEC, [N, 31], seed=7)
    with plans.bypass():
        base = engine.apply(params, b, spec=SPEC, mode="lpcn",
                            fc_backend="pallas")
    entries = autotune.autotune_model(SPEC, 2, N, mode="lpcn",
                                      store=plans.active_store(),
                                      budget=8, timer=cost_model)
    assert entries
    with plans.capture() as cap:
        tuned = engine.apply(params, b, spec=SPEC, mode="lpcn",
                             fc_backend="pallas")
    used = [r for r in cap if r["dims"].get("b") is not None]
    assert used and all(r["plan"]["provenance"] == "autotuned"
                        for r in used)
    np.testing.assert_allclose(np.asarray(tuned), np.asarray(base),
                               rtol=1e-5, atol=1e-5)


def test_cache_hit_adds_no_jit_compilations():
    """With autotuned plans active, one executable still serves every
    ragged mix of the same batch shape (the plan is trace-time static —
    a hit changes which plan is traced, never how many executables)."""
    autotune.autotune_model(SPEC, 2, N, mode="lpcn",
                            store=plans.active_store(), budget=8,
                            timer=cost_model)
    params = engine.init(jax.random.PRNGKey(0), SPEC)
    f = jax.jit(partial(engine.apply, spec=SPEC, mode="lpcn",
                        fc_backend="pallas"))
    o1 = f(params, _batch(SPEC, [N, 30]))
    o2 = f(params, _batch(SPEC, [17, N], seed=9))
    assert compile_cache_size(f) == 1
    assert o1.shape == o2.shape
    assert bool(jnp.isfinite(o1).all() and jnp.isfinite(o2).all())


def test_store_mutation_invalidates_kernel_traces():
    """Recording a new winner must clear the kernel jit caches: the ops
    resolve plans at trace time, so an already-compiled executable would
    otherwise keep serving the old plan."""
    from repro.kernels.gather_mlp.ops import gather_mlp_batched
    rng = np.random.default_rng(0)
    d = GDIMS
    raw = jnp.asarray(rng.normal(
        size=(d["b"], d["s"], d["k"], d["d"])), jnp.float32)
    ctr = jnp.asarray(rng.normal(
        size=(d["b"], d["s"], d["dc"])), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(d["d"], d["h"])), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(d["h"], d["f"])), jnp.float32)
    b1, b2 = jnp.zeros(d["h"]), jnp.zeros(d["f"])

    with plans.capture() as cap:
        out_h = gather_mlp_batched(raw, ctr, w1, b1, w2, b2)
    assert cap[-1]["plan"]["provenance"] == "heuristic"

    plans.active_store().record("gather_mlp", GDIMS, _entry(ts=8, lanes=8))
    with plans.capture() as cap:
        out_a = gather_mlp_batched(raw, ctr, w1, b1, w2, b2)
    # a fresh trace happened (capture saw it) and resolved the new plan
    assert cap and cap[-1]["plan"]["provenance"] == "autotuned"
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_h),
                               rtol=1e-5, atol=1e-5)


def test_promoted_plans_pass_kernel_lint():
    """Every entry the tuner records passes K001–K005 at its own budget
    (what scripts/ci.sh re-checks on the persisted store)."""
    store = plans.PlanStore()
    for kernel, dims in (("gather_mlp", GDIMS), ("hub_reuse", HDIMS)):
        entry = autotune.autotune_cell(kernel, dims, budget=10,
                                       store=store, timer=cost_model)
        knobs = {"tile": entry[plans.TILE_FIELD[kernel]],
                 "lanes": entry["lanes"],
                 "vmem_budget_mb": entry["vmem_budget_mb"],
                 "dimension_semantics": tuple(entry["dimension_semantics"]),
                 "footprint_bytes": entry["footprint_bytes"]}
        assert autotune.lint_knobs(kernel, dims, knobs) == []


# ---- vmap plan variant ------------------------------------------------------

def vmap_wins_model(call, knobs):
    """Injected timer for the small-island regime: the per-cloud vmap
    dispatch (knobs=None) beats every batched-grid candidate."""
    return 10.0 if knobs is None else cost_model(call, knobs)


def test_vmap_variant_promoted_when_grid_loses():
    """When the vmap baseline out-measures every grid finalist the cell
    records a {"variant": "vmap"} entry instead of the losing grid."""
    store = plans.PlanStore()
    entry = autotune.autotune_cell("hub_reuse", HDIMS, budget=10,
                                   store=store, timer=vmap_wins_model)
    assert entry["variant"] == "vmap"
    assert entry["provenance"] == "autotuned"
    assert entry["measured_us"] == 10.0
    assert entry["grid_us"] > entry["measured_us"]
    assert plans.entry_error("hub_reuse", entry) is None
    assert store.lookup("hub_reuse", **HDIMS) == entry
    # deterministic: same seed/budget re-promotes the same entry
    e2 = autotune.autotune_cell("hub_reuse", HDIMS, budget=10,
                                store=plans.PlanStore(),
                                timer=vmap_wins_model)
    assert e2 == entry


def test_vmap_variant_round_trips_and_validates(tmp_path):
    store = plans.PlanStore()
    store.record("hub_reuse", HDIMS, {"variant": "vmap",
                                      "provenance": "autotuned",
                                      "measured_us": 5.0})
    store.record("gather_mlp", GDIMS, {"variant": "vmap", "ts": 8,
                                       "provenance": "autotuned"})
    path = store.save(str(tmp_path / "plans.json"))
    loaded = plans.PlanStore.load(path)
    assert loaded.entries == store.entries
    with pytest.raises(ValueError, match="refusing to record"):
        store.record("hub_reuse", HDIMS, {"variant": "grid9",
                                          "provenance": "autotuned"})
    with pytest.raises(ValueError, match="refusing to record"):
        store.record("gather_mlp", GDIMS, {"variant": "vmap", "ts": 0,
                                           "provenance": "autotuned"})
    with pytest.raises(ValueError, match="refusing to record"):
        store.record("hub_reuse", HDIMS, {"variant": "vmap",
                                          "provenance": "heuristic"})


def test_vmap_variant_dispatches_per_cloud_with_unchanged_numerics():
    """A stored vmap entry reroutes the batched op through jax.vmap of
    the per-cloud kernel: capture observes the variant plan and the
    output matches the batched grid <=1e-5."""
    from repro.kernels.hub_reuse.ops import hub_reuse_batched
    rng = np.random.default_rng(0)
    d = HDIMS
    pool = jnp.asarray(rng.normal(
        size=(d["b"], d["hn"], d["c"], d["d"])), jnp.float32)
    slot = jnp.asarray(rng.integers(
        -1, d["c"], (d["b"], d["hn"], d["m"], d["k"])), jnp.int32)
    comp = jnp.asarray(rng.normal(
        size=(d["b"], d["hn"], d["m"], d["f"])) * 0.01, jnp.float32)
    live = jnp.asarray(rng.integers(
        0, 2, (d["b"], d["hn"], d["m"], d["k"])), jnp.int32)
    w1 = jnp.asarray(rng.normal(size=(d["d"], d["h"])) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(d["h"], d["f"])) * 0.1, jnp.float32)
    b1, b2 = jnp.zeros(d["h"]), jnp.zeros(d["f"])

    with plans.capture() as cap:
        out_grid = hub_reuse_batched(pool, slot, comp, w1, b1, w2, b2,
                                     live=live)
    assert cap[-1]["plan"]["provenance"] == "heuristic"
    assert "variant" not in cap[-1]["plan"]

    plans.active_store().record("hub_reuse", HDIMS,
                                {"variant": "vmap",
                                 "provenance": "autotuned"})
    with plans.capture() as cap:
        out_vmap = hub_reuse_batched(pool, slot, comp, w1, b1, w2, b2,
                                     live=live)
    plan = cap[-1]["plan"]
    assert plan["variant"] == "vmap"
    assert plan["provenance"] == "autotuned"
    assert plan["grid_tiles"] == d["hn"]          # one island per step
    np.testing.assert_allclose(np.asarray(out_vmap), np.asarray(out_grid),
                               rtol=1e-5, atol=1e-5)


def test_vmap_variant_serves_engine_with_unchanged_numerics():
    """End to end at a cell set where vmap wins everywhere: the default
    engine path resolves only variant plans and the logits match the
    heuristic run <=1e-5."""
    params = engine.init(jax.random.PRNGKey(0), SPEC)
    b = _batch(SPEC, [N, 31], seed=7)
    with plans.bypass():
        base = engine.apply(params, b, spec=SPEC, mode="lpcn",
                            fc_backend="pallas")
    entries = autotune.autotune_model(SPEC, 2, N, mode="lpcn",
                                      store=plans.active_store(),
                                      budget=8, timer=vmap_wins_model)
    assert entries and all(e.get("variant") == "vmap" for e in entries)
    with plans.capture() as cap:
        tuned = engine.apply(params, b, spec=SPEC, mode="lpcn",
                             fc_backend="pallas")
    used = [r for r in cap if r["dims"].get("b") is not None]
    assert used and all(r["plan"].get("variant") == "vmap" for r in used)
    np.testing.assert_allclose(np.asarray(tuned), np.asarray(base),
                               rtol=1e-5, atol=1e-5)
