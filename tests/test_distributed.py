"""Distributed tests — run in a subprocess so the forced device count
doesn't leak into the other tests (jax locks devices at first init)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

pytest.importorskip(
    "repro.dist", reason="repro.dist (sharding subsystem) not present")


def _run(code: str, n_dev: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_dev} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_lowers_and_runs():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.lm import model_zoo as zoo, steps
from repro.optim import adamw

cfg = get_config("olmo-1b", reduced=True)
mesh = make_mesh((2, 4), ("data", "model"))
with shd.use_mesh(mesh):
    key = jax.random.PRNGKey(0)
    params = zoo.init(key, cfg)
    p_sh = shd.param_shardings(params, mesh, cfg.moe_shard)
    opt_cfg = adamw.AdamWConfig(state_dtype="float32")
    opt = adamw.init_state(opt_cfg, params)
    o_sh = shd.param_shardings(opt, mesh, cfg.moe_shard)
    params = jax.tree.map(jax.device_put, params, p_sh)
    opt = jax.tree.map(jax.device_put, opt, o_sh)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (4, 65)),
        jnp.int32)}
    ts = steps.make_train_step(cfg, opt_cfg, microbatches=2,
                               param_shardings=p_sh)
    f = jax.jit(ts, in_shardings=(p_sh, o_sh,
                                  shd.batch_shardings(batch, mesh), None),
                donate_argnums=(0, 1))
    params, opt, m = f(params, opt, batch, jnp.int32(0))
    assert bool(jnp.isfinite(m["loss"])), m
    print("loss", float(m["loss"]))
""")


def test_single_vs_sharded_loss_equal():
    """The sharded computation must equal the single-device result."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.lm import model_zoo as zoo

cfg = get_config("qwen2-72b", reduced=True)
key = jax.random.PRNGKey(0)
params = zoo.init(key, cfg)
batch = {"tokens": jnp.asarray(
    np.random.default_rng(0).integers(0, cfg.vocab, (4, 33)), jnp.int32)}
l0, _ = zoo.loss_fn(cfg, params, batch)          # unsharded

mesh = make_mesh((2, 4), ("data", "model"))
with shd.use_mesh(mesh):
    p_sh = shd.param_shardings(params, mesh, cfg.moe_shard)
    params_s = jax.tree.map(jax.device_put, params, p_sh)
    f = jax.jit(lambda p, b: zoo.loss_fn(cfg, p, b)[0],
                in_shardings=(p_sh, shd.batch_shardings(batch, mesh)))
    l1 = f(params_s, batch)
print(float(l0), float(l1))
assert abs(float(l0) - float(l1)) < 5e-2, (float(l0), float(l1))
""")


def test_moe_ep_sharding_lowers():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.lm import model_zoo as zoo

cfg = get_config("llama4-maverick-400b-a17b", reduced=True)
mesh = make_mesh((2, 4), ("data", "model"))
with shd.use_mesh(mesh):
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda k: zoo.init(k, cfg), key)
    p_sh = shd.param_shardings(params, mesh, cfg.moe_shard)
    batch = zoo.input_specs(cfg, 64, 4, "train")
    f = jax.jit(lambda p, b: zoo.loss_fn(cfg, p, b)[0],
                in_shardings=(p_sh, shd.batch_shardings(batch, mesh)))
    c = f.lower(params, batch).compile()
    assert "all-to-all" in c.as_text() or "all-reduce" in c.as_text()
    print("ok")
""")


def test_pipeline_parallel_matches_sequential():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.pipeline import pipeline_apply
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("stage",))
n_stage, n_micro, mb, d = 4, 8, 2, 16
rng = np.random.default_rng(0)
Ws = jnp.asarray(rng.normal(size=(n_stage, d, d)) * 0.2, jnp.float32)

def layer_fn(w, x):
    return jnp.tanh(x @ w)

x = jnp.asarray(rng.normal(size=(n_micro, mb, d)), jnp.float32)
y = pipeline_apply(mesh, "stage", n_micro, layer_fn, Ws, x)
# sequential reference
ref = x
for s in range(n_stage):
    ref = jax.vmap(lambda xx: layer_fn(Ws[s], xx))(ref)
err = float(jnp.abs(y - ref).max())
print("err", err)
assert err < 1e-5, err
""")


def test_multipod_mesh_builds():
    _run("""
from repro.launch.mesh import make_production_mesh
m = make_production_mesh(multi_pod=True)
assert m.shape == {"pod": 2, "data": 16, "model": 16}
m1 = make_production_mesh()
assert m1.shape == {"data": 16, "model": 16}
print("ok")
""", n_dev=512)
