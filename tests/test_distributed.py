"""Distributed tests — run in a subprocess so the forced device count
doesn't leak into the other tests (jax locks devices at first init)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

pytest.importorskip(
    "repro.dist", reason="repro.dist (sharding subsystem) not present")


def _run(code: str, n_dev: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_dev} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_lowers_and_runs():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.lm import model_zoo as zoo, steps
from repro.optim import adamw

cfg = get_config("olmo-1b", reduced=True)
mesh = make_mesh((2, 4), ("data", "model"))
with shd.use_mesh(mesh):
    key = jax.random.PRNGKey(0)
    params = zoo.init(key, cfg)
    p_sh = shd.param_shardings(params, mesh, cfg.moe_shard)
    opt_cfg = adamw.AdamWConfig(state_dtype="float32")
    opt = adamw.init_state(opt_cfg, params)
    o_sh = shd.param_shardings(opt, mesh, cfg.moe_shard)
    params = jax.tree.map(jax.device_put, params, p_sh)
    opt = jax.tree.map(jax.device_put, opt, o_sh)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (4, 65)),
        jnp.int32)}
    ts = steps.make_train_step(cfg, opt_cfg, microbatches=2,
                               param_shardings=p_sh)
    f = jax.jit(ts, in_shardings=(p_sh, o_sh,
                                  shd.batch_shardings(batch, mesh), None),
                donate_argnums=(0, 1))
    params, opt, m = f(params, opt, batch, jnp.int32(0))
    assert bool(jnp.isfinite(m["loss"])), m
    print("loss", float(m["loss"]))
""")


def test_single_vs_sharded_loss_equal():
    """The sharded computation must equal the single-device result."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.lm import model_zoo as zoo

cfg = get_config("qwen2-72b", reduced=True)
key = jax.random.PRNGKey(0)
params = zoo.init(key, cfg)
batch = {"tokens": jnp.asarray(
    np.random.default_rng(0).integers(0, cfg.vocab, (4, 33)), jnp.int32)}
l0, _ = zoo.loss_fn(cfg, params, batch)          # unsharded

mesh = make_mesh((2, 4), ("data", "model"))
with shd.use_mesh(mesh):
    p_sh = shd.param_shardings(params, mesh, cfg.moe_shard)
    params_s = jax.tree.map(jax.device_put, params, p_sh)
    f = jax.jit(lambda p, b: zoo.loss_fn(cfg, p, b)[0],
                in_shardings=(p_sh, shd.batch_shardings(batch, mesh)))
    l1 = f(params_s, batch)
print(float(l0), float(l1))
assert abs(float(l0) - float(l1)) < 5e-2, (float(l0), float(l1))
""")


def test_moe_ep_sharding_lowers():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.lm import model_zoo as zoo

cfg = get_config("llama4-maverick-400b-a17b", reduced=True)
mesh = make_mesh((2, 4), ("data", "model"))
with shd.use_mesh(mesh):
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda k: zoo.init(k, cfg), key)
    p_sh = shd.param_shardings(params, mesh, cfg.moe_shard)
    batch = zoo.input_specs(cfg, 64, 4, "train")
    f = jax.jit(lambda p, b: zoo.loss_fn(cfg, p, b)[0],
                in_shardings=(p_sh, shd.batch_shardings(batch, mesh)))
    c = f.lower(params, batch).compile()
    assert "all-to-all" in c.as_text() or "all-reduce" in c.as_text()
    print("ok")
""")


def test_pipeline_parallel_matches_sequential():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.pipeline import pipeline_apply
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("stage",))
n_stage, n_micro, mb, d = 4, 8, 2, 16
rng = np.random.default_rng(0)
Ws = jnp.asarray(rng.normal(size=(n_stage, d, d)) * 0.2, jnp.float32)

def layer_fn(w, x):
    return jnp.tanh(x @ w)

x = jnp.asarray(rng.normal(size=(n_micro, mb, d)), jnp.float32)
y = pipeline_apply(mesh, "stage", n_micro, layer_fn, Ws, x)
# sequential reference
ref = x
for s in range(n_stage):
    ref = jax.vmap(lambda xx: layer_fn(Ws[s], xx))(ref)
err = float(jnp.abs(y - ref).max())
print("err", err)
assert err < 1e-5, err
""")


def test_multipod_mesh_builds():
    _run("""
from repro.launch.mesh import make_production_mesh
m = make_production_mesh(multi_pod=True)
assert m.shape == {"pod": 2, "data": 16, "model": 16}
m1 = make_production_mesh()
assert m1.shape == {"data": 16, "model": 16}
print("ok")
""", n_dev=512)


def test_pcn_engine_sharded_equals_single_device():
    """The PCN sharded serving path: engine.apply under a forced 8-device
    ("data", "model") mesh == the single-device result (<= 1e-5) for two
    arch families x {traditional, lpcn} x a ragged n_valid mix — the
    PR-2 padding-equivalence oracle, now across devices."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from dataclasses import replace
from repro import engine
from repro.data.synthetic import make_cloud
from repro.engine import Batch, BlockSpec
from repro.launch.mesh import make_mesh
from repro.models import dgcnn, pointnet2

assert len(jax.devices()) == 8
mesh = make_mesh((4, 2), ("data", "model"))
N = 96
specs = {
    "pointnet2_c": replace(pointnet2.POINTNET2_C, blocks=(
        BlockSpec(32, 8, (16, 32)), BlockSpec(16, 8, (32, 48)))),
    "dgcnn_c": replace(dgcnn.with_points(dgcnn.DGCNN_C, N), blocks=(
        BlockSpec(N, 8, (24,), kind="edge", sampler="all"),
        BlockSpec(N, 8, (32,), kind="edge", sampler="all"))),
}
rng = np.random.default_rng(0)
nv = jnp.asarray([96, 70, 50, 96, 33, 80, 60, 90], jnp.int32)
for name, spec in specs.items():
    params = engine.init(jax.random.PRNGKey(0), spec)
    xyz = jnp.asarray(np.stack([make_cloud(rng, N) for _ in range(8)]))
    batch = Batch.make(xyz, key=jax.random.PRNGKey(1), n_valid=nv)
    # pallas (interpret mode on CPU) must also survive the mesh split:
    # the batched (B, ...) kernel grids are what actually shard
    backends = ("reference", "pallas") if name == "pointnet2_c" \
        else ("reference",)
    for mode in ("traditional", "lpcn"):
        for be in backends:
            ref = engine.apply(params, batch, spec=spec, mode=mode,
                               fc_backend=be)
            sh = engine.apply(params, batch, spec=spec, mode=mode,
                              fc_backend=be, mesh=mesh)
            assert "data" in str(getattr(sh, "sharding", "")), sh.sharding
            np.testing.assert_allclose(np.asarray(sh), np.asarray(ref),
                                       rtol=1e-5, atol=1e-5)
            print(name, mode, be, "ok")
print("ok")
""")


def test_pcn_engine_sharded_compile_once():
    """One sharded executable serves every ragged mix of the same shape:
    differing n_valid values (traced, not static) must not retrace."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from dataclasses import replace
from repro import engine
from repro.data.synthetic import make_cloud
from repro.engine import Batch, BlockSpec
from repro.launch.mesh import make_mesh
from repro.models import pointnet2

mesh = make_mesh((4, 2), ("data", "model"))
spec = replace(pointnet2.POINTNET2_C, blocks=(
    BlockSpec(32, 8, (16, 32)), BlockSpec(16, 8, (32, 48))))
eng = engine.PCNEngine(spec, mode="lpcn", mesh=mesh)
params = eng.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
xyz = jnp.asarray(np.stack([make_cloud(rng, 96) for _ in range(8)]))
for nv in ([96] * 8, [96, 70, 50, 96, 33, 80, 60, 90],
           [40, 96, 96, 55, 96, 61, 72, 96]):
    out = eng.apply(params, Batch.make(
        xyz, key=jax.random.PRNGKey(1),
        n_valid=jnp.asarray(nv, jnp.int32)))
    assert bool(jnp.isfinite(out).all())
assert eng._japply._cache_size() == 1, eng._japply._cache_size()
print("ok")
""")


def test_fit_spec_divisibility_multiway():
    """fit_spec on a >1-sized axis: a non-dividing dim is dropped
    (replicated), never left for GSPMD to pad (the 1-way case lives in
    tests/test_substrate.py; this needs real 4-way meshes)."""
    _run("""
from jax.sharding import PartitionSpec as P
from repro.dist.sharding import fit_spec
from repro.launch.mesh import make_mesh

mesh4 = make_mesh((4,), ("model",))
# 16 divides 4 -> kept; 50281 does not -> dropped, not padded
assert fit_spec(P(None, "model"), (50281, 16), mesh4) == P(None, "model")
assert fit_spec(P("model", None), (50281, 16), mesh4) == P(None, None)
# tuple entries use the axis-product (4*2=8): 48 divides, 50 does not
mesh42 = make_mesh((4, 2), ("data", "model"))
assert fit_spec(P(("data", "model"), None), (48, 50), mesh42) \
    == P(("data", "model"), None)
assert fit_spec(P(None, ("data", "model")), (48, 50), mesh42) \
    == P(None, None)
print("ok")
""", n_dev=8)
