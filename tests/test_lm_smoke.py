"""Per-arch smoke: reduced config, one forward/train step + one decode
step on CPU, asserting shapes and finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "repro.dist", reason="repro.dist (sharding subsystem) not present")

from repro.configs import ARCH_IDS, get_config
from repro.lm import model_zoo as zoo
from repro.lm import steps as steps_mod
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def _batch(cfg, rng):
    b = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)}
    if cfg.family == "vlm":
        b["patches"] = 0.02 * jnp.ones(
            (B, cfg.prefix_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        b["frames"] = 0.02 * jnp.ones(
            (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = get_config(arch, reduced=True)
    rng = np.random.default_rng(0)
    params = zoo.init(KEY, cfg)
    loss, aux = zoo.loss_fn(cfg, params, _batch(cfg, rng))
    assert bool(jnp.isfinite(loss)), arch
    assert float(loss) < 2.5 * np.log(cfg.vocab), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    rng = np.random.default_rng(1)
    params = zoo.init(KEY, cfg)
    batch = _batch(cfg, rng)
    cache = zoo.make_cache(cfg, params, B, 32,
                           frames=batch.get("frames"))
    tok = batch["tokens"][:, 0]
    for pos in range(3):
        logits, cache = zoo.decode_fn(cfg, params, tok, cache,
                                      jnp.int32(pos))
        assert logits.shape == (B, cfg.vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["olmo-1b", "mamba2-2.7b",
                                  "recurrentgemma-2b", "grok-1-314b"])
def test_one_train_step_reduces_nothing_nan(arch):
    cfg = get_config(arch, reduced=True)
    rng = np.random.default_rng(2)
    params = zoo.init(KEY, cfg)
    opt_cfg = adamw.AdamWConfig(state_dtype="float32")
    opt = adamw.init_state(opt_cfg, params)
    step = steps_mod.make_train_step(cfg, opt_cfg, microbatches=2)
    params, opt, m = step(params, opt, _batch(cfg, rng), jnp.int32(0))
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_norm"]))
    for leaf in jax.tree.leaves(params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all()), arch


def test_decode_matches_forward_olmo():
    """Teacher-forced decode logits == full forward logits (cache
    correctness)."""
    cfg = get_config("olmo-1b", reduced=True)
    rng = np.random.default_rng(3)
    params = zoo.init(KEY, cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, 9)), jnp.int32)
    from repro.lm import transformer as tfm
    logits_full, _ = tfm.forward(cfg, params, tokens=toks[:, :-1])
    cache = zoo.make_cache(cfg, params, B, 16)
    outs = []
    for pos in range(8):
        lg, cache = zoo.decode_fn(cfg, params, toks[:, pos], cache,
                                  jnp.int32(pos))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32),
        np.asarray(logits_full, np.float32), rtol=2e-2, atol=2e-2)


def test_kv_quant_decode_close():
    """int8 KV cache (beyond-paper decode lever): logits within ~2% of
    the bf16 cache path."""
    import dataclasses
    cfg = get_config("olmo-1b", reduced=True)
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    rng = np.random.default_rng(5)
    params = zoo.init(KEY, cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, 9)), jnp.int32)

    def run(c):
        cache = zoo.make_cache(c, params, B, 16)
        outs = []
        for pos in range(8):
            lg, cache = zoo.decode_fn(c, params, toks[:, pos], cache,
                                      jnp.int32(pos))
            outs.append(lg)
        return jnp.stack(outs, 1)

    a, bq = run(cfg), run(cfg_q)
    rel = float(jnp.abs(a - bq).mean() / (jnp.abs(a).mean() + 1e-9))
    assert rel < 0.05, rel
