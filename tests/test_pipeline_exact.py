"""L-PCN FC vs traditional FC: exactness (block_end + linear comp, paper
§VI-E) and bounded approximation (per_layer)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LPCNConfig, init_mlp, lpcn_block
from repro.core.workload import analyze
from repro.data.synthetic import make_cloud

KEY = jax.random.PRNGKey(0)


def _cloud(n=512, seed=0):
    rng = np.random.default_rng(seed)
    xyz = jnp.asarray(make_cloud(rng, n))
    return xyz, xyz


@pytest.mark.parametrize("kind,dims,sampler,k", [
    ("sa", [6, 32, 64], "fps", 16),
    ("edge", [6, 48], "all", 12),
])
def test_exact_when_block_end_linear(kind, dims, sampler, k):
    xyz, feats = _cloud()
    n_centers = 256 if sampler == "fps" else xyz.shape[0]
    mlp = init_mlp(KEY, dims, activation="block_end")
    c_l = LPCNConfig(n_centers=n_centers, k=k, sampler=sampler,
                     block_kind=kind, mode="lpcn", compensation="linear")
    c_t = LPCNConfig(n_centers=n_centers, k=k, sampler=sampler,
                     block_kind=kind, mode="traditional")
    o_l = lpcn_block(c_l, mlp, xyz, feats, KEY)
    o_t = lpcn_block(c_t, mlp, xyz, feats, KEY)
    np.testing.assert_allclose(np.asarray(o_l.features),
                               np.asarray(o_t.features),
                               rtol=1e-4, atol=1e-4)


def test_approx_bounded_when_per_layer():
    xyz, feats = _cloud(seed=1)
    mlp = init_mlp(KEY, [6, 32, 64], activation="per_layer")
    c_l = LPCNConfig(n_centers=256, k=16, mode="lpcn",
                     compensation="linear")
    c_t = LPCNConfig(n_centers=256, k=16, mode="traditional")
    o_l = lpcn_block(c_l, mlp, xyz, feats, KEY)
    o_t = lpcn_block(c_t, mlp, xyz, feats, KEY)
    ref = np.abs(np.asarray(o_t.features)).mean()
    err = np.abs(np.asarray(o_l.features)
                 - np.asarray(o_t.features)).mean()
    assert err / ref < 0.5   # approximation, but not garbage


def test_mlp_compensation_mode_runs():
    xyz, feats = _cloud(seed=2)
    mlp = init_mlp(KEY, [6, 32, 64], activation="per_layer")
    c_m = LPCNConfig(n_centers=128, k=16, mode="lpcn",
                     compensation="mlp")
    o = lpcn_block(c_m, mlp, xyz, feats, KEY)
    assert o.features.shape == (128, 64)
    assert bool(jnp.isfinite(o.features).all())


def test_workload_report_bounds():
    xyz, feats = _cloud(seed=3)
    mlp = init_mlp(KEY, [6, 32, 64], activation="block_end")
    cfg = LPCNConfig(n_centers=256, k=16, mode="lpcn")
    o = lpcn_block(cfg, mlp, xyz, feats, KEY, with_report=True)
    r = o.report.concrete()
    assert 0 < r.lpcn_fetches <= r.baseline_fetches
    # delta-comp overhead adds at most one eval per subset
    assert r.lpcn_mlp_evals <= r.baseline_mlp_evals + r.n_subsets
    assert 0.0 <= r.fetch_saving < 1.0


def test_mesorasi_exact_for_linear_mlp():
    from repro.core.pipeline import data_structuring, fc_traditional
    from repro.models.baselines import mesorasi_fc
    xyz, feats = _cloud(seed=4)
    mlp = init_mlp(KEY, [6, 64], activation="block_end")
    cfg = LPCNConfig(n_centers=128, k=16)
    cidx, nbr = data_structuring(cfg, xyz, KEY)
    t = fc_traditional(mlp, xyz, feats, nbr, xyz[cidx], feats[cidx], "sa")
    m = mesorasi_fc(mlp, xyz, feats, nbr, xyz[cidx], feats[cidx], "sa")
    np.testing.assert_allclose(np.asarray(t), np.asarray(m),
                               rtol=1e-4, atol=1e-4)
