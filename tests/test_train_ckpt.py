"""Fault-tolerance integration: the training driver saves atomically and
resumes bit-exactly (same losses as an uninterrupted run)."""
import os
import shutil

import pytest

pytest.importorskip(
    "repro.dist", reason="repro.dist (sharding subsystem) not present")

from repro.launch import train as train_mod


@pytest.fixture()
def ckpt_dir(tmp_path):
    d = str(tmp_path / "ckpt")
    yield d
    shutil.rmtree(d, ignore_errors=True)


def test_resume_bit_exact(ckpt_dir):
    argv_base = ["--arch", "olmo-1b", "--reduced", "--batch", "2",
                 "--seq", "32", "--ckpt-every", "2"]
    # uninterrupted reference
    ref = train_mod.main(argv_base + ["--steps", "6",
                                      "--ckpt", ckpt_dir + "_ref"])
    # interrupted at step 3, then resumed
    part1 = train_mod.main(argv_base + ["--steps", "3",
                                        "--ckpt", ckpt_dir])
    part2 = train_mod.main(argv_base + ["--steps", "6",
                                        "--ckpt", ckpt_dir])
    assert len(part1) == 3
    # resumed run starts at step 3 (2 ckpt-every -> saved at 2? final save
    # at step 3 exists because steps==3 triggers the final save)
    combined = part1 + part2
    assert len(combined) == 6
    for a, b in zip(ref, combined):
        assert abs(a - b) < 1e-6, (ref, combined)


def test_elastic_restore_reshard(tmp_path):
    """Checkpoint written under one sharding restores under another
    (device-count-independent layout)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.ckpt.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path))
    params = {"w": jnp.asarray(np.arange(64, dtype=np.float32
                                         ).reshape(8, 8))}
    opt = {"m": {"w": jnp.zeros((8, 8))}}
    mgr.save(1, params, opt, {"seed": 0, "step": 1})
    # restore with explicit (trivial) shardings for the current devices
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    shardings = {"params": {"w": sh}, "opt": {"m": {"w": sh}}}
    step, p2, o2, _ = mgr.restore(params, opt, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(p2["w"]),
                                  np.asarray(params["w"]))


def test_train_compress_runs():
    """--compress wires dist.compress.make_compressor into the train
    step: the error feedback is seeded into opt_state['ef'] before jit
    and survives adamw.apply_updates across steps."""
    import numpy as np
    losses = train_mod.main(["--arch", "olmo-1b", "--reduced", "--batch",
                             "2", "--seq", "32", "--steps", "3",
                             "--compress", "int8"])
    assert len(losses) == 3 and np.all(np.isfinite(losses)), losses
