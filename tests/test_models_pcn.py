"""PCN model smoke tests (reduced clouds) + workload reports, through
the engine API (the PR-1 ``models.*.init``/``apply`` shims are gone)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.data.synthetic import make_cloud
from repro.models import dgcnn, pointnet2, pointnext, pointvector

KEY = jax.random.PRNGKey(0)


def _cloud(n, f=3, seed=0):
    rng = np.random.default_rng(seed)
    xyz = jnp.asarray(make_cloud(rng, n))
    if f > 3:
        feats = jnp.concatenate(
            [xyz, jnp.asarray(rng.uniform(0, 1, (n, f - 3)),
                              jnp.float32)], -1)
    else:
        feats = xyz
    return xyz, feats


def test_pointnet2_cls():
    xyz, feats = _cloud(512)
    from dataclasses import replace
    from repro.models.common import BlockSpec
    spec = replace(pointnet2.POINTNET2_C, blocks=(
        BlockSpec(128, 16, (32, 64)), BlockSpec(32, 16, (64, 128))))
    p = engine.init(KEY, spec)
    logits, rep = engine.apply_single(p, xyz, feats, KEY, spec=spec,
                                      mode="lpcn", with_report=True)
    assert logits.shape == (40,)
    assert bool(jnp.isfinite(logits).all())
    assert rep.concrete().fetch_saving > 0


def test_pointnet2_seg():
    xyz, feats = _cloud(512, f=6, seed=1)
    from dataclasses import replace
    from repro.models.common import BlockSpec
    spec = replace(pointnet2.POINTNET2_S, blocks=(
        BlockSpec(128, 16, (32, 64)), BlockSpec(32, 16, (64, 128))))
    p = engine.init(KEY, spec)
    logits, _ = engine.apply_single(p, xyz, feats, KEY, spec=spec,
                                    mode="traditional")
    assert logits.shape == (512, 13)
    assert bool(jnp.isfinite(logits).all())


def test_dgcnn_cls_exact_reuse():
    """DGCNN(c) uses block_end activation -> islandized output must match
    traditional (paper §VI-E)."""
    xyz, feats = _cloud(256, seed=2)
    spec = dgcnn.with_points(dgcnn.DGCNN_C, 256)
    p = engine.init(KEY, spec)
    l1, _ = engine.apply_single(p, xyz, feats, KEY, spec=spec, mode="lpcn")
    l0, _ = engine.apply_single(p, xyz, feats, KEY, spec=spec,
                                mode="traditional")
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l0),
                               rtol=2e-3, atol=2e-3)


def test_pointnext():
    xyz, feats = _cloud(512, f=6, seed=3)
    from dataclasses import replace
    from repro.models.common import BlockSpec
    spec = replace(pointnext.POINTNEXT_S, blocks=(
        BlockSpec(128, 16, (32,)), BlockSpec(32, 16, (64,))))
    p = engine.init(KEY, spec)
    logits, rep = engine.apply_single(p, xyz, feats, KEY, spec=spec,
                                      mode="lpcn", with_report=True)
    assert logits.shape == (512, 13)
    assert bool(jnp.isfinite(logits).all())


def test_pointvector():
    xyz, feats = _cloud(512, f=6, seed=4)
    from dataclasses import replace
    from repro.models.common import BlockSpec
    spec = replace(pointvector.POINTVECTOR_L, blocks=(
        BlockSpec(128, 16, (48,)), BlockSpec(32, 16, (96,))))
    p = engine.init(KEY, spec)
    logits, _ = engine.apply_single(p, xyz, feats, KEY, spec=spec,
                                    mode="lpcn")
    assert logits.shape == (512, 13)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("method", ["pointacc", "hgpcn", "edgepc",
                                    "crescent"])
def test_ds_method_recall(method):
    """Approximate DS methods must overlap heavily with exact KNN."""
    from repro.core.pipeline import LPCNConfig, data_structuring
    xyz, _ = _cloud(512, seed=5)
    cfg = LPCNConfig(n_centers=64, k=8, neighbor=method)
    cidx, nbr = data_structuring(cfg, xyz, KEY)
    cfg0 = LPCNConfig(n_centers=64, k=8, neighbor="pointacc")
    _, nbr0 = data_structuring(cfg0, xyz, KEY)
    recall = np.mean([
        len(set(a.tolist()) & set(b.tolist())) / 8
        for a, b in zip(np.asarray(nbr), np.asarray(nbr0))])
    assert recall > (0.99 if method in ("pointacc", "hgpcn") else 0.5)
