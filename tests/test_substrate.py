"""Substrate tests: optimizer, data pipeline, checkpoint roundtrip,
gradient compression, losses, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "repro.dist", reason="repro.dist (sharding subsystem) not present")

from repro.data.loader import TokenStream
from repro.dist import compress
from repro.dist.sharding import fit_spec, param_spec
from repro.lm.losses import cross_entropy
from repro.optim import adafactor, adamw


def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0,
                            state_dtype="float32")
    params = {"w": jnp.asarray([2.0, -3.0])}
    state = adamw.init_state(cfg, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw.apply_updates(cfg, params, g, state)
    assert float(loss(params)) < 1e-3


def test_adafactor_converges_quadratic():
    cfg = adafactor.AdafactorConfig(lr=0.1)
    params = {"w": jnp.ones((4, 3)) * 2.0}
    state = adafactor.init_state(cfg, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = adafactor.apply_updates(cfg, params, g, state)
    assert float(loss(params)) < 1e-2


def test_data_stream_deterministic_resume():
    kw = dict(vocab=100, batch=4, seq_len=16, seed=7)
    s1 = TokenStream(**kw)
    batches = [s1.next() for _ in range(5)]
    s2 = TokenStream.from_state({"seed": 7, "step": 3}, **{
        k: v for k, v in kw.items() if k != "seed"})
    np.testing.assert_array_equal(s2.next(), batches[3])
    np.testing.assert_array_equal(s2.next(), batches[4])


def test_data_stream_host_sharding():
    kw = dict(vocab=100, batch=8, seq_len=16, seed=1)
    full = TokenStream(**kw).next()
    h0 = TokenStream(host_index=0, host_count=2, **kw).next()
    h1 = TokenStream(host_index=1, host_count=2, **kw).next()
    np.testing.assert_array_equal(np.concatenate([h0, h1]), full)


def test_ckpt_roundtrip(tmp_path):
    from repro.ckpt.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=2)
    params = {"a": jnp.ones((4, 4), jnp.bfloat16),
              "b": [jnp.zeros((3,), jnp.float32)]}
    opt = {"m": {"a": jnp.full((4, 4), 0.5, jnp.bfloat16),
                 "b": [jnp.ones((3,))]},
           "step": jnp.int32(5)}
    mgr.save(5, params, opt, {"seed": 1, "step": 5})
    step, p2, o2, ds = mgr.restore(params, opt)
    assert step == 5 and ds == {"seed": 1, "step": 5}
    np.testing.assert_array_equal(np.asarray(p2["a"], np.float32),
                                  np.ones((4, 4), np.float32))
    assert int(o2["step"]) == 5


def test_ckpt_gc_keeps_latest(tmp_path):
    from repro.ckpt.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=2)
    p = {"a": jnp.ones((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, p, {"m": p}, {})
    assert mgr.latest_step() == 4
    dirs = sorted(os.listdir(tmp_path))
    assert len([d for d in dirs if d.startswith("step_")]) == 2


def test_uncommitted_checkpoint_ignored(tmp_path):
    from repro.ckpt.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path))
    p = {"a": jnp.ones((2,))}
    mgr.save(1, p, {"m": p}, {})
    # simulate a torn save at step 2
    os.makedirs(tmp_path / "step_000000002")
    assert mgr.latest_step() == 1


def test_grad_compression_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                          jnp.float32)}
    ef = compress.init_error_feedback(g)
    total = jnp.zeros((64,))
    for _ in range(50):
        dg, ef = compress.compress_grads(g, ef)
        total = total + dg["w"]
    # error feedback keeps long-run average unbiased
    np.testing.assert_allclose(np.asarray(total / 50),
                               np.asarray(g["w"]), atol=0.02)


def test_cross_entropy_matches_manual():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 7)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 7, (4,)), jnp.int32)
    got = cross_entropy(logits, labels)
    p = jax.nn.log_softmax(logits)
    want = -jnp.mean(p[jnp.arange(4), labels])
    np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


def test_param_spec_rules():
    class L:  # fake leaf
        def __init__(self, nd):
            self.ndim = nd
    assert param_spec("layers/0/mixer/wq", L(2)) == ("fsdp", "tp")
    assert param_spec("layers/0/mixer/wo", L(2)) == ("tp", "fsdp")
    assert param_spec("layers/1/ffn/w_in", L(3), "ep") \
        == ("tp", "fsdp", None)
    assert param_spec("layers/1/ffn/w_in", L(3), "tp") \
        == (None, "fsdp", "tp")
    assert param_spec("embed", L(2)) == ("tp", "fsdp")
    assert param_spec("layers/0/norm1/scale", L(1)) == (None,)


def test_fit_spec_divisibility():
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("model",))
    # model axis size 1 always divides; the 4-way drop-vs-pad cases run
    # under forced devices in tests/test_distributed.py
    assert fit_spec(P("model", None), (50280, 16), mesh) \
        == P("model", None)
