"""Morton codes + linear octree: unit and property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean env: deterministic fallback sampler
    from _hyp import given, settings, strategies as st

from repro.core import morton, octree


@given(st.lists(st.tuples(st.integers(0, 1023), st.integers(0, 1023),
                          st.integers(0, 1023)),
                min_size=1, max_size=64))
@settings(max_examples=30, deadline=None)
def test_morton_roundtrip(ivox):
    arr = jnp.asarray(np.array(ivox, dtype=np.uint32))
    codes = morton.encode(arr)
    back = morton.decode(codes)
    np.testing.assert_array_equal(np.asarray(back), np.array(ivox))


def test_morton_locality_order():
    # points in the same octant at level 1 share the leading 3 bits
    pts = jnp.asarray([[0.1, 0.1, 0.1], [0.2, 0.2, 0.2],
                       [0.9, 0.9, 0.9]])
    codes = morton.morton_codes(pts, lo=jnp.zeros(3), hi=jnp.ones(3))
    k = morton.node_key(codes, 1)
    assert int(k[0]) == int(k[1]) != int(k[2])


def test_np_jax_morton_match():
    rng = np.random.default_rng(0)
    pts = rng.uniform(-1, 1, (256, 3)).astype(np.float32)
    a = np.asarray(morton.morton_codes(jnp.asarray(pts)))
    b = morton.np_morton_codes(pts)
    # float32 vs float64 quantization can differ at voxel boundaries
    assert (a == b).mean() > 0.98


def test_octree_node_range_contains_points():
    rng = np.random.default_rng(1)
    pts = jnp.asarray(rng.uniform(-1, 1, (512, 3)).astype(np.float32))
    tree = octree.build(pts)
    level = 2
    keys = tree.node_keys(level)
    k0 = keys[0]
    start, end = tree.node_range(k0, level)
    inside = keys[int(start):int(end)]
    assert bool((inside == k0).all())
    # points outside the range have different keys
    if int(end) < 512:
        assert int(keys[int(end)]) != int(k0)


def test_octree_contains():
    rng = np.random.default_rng(2)
    pts = jnp.asarray(rng.uniform(-1, 1, (128, 3)).astype(np.float32))
    tree = octree.build(pts)
    hit, idx = tree.contains(tree.codes[10:20])
    assert bool(hit.all())
    # a code guaranteed absent
    absent = jnp.asarray([0x3FFFFFFF], jnp.uint32)
    hit2, idx2 = tree.contains(absent)
    if not bool((tree.codes == absent[0]).any()):
        assert not bool(hit2[0])
        assert int(idx2[0]) == -1


def test_adjacent_node_keys_are_neighbors():
    keys = jnp.asarray([0], jnp.uint32)  # corner voxel at level 2
    nk = octree.adjacent_node_keys(keys, 2)
    xyz = morton.decode(nk[0])
    # all neighbors within +-1 of (0,0,0), clipped to >= 0
    assert int(xyz.max()) <= 1
    assert nk.shape == (1, 27)
