"""Hub-based Scheduling invariants + the octree-equivalence proof of the
overlap detection (paper §IV-B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean env: deterministic fallback sampler
    from _hyp import given, settings, strategies as st

from repro.core.islandize import islandize as _islandize
from repro.core import octree
from repro.core.hub_schedule import build_schedule
from repro.core.pipeline import LPCNConfig, data_structuring
from repro.data.synthetic import make_cloud


def _setup(n=256, s=128, k=16, seed=0, capacity=32):
    rng = np.random.default_rng(seed)
    xyz = jnp.asarray(make_cloud(rng, n))
    cfg = LPCNConfig(n_centers=s, k=k)
    cidx, nbr = data_structuring(cfg, xyz, jax.random.PRNGKey(seed))
    islands = _islandize(xyz[cidx], max(s // 32, 1), capacity=64,
                            key=jax.random.PRNGKey(seed))
    sched = build_schedule(islands, nbr, capacity)
    return xyz, cidx, nbr, islands, sched, capacity


def test_schedule_shapes_and_ranges():
    xyz, cidx, nbr, islands, sched, C = _setup()
    slot = np.asarray(sched.reuse_slot)
    assert slot.max() < C
    assert slot.min() >= -1
    pool = np.asarray(sched.pool_ids)
    assert pool.shape[1] == C


def test_pool_is_first_occurrences_in_order():
    """Replay the island sequence in numpy (the FPGA temporal semantics)
    and check the closed-form schedule matches exactly."""
    xyz, cidx, nbr, islands, sched, C = _setup(seed=1)
    members = np.asarray(islands.members)
    nbr_np = np.asarray(nbr)
    pool = np.asarray(sched.pool_ids)
    slot_arr = np.asarray(sched.reuse_slot)
    for h in range(members.shape[0]):
        cache: dict = {}
        for m, cidx_m in enumerate(members[h]):
            if cidx_m < 0:
                continue
            for kk, pid in enumerate(nbr_np[cidx_m]):
                if pid in cache:
                    expected = cache[pid]
                elif len(cache) < C:
                    cache[pid] = len(cache)
                    expected = cache[pid]
                else:
                    expected = -1
                assert slot_arr[h, m, kk] == expected, (h, m, kk)
        for pid, s in cache.items():
            assert pool[h, s] == pid


def test_hub_fills_first_k_slots():
    xyz, cidx, nbr, islands, sched, C = _setup(seed=2)
    members = np.asarray(islands.members)
    nbr_np = np.asarray(nbr)
    pool = np.asarray(sched.pool_ids)
    for h in range(members.shape[0]):
        hub = members[h, 0]
        if hub < 0:
            continue
        hub_pts = []
        for pid in nbr_np[hub]:
            if pid not in hub_pts:
                hub_pts.append(pid)
        np.testing.assert_array_equal(pool[h, :len(hub_pts)], hub_pts)


def test_overlap_detection_octree_equivalence():
    """Membership-by-id == Morton-octree probe (the hardware mechanism):
    for each cached pool the octree built on pool points must report
    hit/miss identically to the id test."""
    xyz, cidx, nbr, islands, sched, C = _setup(seed=3)
    pool = np.asarray(sched.pool_ids)
    nbr_np = np.asarray(nbr)
    members = np.asarray(islands.members)
    # unique quantization for identity: include point index in the key
    # (two points may share a voxel; hardware stores per-point entries)
    for h in range(min(4, members.shape[0])):
        ids = pool[h][pool[h] >= 0]
        if len(ids) == 0:
            continue
        tree = octree.build(xyz[jnp.asarray(ids)])
        m = members[h, 1] if members.shape[1] > 1 else -1
        if m < 0:
            continue
        probe = xyz[jnp.asarray(nbr_np[m])]
        codes = octree.morton.morton_codes(
            probe, lo=xyz[jnp.asarray(ids)].min(0),
            hi=xyz[jnp.asarray(ids)].max(0))
        hit, _ = tree.contains(codes)
        id_hit = np.isin(nbr_np[m], ids)
        # octree hit must cover every id hit (same voxel => hit); spurious
        # voxel collisions are possible but rare
        assert (np.asarray(hit)[id_hit].mean() if id_hit.any() else 1.0) \
            > 0.9


@given(st.integers(0, 100), st.integers(8, 32))
@settings(max_examples=8, deadline=None)
def test_capacity_monotonicity(seed, cap):
    """More cache capacity never decreases reuse."""
    xyz, cidx, nbr, islands, _, _ = _setup(seed=seed)
    s1 = build_schedule(islands, nbr, cap)
    s2 = build_schedule(islands, nbr, cap * 2)
    r1 = int((np.asarray(s1.reuse_slot) >= 0).sum())
    r2 = int((np.asarray(s2.reuse_slot) >= 0).sum())
    assert r2 >= r1
