"""Per-kernel allclose vs. pure-jnp oracles, swept over shapes/dtypes
(interpret mode on CPU; the same kernels compile via Mosaic on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("s,n,k,tc,tp", [
    (64, 256, 8, 64, 128),
    (130, 1000, 32, 128, 256),   # ragged tiles both axes
    (32, 512, 16, 32, 512),
    (16, 100, 4, 16, 64),
])
def test_knn_kernel(s, n, k, tc, tp):
    from repro.kernels.knn.ops import knn, knn_ref
    c = jnp.asarray(RNG.normal(size=(s, 3)), jnp.float32)
    p = jnp.asarray(RNG.normal(size=(n, 3)), jnp.float32)
    d1, i1 = knn(c, p, k, tc=tc, tp=tp, interpret=True)
    d0, i0 = knn_ref(c, p, k)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d0),
                               rtol=1e-5, atol=1e-5)
    unique_d = np.asarray(jnp.abs(d0[:, 1:] - d0[:, :-1]) > 1e-9)
    agree = (np.asarray(i1) == np.asarray(i0))[:, 1:][unique_d]
    assert agree.mean() > 0.99  # ties may reorder


@pytest.mark.parametrize("s,k,d,dc,h,f,dtype", [
    (37, 32, 6, 3, 64, 128, jnp.float32),
    (8, 16, 10, 3, 32, 64, jnp.float32),
    (64, 20, 12, 6, 48, 96, jnp.float32),
])
def test_gather_mlp_kernel(s, k, d, dc, h, f, dtype):
    from repro.kernels.gather_mlp.ops import gather_mlp, gather_mlp_ref
    raw = jnp.asarray(RNG.normal(size=(s, k, d)), dtype)
    ctr = jnp.asarray(RNG.normal(size=(s, dc)), dtype)
    w1 = jnp.asarray(RNG.normal(size=(d, h)) * 0.1, dtype)
    w2 = jnp.asarray(RNG.normal(size=(h, f)) * 0.1, dtype)
    b1 = jnp.asarray(RNG.normal(size=(h,)) * 0.01, dtype)
    b2 = jnp.asarray(RNG.normal(size=(f,)) * 0.01, dtype)
    y1 = gather_mlp(raw, ctr, w1, b1, w2, b2, ts=8, interpret=True)
    y0 = gather_mlp_ref(raw, ctr, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("hn,c,m,k,d,hd,f", [
    (4, 64, 16, 32, 6, 64, 128),
    (2, 32, 8, 16, 9, 32, 64),
    (1, 16, 4, 8, 6, 16, 32),
])
def test_hub_reuse_kernel(hn, c, m, k, d, hd, f):
    from repro.kernels.hub_reuse.ops import hub_reuse, hub_reuse_ref
    pool = jnp.asarray(RNG.normal(size=(hn, c, d)), jnp.float32)
    slot = jnp.asarray(RNG.integers(-1, c, (hn, m, k)), jnp.int32)
    comp = jnp.asarray(RNG.normal(size=(hn, m, f)) * 0.01, jnp.float32)
    w1 = jnp.asarray(RNG.normal(size=(d, hd)) * 0.1, jnp.float32)
    w2 = jnp.asarray(RNG.normal(size=(hd, f)) * 0.1, jnp.float32)
    b1, b2 = jnp.zeros(hd), jnp.zeros(f)
    z1 = hub_reuse(pool, slot, comp, w1, b1, w2, b2, interpret=True)
    z0 = hub_reuse_ref(pool, slot, comp, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z0),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("b,hq,hkv,s,d,causal", [
    (1, 2, 1, 128, 32, True),
    (2, 4, 2, 256, 64, True),
    (1, 4, 4, 64, 32, False),
    (1, 8, 2, 192, 16, True),     # ragged q tiles
])
def test_flash_attention_kernel(b, hq, hkv, s, d, causal):
    from repro.kernels.flash_attention.ops import (attention_ref,
                                                   flash_attention)
    q = jnp.asarray(RNG.normal(size=(b, hq, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    a1 = flash_attention(q, k, v, causal=causal, tq=64, tk=64,
                         interpret=True)
    a0 = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a0),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention.ops import (attention_ref,
                                                   flash_attention)
    q = jnp.asarray(RNG.normal(size=(1, 2, 128, 32)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 1, 128, 32)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 1, 128, 32)), jnp.bfloat16)
    a1 = flash_attention(q, k, v, tq=64, tk=64, interpret=True)
    a0 = attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(a1, np.float32), np.asarray(a0, np.float32),
        rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("bs,nc,q,h,p,s", [
    (1, 2, 16, 2, 8, 16),
    (2, 1, 32, 4, 16, 32),
])
def test_ssd_chunk_kernel(bs, nc, q, h, p, s):
    from repro.kernels.ssd_chunk.ops import ssd_chunk, ssd_chunk_ref
    x = jnp.asarray(RNG.normal(size=(bs, nc, q, h, p)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(bs, nc, q, s)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(bs, nc, q, s)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 1.0, (bs, nc, q, h)), jnp.float32)
    # cum must be non-increasing within a chunk (dA < 0)
    cum = -jnp.cumsum(jnp.asarray(
        RNG.uniform(0.01, 0.2, (bs, nc, q, h)), jnp.float32), axis=2)
    y1, st1 = ssd_chunk(x, B, C, dt, cum, interpret=True)
    y0, st0 = ssd_chunk_ref(x, B, C, dt, cum)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st0),
                               rtol=2e-4, atol=2e-4)
