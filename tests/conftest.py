import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True)
def _fresh_plan_store():
    """Pin every test to an empty in-memory tile-plan store: results are
    independent of whatever results/tile_plans.json the host carries,
    and tests that seed plans (tests/test_autotune.py) can't leak them
    into each other."""
    from repro.kernels import plans
    plans.configure(None)
    yield
    plans.configure(None)
