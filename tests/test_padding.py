"""Ragged-batch masking: a padded batch must be numerically equivalent to
running each cloud unpadded, across every model family, execution mode and
FC backend; padding must never inflate workload counters; degenerate
clouds (fewer valid points than k, empty ball queries) must degrade to
zero feature rows instead of NaN/-inf."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean env: deterministic fallback sampler
    from _hyp import given, settings, strategies as st

from repro import engine
from repro.data.synthetic import make_cloud
from repro.engine import Batch, BlockSpec
from repro.models import MODEL_ZOO, dgcnn, pointnet2

KEY = jax.random.PRNGKey(0)

# small variants of the four paper families (same layer structure, sized
# for CPU test runtime); DGCNN's "all" sampler is the case where padding
# rows survive into every layer
SPECS = {
    "pointnet2": replace(pointnet2.POINTNET2_C, blocks=(
        BlockSpec(48, 8, (16, 32)), BlockSpec(16, 8, (32, 48)))),
    "dgcnn": replace(dgcnn.DGCNN_C, blocks=(
        BlockSpec(96, 8, (16,), kind="edge", sampler="all"),
        BlockSpec(96, 8, (24,), kind="edge", sampler="all"))),
    "pointnext": replace(MODEL_ZOO["pointnext_s"][1], blocks=(
        BlockSpec(48, 8, (16,)), BlockSpec(16, 8, (32,)))),
    "pointvector": replace(MODEL_ZOO["pointvector_l"][1], blocks=(
        BlockSpec(48, 8, (24,)), BlockSpec(16, 8, (48,)))),
}
SIZES = (96, 72, 60)          # includes the no-padding case


def _ragged(spec, sizes=SIZES, seed=0):
    rng = np.random.default_rng(seed)
    f_extra = spec.in_feats - 3
    clouds, feats = [], []
    for n in sizes:
        c = np.asarray(make_cloud(rng, n), np.float32)
        clouds.append(c)
        feats.append(np.concatenate(
            [c, rng.uniform(0, 1, (n, f_extra)).astype(np.float32)], -1)
            if f_extra else c)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), len(sizes))
    batch = Batch.from_clouds(clouds, feats=None if not f_extra else feats,
                              key=keys)
    return clouds, feats, keys, batch


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("mode", ["traditional", "lpcn"])
@pytest.mark.parametrize("name", sorted(SPECS))
def test_padded_matches_unpadded(name, mode, backend):
    """The oracle: engine.apply(padded)[i, :n_valid[i]] equals
    engine.apply_single(cloud_i) for every model x mode x backend."""
    spec = SPECS[name]
    params = engine.init(KEY, spec)
    clouds, feats, keys, batch = _ragged(spec, seed=sorted(SPECS).index(name))
    out = engine.apply(params, batch, spec=spec, mode=mode,
                       fc_backend=backend)
    tol = 1e-5 if backend == "reference" else 1e-4
    for i, (c, f) in enumerate(zip(clouds, feats)):
        ref, _ = engine.apply_single(
            params, jnp.asarray(c), jnp.asarray(f), keys[i], spec=spec,
            mode=mode, fc_backend=backend)
        got = out[i]
        if got.ndim == 2:            # seg: compare valid rows, pad is zero
            np.testing.assert_array_equal(
                np.asarray(got[c.shape[0]:]), 0.0)
            got = got[:c.shape[0]]
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=tol, atol=tol, err_msg=name)


def test_report_counters_unchanged_by_padding():
    """Islandization / hub-schedule reuse counters must be identical with
    and without padding rows (padding contributes zero work)."""
    spec = SPECS["pointnet2"]
    params = engine.init(KEY, spec)
    clouds, feats, keys, batch = _ragged(spec, seed=5)
    _, rep = engine.apply_with_reports(params, batch, spec=spec,
                                       mode="lpcn")
    rep = rep.concrete()
    for i, c in enumerate(clouds):
        _, ref = engine.apply_single(params, jnp.asarray(c),
                                     jnp.asarray(c), keys[i], spec=spec,
                                     mode="lpcn", with_report=True)
        ref = ref.concrete()
        for field in ("baseline_fetches", "lpcn_fetches",
                      "baseline_mlp_evals", "lpcn_mlp_evals",
                      "n_subsets", "n_islands_used"):
            assert int(getattr(rep, field)[i]) == int(getattr(ref, field)), \
                (field, i)


@given(st.integers(0, 2), st.integers(0, 1000))
@settings(max_examples=6, deadline=None)
def test_padding_equivalence_property(rotate, seed):
    """Property form: ragged size mixes (incl. all-full) stay equivalent
    and keep finite logits.  Sizes are drawn from a fixed menu so the jit
    cache is bounded."""
    sizes = tuple(np.roll([96, 80, 64], rotate))
    spec = SPECS["pointnet2"]
    params = engine.init(jax.random.PRNGKey(seed % 7), spec)
    clouds, feats, keys, batch = _ragged(spec, sizes=sizes, seed=seed)
    out = engine.apply(params, batch, spec=spec, mode="lpcn")
    assert bool(jnp.isfinite(out).all())
    for i, c in enumerate(clouds):
        ref, _ = engine.apply_single(params, jnp.asarray(c),
                                     jnp.asarray(c), keys[i], spec=spec,
                                     mode="lpcn")
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_ball_query_zero_valid_in_radius_yields_zero_row():
    """A center whose radius holds zero *valid* points must produce an
    all -1 neighbor row and a zero feature row (not NaN / -inf)."""
    from repro.core.mlp import init_mlp
    from repro.core.neighbor import ball_query
    from repro.core.pipeline import fc_traditional
    rng = np.random.default_rng(3)
    pts = jnp.asarray(rng.uniform(-1, 1, (32, 3)), jnp.float32)
    centers = jnp.asarray([[5.0, 5.0, 5.0], [0.0, 0.0, 0.0]], jnp.float32)
    # only the first 4 rows are valid; center 0 is far from all of them
    idx = ball_query(pts, centers, 0.05, 8, n_valid=4)
    idx_np = np.asarray(idx)
    assert (idx_np[0] == -1).all()
    assert (idx_np < 4).all()        # padding rows never appear
    mlp = init_mlp(KEY, [6, 16, 8])
    f = fc_traditional(mlp, pts, pts, idx, centers, centers, "sa",
                       nbr_valid=idx >= 0)
    f_np = np.asarray(f)
    assert np.isfinite(f_np).all()
    np.testing.assert_array_equal(f_np[0], 0.0)


def test_ball_query_unmasked_keeps_reference_fallback():
    """Legacy (unmasked) semantics preserved: an empty-radius center
    falls back to point 0 (the reference CUDA kernel behavior), never -1
    — so eager callers that gather by the returned ids are unaffected."""
    from repro.core.neighbor import ball_query
    rng = np.random.default_rng(8)
    pts = jnp.asarray(rng.uniform(-1, 1, (16, 3)), jnp.float32)
    centers = jnp.asarray([[9.0, 9.0, 9.0]], jnp.float32)
    idx = np.asarray(ball_query(pts, centers, 0.05, 4))
    np.testing.assert_array_equal(idx, 0)


def test_all_sampler_cls_global_pool_masks_padding():
    """pointnet2-family cls spec whose blocks all use the "all" sampler:
    padding reaches the final global pool and must be masked there."""
    spec = replace(pointnet2.POINTNET2_C, blocks=(
        BlockSpec(96, 8, (16, 32), sampler="all"),))
    params = engine.init(KEY, spec)
    clouds, feats, keys, batch = _ragged(spec, seed=9)
    out = engine.apply(params, batch, spec=spec, mode="traditional")
    for i, c in enumerate(clouds):
        ref, _ = engine.apply_single(params, jnp.asarray(c),
                                     jnp.asarray(c), keys[i], spec=spec,
                                     mode="traditional")
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_pre_ragged_component_signature_errors_clearly():
    """A registered sampler without n_valid works in the eager per-cloud
    path but raises an actionable TypeError through the batched engine
    (which always passes a traced n_valid)."""
    from repro.core.pipeline import LPCNConfig, data_structuring
    spec = replace(pointnet2.POINTNET2_C, blocks=(
        BlockSpec(16, 4, (8, 16), sampler="test_legacy_sig"),))
    engine.register_sampler(
        "test_legacy_sig",
        lambda xyz, *, tree, n_centers, key:
        jnp.arange(n_centers, dtype=jnp.int32))
    try:
        params = engine.init(KEY, spec)
        xyz = jnp.asarray(np.random.default_rng(0).uniform(
            -1, 1, (2, 32, 3)), jnp.float32)
        # eager path (no n_valid): still works
        cfg = LPCNConfig(n_centers=16, k=4, sampler="test_legacy_sig")
        cidx, _ = data_structuring(cfg, xyz[0], KEY)
        assert cidx.shape == (16,)
        # batched path: clear, actionable error
        with pytest.raises(TypeError, match="n_valid"):
            engine.apply(params, Batch.make(xyz), spec=spec,
                         mode="traditional")
    finally:
        engine.SAMPLERS._entries.pop("test_legacy_sig", None)


@pytest.mark.parametrize("neighbor", ["ball", "pointacc"])
def test_one_valid_point_cloud(neighbor):
    """Regression: a 1-valid-point padded cloud (fewer valid points than
    k) runs every mode/backend without NaN."""
    spec = replace(pointnet2.POINTNET2_C, blocks=(
        BlockSpec(8, 4, (8, 16), radius=0.1, neighbor=neighbor),))
    params = engine.init(KEY, spec)
    rng = np.random.default_rng(4)
    xyz = jnp.asarray(rng.uniform(-1, 1, (2, 64, 3)), jnp.float32)
    batch = Batch.make(xyz, n_valid=jnp.asarray([1, 64], jnp.int32))
    for mode in ("traditional", "lpcn"):
        for backend in ("reference", "pallas"):
            out = engine.apply(params, batch, spec=spec, mode=mode,
                               fc_backend=backend)
            assert bool(jnp.isfinite(out).all()), (mode, backend)


def test_sampling_and_neighbors_never_return_padding():
    """DS-level invariant across every registered sampler/neighbor pair:
    centers and neighbor ids stay below n_valid (or are -1)."""
    from repro.core.pipeline import LPCNConfig, data_structuring
    rng = np.random.default_rng(6)
    xyz = jnp.asarray(make_cloud(rng, 128), jnp.float32)
    n_valid = jnp.int32(90)
    for sampler in ("fps", "random", "morton"):
        for method in ("pointacc", "hgpcn", "edgepc", "crescent", "ball"):
            cfg = LPCNConfig(n_centers=32, k=8, sampler=sampler,
                             neighbor=method, radius=0.3)
            cidx, nbr = data_structuring(cfg, xyz, KEY, n_valid=n_valid)
            assert (np.asarray(cidx) < 90).all(), (sampler, method)
            assert (np.asarray(cidx) >= 0).all(), (sampler, method)
            assert (np.asarray(nbr) < 90).all(), (sampler, method)
