"""Minimal deterministic stand-in for ``hypothesis`` (fallback only).

The property tests in this suite use a tiny slice of the hypothesis API:
``@given(st.integers(...), ...)``, ``@settings(max_examples=, deadline=)``
and the ``integers`` / ``tuples`` / ``lists`` strategies.  On environments
without hypothesis installed we degrade to a fixed-seed sampler that runs
each property over ``max_examples`` deterministic draws, so the whole
suite still collects and the invariants are still exercised.

Install the real thing (``pip install -e .[test]``) for shrinking and
real randomized search.
"""
from __future__ import annotations


import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def tuples(*elems: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elem.draw(rng) for _ in range(n)]
        return _Strategy(draw)


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        def runner():
            # read at call time: with `@settings` ABOVE `@given` the
            # attribute lands on runner, below it lands on fn
            n = getattr(runner, "_hyp_max_examples",
                        getattr(fn, "_hyp_max_examples", 10))
            rng = np.random.default_rng(0)
            for _ in range(n):
                fn(*(s.draw(rng) for s in strats))
        # NOT functools.wraps: pytest would re-read the wrapped signature
        # and treat the strategy arguments as fixtures.
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner
    return deco


st = strategies
