"""repro.analysis: each rule family flags its golden known-bad fixture
with the right rule id, suppressions work, and the real engine matrix
passes clean (zero unsuppressed findings — the CI gate's contract)."""
import json
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.analysis import (RULES, active, apply_suppressions,
                            compile_cache_size, count_pallas_calls,
                            kernel_findings, leaf_findings,
                            masked_reduction_findings, pallas_call_sites,
                            repo_findings, scan_suppressions,
                            static_findings)
from repro.analysis.cli import _src_suppressions, analyze_targets
from repro.analysis.retrace import cache_growth_findings
from repro.analysis.targets import default_targets

BIG = 3.4e38


def _rules(findings, unsuppressed_only=False):
    fs = active(findings) if unsuppressed_only else findings
    return sorted({f.rule for f in fs})


# ---- kernel lint golden fixtures -------------------------------------------

def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _trace_copy(shape, block, grid, index_map, *, out_block=None,
                out_index=None, semantics=None):
    """A minimal pallas_call with fully controllable specs (interpret
    mode — nothing executes, we only trace)."""
    params = {}
    if semantics is not None:
        from jax.experimental.pallas import tpu as pltpu
        params["compiler_params"] = dict(
            mosaic=dict(dimension_semantics=semantics))

    def fn(x):
        return pl.pallas_call(
            _copy_kernel, grid=grid,
            in_specs=[pl.BlockSpec(block, index_map)],
            out_specs=pl.BlockSpec(out_block or block,
                                   out_index or index_map),
            out_shape=jax.ShapeDtypeStruct(shape, jnp.float32),
            interpret=True, **params)(x)

    return jax.make_jaxpr(fn)(jnp.zeros(shape, jnp.float32))


def test_k001_over_budget_tile_plan():
    jx = _trace_copy((256, 256), (256, 256), (1,), lambda i: (0, 0))
    # 2 streamed-ish buffers of 256KB each easily bust a 0.1 MB budget
    fs = kernel_findings(jx, vmem_budget_mb=0.1, where="fixture")
    assert "K001" in _rules(fs), fs


def test_k002_misaligned_lane_kernel():
    # block last dim 64 is neither a 128-multiple nor the full width 256
    jx = _trace_copy((8, 256), (8, 64), (4,), lambda i: (0, i))
    fs = kernel_findings(jx, vmem_budget_mb=8.0, where="fixture")
    assert "K002" in _rules(fs), fs


def test_k002_full_width_small_operand_is_clean():
    # a 3-wide block streaming the full 3-wide axis (the ctr pattern)
    jx = _trace_copy((16, 3), (8, 3), (2,), lambda i: (i, 0))
    fs = kernel_findings(jx, vmem_budget_mb=8.0, where="fixture")
    assert "K002" not in _rules(fs), fs


def test_k003_out_of_bounds_grid_tile():
    # 16 rows / block 8 = 2 tiles, but the grid claims 4
    jx = _trace_copy((16, 128), (8, 128), (4,), lambda i: (i, 0))
    fs = kernel_findings(jx, vmem_budget_mb=8.0, where="fixture")
    assert "K003" in _rules(fs), fs


def test_k004_resident_operand_not_covering():
    # constant index map (resident) but the block covers half the rows
    jx = _trace_copy((256, 128), (128, 128), (2,), lambda i: (0, 0),
                     out_block=(128, 128), out_index=lambda i: (i, 0))
    fs = kernel_findings(jx, vmem_budget_mb=8.0, where="fixture")
    assert "K004" in _rules(fs), fs


def test_k005_parallel_axis_write_race():
    # grid axis 0 marked "parallel" but the output block never moves
    jx = _trace_copy((8, 128), (8, 128), (2,), lambda i: (0, 0),
                     semantics=("parallel",))
    fs = kernel_findings(jx, vmem_budget_mb=8.0, where="fixture")
    assert "K005" in _rules(fs), fs


def test_real_batched_kernel_is_clean_and_counted():
    """The PR-3 batched gather-MLP kernel passes every K rule at the
    default budget, and the migrated dispatch-count walker sees exactly
    one pallas_call with the batch in the grid."""
    from repro.kernels.gather_mlp.gather_mlp import gather_mlp_batched_pallas
    b, s, k, d, dc = 3, 16, 8, 6, 3
    args = (jnp.zeros((b, s, k, d)), jnp.zeros((b, s, dc)),
            jnp.zeros((d, 16)), jnp.zeros((16,)),
            jnp.zeros((16, 8)), jnp.zeros((8,)))
    jx = jax.make_jaxpr(
        lambda *a: gather_mlp_batched_pallas(*a, interpret=True))(*args)
    assert kernel_findings(jx, vmem_budget_mb=8.0) == []
    grids = []
    assert count_pallas_calls(jx, grids) == 1
    assert grids[0][0] == b, grids
    (site,) = pallas_call_sites(jx)
    assert site.footprint_bytes > 0
    # the weights ride constant index maps -> resident
    assert sum(o.resident for o in site.operands) >= 4, site.operands


# ---- masking lint golden fixtures ------------------------------------------

def test_m001_unmasked_reduction_flagged():
    jx = jax.make_jaxpr(lambda y: jnp.max(y, axis=1))(
        jnp.zeros((4, 8, 16)))
    fs = masked_reduction_findings(jx, point_sizes={8}, where="fixture")
    assert _rules(fs) == ["M001"], fs


def test_m001_sentinel_masked_reduction_clean():
    def fn(y, mask):
        return jnp.max(jnp.where(mask[..., None], y, -BIG), axis=1)
    jx = jax.make_jaxpr(fn)(jnp.zeros((4, 8, 16)),
                            jnp.zeros((4, 8), bool))
    assert masked_reduction_findings(jx, point_sizes={8}) == []


def test_m001_zero_fill_sum_clean():
    def fn(y, mask):
        return jnp.where(mask[..., None], y, 0.0).sum(axis=1)
    jx = jax.make_jaxpr(fn)(jnp.zeros((4, 8, 16)),
                            jnp.zeros((4, 8), bool))
    assert masked_reduction_findings(jx, point_sizes={8}) == []


def test_m001_guard_consumed_by_matmul():
    """A mask applied BEFORE a matmul does not guard a pool after it —
    the mask must be re-applied at the reduction."""
    def fn(y, mask, w):
        h = jnp.where(mask[..., None], y, 0.0) @ w    # (4, 8, 16)
        return jnp.max(h, axis=1)                      # unguarded again
    jx = jax.make_jaxpr(fn)(jnp.zeros((4, 8, 16)),
                            jnp.zeros((4, 8), bool),
                            jnp.zeros((16, 16)))
    fs = masked_reduction_findings(jx, point_sizes={8})
    assert _rules(fs) == ["M001"], fs


def test_m001_non_point_axis_ignored():
    jx = jax.make_jaxpr(lambda y: jnp.max(y, axis=2))(
        jnp.zeros((4, 8, 16)))
    assert masked_reduction_findings(jx, point_sizes={8}) == []


# ---- recompile-hazard golden fixtures --------------------------------------

def test_r001_numpy_leaf_into_jit():
    fs = leaf_findings({"x": np.zeros((3,), np.float32),
                        "y": jnp.zeros((3,))}, where="fx")
    assert _rules(fs) == ["R001"], fs
    assert "x" in fs[0].where


def test_r002_python_scalar_leaf():
    fs = leaf_findings({"s": 2.0, "y": jnp.zeros((3,))})
    assert _rules(fs) == ["R002"]
    assert fs[0].severity == "warning"


def test_r003_unhashable_static():
    fs = static_findings({"spec": [1, 2, 3], "mode": "lpcn"})
    assert _rules(fs) == ["R003"], fs


def test_r004_cache_growth_across_leaf_types():
    f = jax.jit(lambda x: x * 2)
    a = np.ones((4,), np.float32)
    fs = cache_growth_findings(f, [(a,), (jnp.asarray(a),)], expected=1)
    assert _rules(fs) == ["R004"], fs
    g = jax.jit(lambda x: x * 2)
    assert cache_growth_findings(
        g, [(jnp.ones((4,)),), (jnp.zeros((4,)),)], expected=1) == []
    assert compile_cache_size(g) == 1


# ---- repo lint golden fixtures ---------------------------------------------

def _write(root, rel, text):
    path = os.path.join(root, rel)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(textwrap.dedent(text))


@pytest.fixture
def bad_repo(tmp_path):
    src = str(tmp_path / "src")
    _write(src, "repro/__init__.py", "")
    _write(src, "repro/dist/__init__.py", "")
    _write(src, "repro/engine/__init__.py", """\
        import repro.dist
        """)
    _write(src, "repro/core/bad.py", """\
        import time

        import jax


        def sample(key, n):
            t0 = time.time()
            idx = jax.random.choice(key, n, shape=(4,))
            return idx, t0


        def sample_ok(key, n):
            # analysis: allow A001 -- golden-fixture suppression test
            idx = jax.random.choice(key, n, shape=(4,))
            return idx


        def sample_unjustified(key, n):
            idx = jax.random.choice(key, n, shape=(4,))  # analysis: allow A001
            return idx
        """)
    _write(src, "repro/serve/__init__.py", "")
    _write(src, "repro/serve/bad.py", """\
        def fire_swallowing(fn, batch):          # A004: silently eaten
            try:
                return fn(batch)
            except Exception:
                pass


        def fire_bare(fn, batch):                # A004: bare except
            try:
                return fn(batch)
            except:
                return None


        def fire_converting(fn, batch, outcomes):  # ok: uses the error
            try:
                return fn(batch)
            except Exception as e:
                outcomes.append(repr(e))


        def fire_reraising(fn, batch):           # ok: re-raises
            try:
                return fn(batch)
            except Exception:
                raise RuntimeError("dispatch failed")


        def fire_narrow(fn, batch):              # ok: not a blanket catch
            try:
                return fn(batch)
            except KeyError:
                return None


        def fire_and_forget(pool, fn, batch):    # A005: result discarded
            pool.submit(fn, batch)
            return True


        def fire_state_check_only(pool, fn, batch):  # A005: .done() never
            fut = pool.submit(fn, batch)             # surfaces the error
            return fut.done()


        def fire_joined(pool, fn, batch):        # ok: joined inline
            return pool.submit(fn, batch).result()


        def fire_callback(rec, pool, fn, batch):  # ok: completion path
            rec.future = pool.submit(fn, batch)
            rec.future.add_done_callback(print)


        def fire_handed_off(pool, fn, batch, futs):  # ok: escapes to the
            f = pool.submit(fn, batch)               # caller, who owns it
            futs.append(f)


        def admit(queue, xyz):                   # ok: not a future at all
            req = queue.submit(xyz)
            return req.rid
        """)
    # the same swallow OUTSIDE repro.serve is not A004's business, and
    # the same dropped submit outside it is not A005's
    _write(src, "repro/launch/swallow.py", """\
        def best_effort(fn):
            try:
                return fn()
            except Exception:
                return None


        def best_effort_submit(pool, fn):
            pool.submit(fn)
        """)
    return src


def test_forbidden_ast_patterns_flagged(bad_repo):
    fs = repo_findings(bad_repo)
    rules = _rules(fs, unsuppressed_only=True)
    assert "A001" in rules and "A002" in rules and "A003" in rules, fs
    # A004: exactly the two swallowing handlers in repro.serve — the
    # converting / re-raising / narrow ones and the swallow outside the
    # serving layer stay clean
    a004 = [f for f in active(fs) if f.rule == "A004"]
    assert len(a004) == 2, a004
    assert all("serve/bad.py" in f.where for f in a004)
    assert any("bare except" in f.message for f in a004)
    assert any("except Exception" in f.message for f in a004)
    # A005: exactly the discarded submit and the state-check-only future
    # — joined / callback'd / escaping bindings, the non-future
    # queue.submit, and the drop outside repro.serve all stay clean
    a005 = [f for f in active(fs) if f.rule == "A005"]
    assert len(a005) == 2, a005
    assert all("serve/bad.py" in f.where for f in a005)
    assert any("result discarded" in f.message for f in a005)
    assert any("never consumed" in f.message for f in a005)
    # the justified suppression took effect...
    suppressed = [f for f in fs if f.suppressed]
    assert [f.rule for f in suppressed] == ["A001"]
    assert "golden-fixture" in suppressed[0].justification
    # ...the justification-less one did not, and was itself reported
    assert "S001" in rules, fs
    unsup_a001 = [f for f in active(fs) if f.rule == "A001"]
    assert len(unsup_a001) == 2  # the plain one + the unjustified one


def test_suppression_scan_syntax(tmp_path):
    p = str(tmp_path / "x.py")
    with open(p, "w") as fh:
        fh.write("# analysis: allow K002 */fc* -- lane-padded by hand\n"
                 "# analysis: allow M001\n")
    sups, meta = scan_suppressions(p)
    assert len(sups) == 1 and sups[0].rule == "K002"
    assert sups[0].pattern == "*/fc*"
    assert len(meta) == 1 and meta[0].rule == "S001"


# ---- the clean-repo pass (what `--strict` gates in CI) ---------------------

def test_repo_source_is_clean():
    fs = repo_findings()
    assert active(fs) == [], [str(f) for f in active(fs)]


def test_engine_matrix_clean_no_false_positives():
    """A representative slice of the matrix (the masked lpcn path on
    the batched pallas backend + the reference oracle, plus dgcnn whose
    sampler='all' keeps masks live at every level) yields zero
    unsuppressed findings — the zero-false-positive contract."""
    targets = [t for t in default_targets(
        models=("pointnet2", "dgcnn"), modes=("lpcn",),
        backends=("reference", "pallas"),
        include_serve=True, include_dist=False)]
    sups, _meta = _src_suppressions(None)
    findings, inventory = analyze_targets(targets, suppressions=sups)
    assert active(findings) == [], [str(f) for f in active(findings)]
    # the pallas targets contribute kernel sites to the inventory
    assert any(row["grid"][0] == 3 for row in inventory), inventory
    assert all(row["footprint_bytes"] > 0 for row in inventory)


def test_cli_quick_strict_and_report(tmp_path):
    from repro.analysis.cli import main
    out = str(tmp_path / "report.json")
    rc = main(["--quick", "--strict", "--json", out])
    assert rc == 0
    rep = json.load(open(out))
    assert rep["summary"]["strict_ok"] is True
    assert rep["summary"]["errors"] == 0
    assert rep["kernel_sites"], "quick matrix should include pallas targets"
    assert set(rep["rules"]) == set(RULES)
