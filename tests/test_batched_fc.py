"""The natively batched FC path: batched-pallas == reference oracle ==
old vmap-of-kernels path for all 4 model families × modes under ragged
``n_valid`` mixes; one pallas_call per FC call site (not per cloud); one
executable serves differing ``n_valid``; the hub_reuse −BIG sentinel
never leaks past the merge boundary."""
import zlib
from dataclasses import replace
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.data.synthetic import make_cloud
from repro.engine import Batch, BlockSpec
from repro.models import MODEL_ZOO, dgcnn, pointnet2

KEY = jax.random.PRNGKey(0)

SPECS = {
    "pointnet2": replace(pointnet2.POINTNET2_C, blocks=(
        BlockSpec(48, 8, (16, 32)), BlockSpec(16, 8, (32, 48)))),
    "dgcnn": replace(dgcnn.with_points(dgcnn.DGCNN_C, 96), blocks=(
        BlockSpec(96, 8, (24,), kind="edge", sampler="all"),
        BlockSpec(96, 8, (32,), kind="edge", sampler="all"))),
    "pointnext": replace(MODEL_ZOO["pointnext_s"][1], blocks=(
        BlockSpec(48, 8, (24,)), BlockSpec(16, 8, (32,)))),
    "pointvector": replace(MODEL_ZOO["pointvector_l"][1], blocks=(
        BlockSpec(48, 8, (24,)), BlockSpec(16, 8, (48,)))),
}

# ragged n_valid mixes over N=96 clouds: a plain ragged mix, B=1, and a
# batch containing a (nearly) fully-padded cloud — 1 real point, 95 rows
# of padding — the hardest empty-subset / empty-island corner
RAGGED_MIXES = {
    "mix": [96, 70, 57],
    "b1": [64],
    "fully_padded": [96, 1],
}


def _batch(spec, sizes, seed=0):
    rng = np.random.default_rng(seed)
    n, b = 96, len(sizes)
    xyz = jnp.asarray(np.stack([make_cloud(rng, n) for _ in range(b)]))
    f_in = spec.in_feats
    feats = xyz if f_in == 3 else jnp.concatenate(
        [xyz, jnp.asarray(rng.uniform(0, 1, (b, n, f_in - 3)),
                          jnp.float32)], -1)
    return Batch.make(xyz, feats, key=jax.random.PRNGKey(7),
                      n_valid=jnp.asarray(sizes, jnp.int32))


@pytest.mark.parametrize("mix", sorted(RAGGED_MIXES), ids=str)
@pytest.mark.parametrize("mode", ["traditional", "lpcn"])
@pytest.mark.parametrize("model", sorted(SPECS), ids=str)
def test_batched_pallas_matches_reference_and_vmap(model, mode, mix):
    """Batched-grid pallas == jnp reference (≤1e-4) == the old
    vmap-of-kernels path, under ragged batches."""
    spec = SPECS[model]
    params = engine.init(KEY, spec)
    # deterministic per-case seed (hash() is randomized per process)
    seed = zlib.crc32(f"{model}-{mode}".encode()) % 1000
    b = _batch(spec, RAGGED_MIXES[mix], seed=seed)
    outs = {be: engine.apply(params, b, spec=spec, mode=mode,
                             fc_backend=be)
            for be in ("reference", "pallas", "pallas_vmap")}
    for be, out in outs.items():
        assert bool(jnp.isfinite(out).all()), (model, mode, mix, be)
    np.testing.assert_allclose(np.asarray(outs["pallas"]),
                               np.asarray(outs["reference"]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(outs["pallas"]),
                               np.asarray(outs["pallas_vmap"]),
                               rtol=1e-4, atol=1e-4)


# one implementation of the dispatch-count invariant: the repro.analysis
# jaxpr walker (also used by the scripts/ci.sh batched-kernel smoke and
# the kernel linter)
from repro.analysis import count_pallas_calls as _count_pallas_calls


@pytest.mark.parametrize("mode,per_block", [("traditional", 1),
                                            ("lpcn", 2)])
def test_one_pallas_call_per_fc_block(mode, per_block):
    """engine.apply(fc_backend="pallas") issues exactly one pallas_call
    per FC call site — gather_mlp (+ hub_reuse in lpcn mode) per block —
    with the batch folded into the leading grid axis, independent of B."""
    spec = SPECS["pointnet2"]
    params = engine.init(KEY, spec)
    expected = per_block * len(spec.blocks)
    for bsz in (1, 4):
        b = _batch(spec, [96] * bsz)
        jx = jax.make_jaxpr(partial(engine.apply, spec=spec, mode=mode,
                                    fc_backend="pallas"))(params, b)
        grids = []
        n = _count_pallas_calls(jx.jaxpr, grids)
        assert n == expected, (bsz, n, expected)
        # the batch is IN the grid — not dispatched per cloud
        assert all(g[0] == bsz for g in grids), grids


def test_one_executable_serves_differing_n_valid():
    """n_valid is traced data: one compiled executable serves every
    ragged mix of the same batch shape."""
    spec = SPECS["pointnet2"]
    params = engine.init(KEY, spec)
    f = jax.jit(partial(engine.apply, spec=spec, mode="lpcn",
                        fc_backend="pallas"))
    o1 = f(params, _batch(spec, [96, 50, 96]))
    o2 = f(params, _batch(spec, [20, 96, 77], seed=3))
    assert o1.shape == o2.shape
    assert f._cache_size() == 1
    assert bool(jnp.isfinite(o1).all() and jnp.isfinite(o2).all())


@pytest.mark.parametrize("model", sorted(SPECS), ids=str)
def test_batched_forward_matches_apply_single(model):
    """The documented ragged contract holds for every family's batched
    two-stage forward: apply(batch)[i] (cls) / apply(batch)[i, :nv] (seg)
    == apply_single on cloud i's unpadded prefix (the batched structure
    stage must mirror the per-cloud key-split sequence exactly)."""
    spec = SPECS[model]
    params = engine.init(KEY, spec)
    sizes = [96, 70]
    b = _batch(spec, sizes, seed=11)
    out = engine.apply(params, b, spec=spec, mode="lpcn",
                       fc_backend="reference")
    for i, nv in enumerate(sizes):
        ref, _ = engine.apply_single(
            params, b.xyz[i, :nv], b.feats[i, :nv], b.keys[i], spec=spec,
            mode="lpcn")
        got = out[i] if spec.task == "cls" else out[i, :nv]
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_kernel_kw_rejects_unknown_keys():
    """A typo'd kernel_kw key raises instead of silently measuring the
    untuned heuristic."""
    spec = SPECS["pointnet2"]
    params = engine.init(KEY, spec)
    with pytest.raises(ValueError, match="unknown kernel_kw"):
        engine.apply(params, _batch(spec, [96]), spec=spec,
                     fc_backend="pallas", kernel_kw={"tile_s": 32})


def test_kernel_kw_overrides_tiles():
    """The kernel_kw knob reaches the kernels (different tile sizes, same
    numbers)."""
    spec = SPECS["pointnet2"]
    params = engine.init(KEY, spec)
    b = _batch(spec, [96, 60])
    base = engine.apply(params, b, spec=spec, mode="lpcn",
                        fc_backend="pallas")
    tuned = engine.apply(params, b, spec=spec, mode="lpcn",
                         fc_backend="pallas",
                         kernel_kw={"ts": 4, "th": 2,
                                    "vmem_budget_mb": 2.0})
    np.testing.assert_allclose(np.asarray(tuned), np.asarray(base),
                               rtol=1e-5, atol=1e-5)


# ---- plan equivalence: the autotuner's search space is numerics-safe --------
#
# Every knob combination repro.launch.autotune may promote must produce
# the same outputs as the heuristic plan — tiles only re-block the same
# arithmetic.  Named overrides span the search space's corners: tiny
# tiles forcing grid_tiles > 1 (multi-step grids exercise the index
# maps), sub-128 lane padding with all-"arbitrary" semantics, and a
# mid-size mixed configuration.

PLAN_OVERRIDES = {
    "tiny_tiles": {"ts": 4, "th": 1, "vmem_budget_mb": 2.0},
    "lanes8_arbitrary": {"lanes": 8,
                         "dimension_semantics": ("arbitrary", "arbitrary")},
    "lanes32_mid": {"ts": 8, "th": 2, "lanes": 32},
}


@pytest.mark.parametrize("mode", ["traditional", "lpcn"])
@pytest.mark.parametrize("model", sorted(SPECS), ids=str)
def test_plan_override_equivalence(model, mode):
    """Any feasible (TS, TH, lanes, semantics) override == the heuristic
    plan ≤1e-5, on a ragged batch, for every model family × mode."""
    from repro.kernels import plans

    spec = SPECS[model]
    params = engine.init(KEY, spec)
    seed = zlib.crc32(f"plan-{model}-{mode}".encode()) % 1000
    b = _batch(spec, RAGGED_MIXES["mix"], seed=seed)
    base = engine.apply(params, b, spec=spec, mode=mode,
                        fc_backend="pallas")
    for name, kw in PLAN_OVERRIDES.items():
        with plans.capture() as cap:
            out = engine.apply(params, b, spec=spec, mode=mode,
                               fc_backend="pallas", kernel_kw=kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"{model}/{mode}/{name}")
        assert cap, (model, mode, name)          # planner actually consulted
        assert all(r["plan"]["provenance"] == "override" for r in cap), name


@pytest.mark.parametrize("mix", sorted(RAGGED_MIXES), ids=str)
def test_tiny_tiles_force_multi_step_grid(mix):
    """Deliberately tiny tiles push grid_tiles > 1 — the multi-step grid
    the heuristic never reaches at smoke shapes — and stay equal to the
    single-tile plan across every ragged mix (incl. a fully-padded
    cloud, the empty-subset corner a wrong index map would corrupt)."""
    from repro.kernels import plans

    spec = SPECS["pointnet2"]
    params = engine.init(KEY, spec)
    b = _batch(spec, RAGGED_MIXES[mix], seed=5)
    base = engine.apply(params, b, spec=spec, mode="lpcn",
                        fc_backend="pallas")
    with plans.capture() as cap:
        out = engine.apply(params, b, spec=spec, mode="lpcn",
                           fc_backend="pallas",
                           kernel_kw={"ts": 4, "th": 1})
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=1e-5, atol=1e-5)
    gather = [r["plan"] for r in cap if r["kernel"] == "gather_mlp"]
    hub = [r for r in cap if r["kernel"] == "hub_reuse"]
    assert gather and all(p["grid_tiles"] > 1 for p in gather)
    # th=1 walks every island singly — a single-island site (hn=1) can
    # only ever have one step, so pin grid_tiles == hn instead
    assert hub and all(r["plan"]["grid_tiles"] == r["dims"]["hn"]
                       for r in hub)


def test_kernel_kw_dimension_semantics_validated():
    """Bad semantics raise at EngineCtx construction (not deep inside
    Mosaic); JSON-style lists are canonicalized to hashable tuples."""
    spec = SPECS["pointnet2"]
    params = engine.init(KEY, spec)
    b = _batch(spec, [96])
    for bad in (("parallel",), ("parallel", "sequential"), "parallel"):
        with pytest.raises(ValueError, match="dimension_semantics"):
            engine.apply(params, b, spec=spec, fc_backend="pallas",
                         kernel_kw={"dimension_semantics": bad})
    base = engine.apply(params, b, spec=spec, fc_backend="pallas")
    out = engine.apply(params, b, spec=spec, fc_backend="pallas",
                       kernel_kw={"dimension_semantics":
                                  ["arbitrary", "arbitrary"]})
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=1e-5, atol=1e-5)


def test_hub_reuse_sentinel_guarded_at_merge():
    """Regression (merge-boundary zero-fill): a subset whose positions
    are all cached — so its overflow side is empty (-BIG) — must come
    back finite from fc_lpcn even if the reuse partial itself returns the
    -BIG sentinel, mirroring gather_mlp's empty-subset zero-fill."""
    from repro.core.islandize import Islands
    from repro.core.hub_schedule import Schedule
    from repro.core.mlp import init_mlp
    from repro.core.pipeline import (BIG, FCBackend, LPCNConfig, fc_lpcn,
                                     fc_lpcn_batched)

    S, K, H, M, C, N, Fout = 4, 2, 1, 4, 8, 8, 16
    mlp = init_mlp(jax.random.PRNGKey(1), [3 + 3, 8, Fout])
    rng = np.random.default_rng(0)
    xyz = jnp.asarray(rng.normal(size=(N, 3)), jnp.float32)
    feats = xyz
    nbr = jnp.asarray(rng.integers(0, N, (S, K)), jnp.int32)
    centers = xyz[:S]
    islands = Islands(
        members=jnp.arange(M, dtype=jnp.int32)[None, :],   # (1, M)
        hub=jnp.asarray([0], jnp.int32),
        solo=jnp.zeros((S,), bool),
        round_of=jnp.zeros((S,), jnp.int32))
    sched = Schedule(
        pool_ids=jnp.arange(C, dtype=jnp.int32)[None, :],  # all resident
        reuse_slot=jnp.zeros((H, M, K), jnp.int32),        # all cached
        is_first=jnp.zeros((H, M, K), bool),
        subset_valid=jnp.ones((H, M), bool),
        pos_live=jnp.ones((H, M, K), bool))
    cfg = LPCNConfig(n_centers=S, k=K, mode="lpcn")

    # a backend whose reuse leaks the sentinel (the corner the guard is
    # for); dense returns zeros so fallback rows are visibly finite too
    bad = FCBackend(
        name="bad",
        dense=lambda mlp_, kind, *a, **k: jnp.zeros((S, Fout)),
        reuse=lambda mlp_, pool_in, slot, comp, live=None: jnp.full(
            (H, M, Fout), -BIG))
    out = fc_lpcn(mlp, xyz, feats, nbr, centers, islands, sched, cfg,
                  centers, backend=bad)
    assert bool(jnp.isfinite(out).all())
    # all-cached subsets (no overflow, no fallback) zero-fill exactly
    np.testing.assert_array_equal(np.asarray(out[:M]), 0.0)

    stack = lambda t: jax.tree.map(lambda x: x[None], t)
    out_b = fc_lpcn_batched(mlp, xyz[None], feats[None], nbr[None],
                            centers[None], stack(islands), stack(sched),
                            cfg, centers[None], backend=bad)
    assert bool(jnp.isfinite(out_b).all())
    np.testing.assert_array_equal(np.asarray(out_b[0, :M]), 0.0)


def test_hub_reuse_kernel_keeps_merge_identity():
    """The kernel side of the contract: a subset with zero live positions
    returns exactly -BIG from hub_reuse (the merge identity — NOT zero,
    which would poison max-merges with negative overflow features)."""
    from repro.kernels.hub_reuse.ops import hub_reuse, hub_reuse_batched
    rng = np.random.default_rng(1)
    HN, C, M, K, D, Hd, F = 2, 8, 3, 4, 6, 16, 32
    pool = jnp.asarray(rng.normal(size=(HN, C, D)), jnp.float32)
    slot = jnp.full((HN, M, K), -1, jnp.int32)             # nothing cached
    comp = jnp.zeros((HN, M, F), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(D, Hd)), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(Hd, F)), jnp.float32)
    b1, b2 = jnp.zeros(Hd), jnp.zeros(F)
    sentinel = np.float32(-3.4e38)
    z = hub_reuse(pool, slot, comp, w1, b1, w2, b2)
    np.testing.assert_array_equal(np.asarray(z), sentinel)
    zb = hub_reuse_batched(pool[None], slot[None], comp[None],
                           w1, b1, w2, b2)
    np.testing.assert_array_equal(np.asarray(zb[0]), sentinel)
