"""Property tests for the tiling layer (repro.kernels.tiling) and the
tile planners' emitted-plan invariants — the contracts the autotuner's
candidate enumeration and the K001–K003 lint rules both lean on."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean env: deterministic fallback sampler
    from _hyp import given, settings, strategies as st

from repro.kernels.gather_mlp.ops import gather_mlp_tile_plan
from repro.kernels.hub_reuse.ops import hub_reuse_tile_plan
from repro.kernels.tiling import (DEFAULT_VMEM_BUDGET_MB, F32_BYTES, LANE,
                                  SUBLANE, gather_mlp_footprint_elems,
                                  hub_reuse_footprint_elems, largest_tile,
                                  pad_axis, pad_lanes, round_up)


# ---- round_up ---------------------------------------------------------------

@settings(max_examples=50)
@given(st.integers(1, 10_000), st.integers(1, 512))
def test_round_up_properties(n, m):
    r = round_up(n, m)
    assert r % m == 0
    assert n <= r < n + m
    assert round_up(r, m) == r          # idempotent
    assert round_up(m * 7, m) == m * 7  # exact at multiples


# ---- pad_axis / pad_lanes ---------------------------------------------------

@settings(max_examples=25)
@given(st.integers(1, 17), st.integers(1, 13), st.integers(0, 40))
def test_pad_axis_zero_extends(rows, cols, extra):
    rng = np.random.default_rng(rows * 1000 + cols * 40 + extra)
    x = jnp.asarray(rng.normal(size=(rows, cols)), jnp.float32)
    y = pad_axis(x, 1, cols + extra)
    assert y.shape == (rows, cols + extra)
    assert jnp.array_equal(y[:, :cols], x)
    assert not jnp.any(y[:, cols:])
    if extra == 0:
        assert y is x                   # exact no-op, no copy


@settings(max_examples=25)
@given(st.integers(1, 200), st.integers(0, 1))
def test_pad_lanes_alignment(width, which):
    mult = (SUBLANE, LANE)[which]
    x = jnp.ones((3, width), jnp.float32)
    y = pad_lanes(x, mult)
    assert y.shape[-1] % mult == 0
    assert y.shape[-1] - width < mult


@settings(max_examples=20)
@given(st.integers(1, 40), st.integers(1, 40), st.integers(1, 40))
def test_zero_pad_through_matmul_is_noop(n, d, f):
    """The tiling layer's core legality claim: zero lanes through a
    matmul are exact no-ops, so lane padding never changes the math."""
    rng = np.random.default_rng(n * 1601 + d * 40 + f)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, f)), jnp.float32)
    dp, fp = round_up(d, SUBLANE), round_up(f, LANE)
    xp = pad_axis(x, 1, dp)
    wp = pad_axis(pad_axis(w, 0, dp), 1, fp)
    out = (xp @ wp)[:, :f]
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                               rtol=1e-6, atol=1e-6)


# ---- largest_tile -----------------------------------------------------------

@settings(max_examples=50)
@given(st.integers(1, 1024), st.integers(1, 10_000), st.integers(0, 1))
def test_largest_tile_is_maximal_feasible(limit, cap, which):
    base = (1, SUBLANE)[which]
    fits = lambda t: t <= cap           # any monotone predicate
    t = largest_tile(limit, fits, base=base)
    start = min(base, max(limit, 1))
    assert 1 <= t <= max(limit, 1)
    assert fits(t) or t == 1            # feasible, or the floor tile
    if fits(start):
        # ladder path: power-of-two multiple of the start tile, maximal
        # (the next rung busts the limit or the budget)
        q = t // start
        assert t == start * q and q & (q - 1) == 0
        assert (t * 2 > limit) or not fits(t * 2)
    else:
        # halving path: repeated floor-halving of the start tile
        assert any(t == max(start >> j, 1) for j in range(start.bit_length()))


# ---- emitted plans: gather_mlp ----------------------------------------------

@settings(max_examples=15)
@given(st.integers(1, 300), st.integers(1, 48), st.integers(1, 64),
       st.integers(1, 8), st.integers(1, 96), st.integers(1, 160))
def test_gather_plan_invariants(s, k, d, dc, h, f):
    plan = gather_mlp_tile_plan(s, k, d, dc, h, f)
    ts, lanes = plan["ts"], plan["lanes"]
    assert plan["provenance"] == "heuristic"
    assert lanes == LANE
    for key, dim in (("d_pad", d), ("h_pad", h), ("f_pad", f)):
        assert plan[key] % lanes == 0 and plan[key] >= dim
    assert 1 <= ts <= max(s, 1)
    assert ts % SUBLANE == 0 or ts < SUBLANE or ts == s
    assert plan["grid_tiles"] * ts >= s          # full grid coverage
    budget = int(plan["vmem_budget_mb"] * 2 ** 20)
    assert plan["footprint_bytes"] == F32_BYTES * gather_mlp_footprint_elems(
        ts, k, plan["d_pad"], dc, plan["h_pad"], plan["f_pad"])
    assert plan["footprint_bytes"] <= budget or ts == 1


@settings(max_examples=15)
@given(st.integers(1, 80), st.integers(1, 24), st.integers(0, 2))
def test_gather_plan_override_invariants(s, ts, which):
    lanes = (8, 32, LANE)[which]
    plan = gather_mlp_tile_plan(s, 8, 35, 3, 64, 128, ts=ts, lanes=lanes,
                                dimension_semantics=("arbitrary",
                                                     "arbitrary"))
    assert plan["provenance"] == "override"
    assert plan["ts"] == min(max(ts, 1), s)      # clamped into [1, s]
    assert plan["lanes"] == lanes
    assert tuple(plan["dimension_semantics"]) == ("arbitrary", "arbitrary")
    for key in ("d_pad", "h_pad", "f_pad"):
        assert plan[key] % lanes == 0
    assert plan["grid_tiles"] * plan["ts"] >= s


# ---- emitted plans: hub_reuse -----------------------------------------------

@settings(max_examples=15)
@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 32),
       st.integers(1, 32), st.integers(1, 64), st.integers(1, 96),
       st.integers(1, 160))
def test_hub_plan_invariants(hn, c, m, k, d, h, f):
    plan = hub_reuse_tile_plan(hn, c, m, k, d, h, f)
    th, lanes = plan["th"], plan["lanes"]
    assert plan["provenance"] == "heuristic"
    assert lanes == LANE
    for key, dim in (("d_pad", d), ("h_pad", h), ("f_pad", f)):
        assert plan[key] % lanes == 0 and plan[key] >= dim
    assert 1 <= th <= max(hn, 1)
    assert plan["grid_tiles"] * th >= hn         # full grid coverage
    budget = int(plan["vmem_budget_mb"] * 2 ** 20)
    assert plan["footprint_bytes"] == F32_BYTES * hub_reuse_footprint_elems(
        th, c, m, k, plan["d_pad"], plan["h_pad"], plan["f_pad"])
    assert plan["footprint_bytes"] <= budget or th == 1


@settings(max_examples=15)
@given(st.integers(1, 32), st.integers(1, 48), st.integers(0, 2))
def test_hub_plan_override_invariants(hn, th, which):
    lanes = (8, 32, LANE)[which]
    plan = hub_reuse_tile_plan(hn, 32, 16, 8, 35, 64, 128, th=th,
                               lanes=lanes)
    assert plan["provenance"] == "override"
    assert plan["th"] == min(max(th, 1), hn)
    assert plan["lanes"] == lanes
    for key in ("d_pad", "h_pad", "f_pad"):
        assert plan[key] % lanes == 0
    assert plan["grid_tiles"] * plan["th"] >= hn


def test_default_budget_is_the_planners_default():
    plan = gather_mlp_tile_plan(64, 8, 35, 3, 64, 128)
    assert plan["vmem_budget_mb"] == DEFAULT_VMEM_BUDGET_MB
    tight = gather_mlp_tile_plan(64, 8, 35, 3, 64, 128, vmem_budget_mb=0.5)
    assert tight["vmem_budget_mb"] == 0.5
    assert tight["provenance"] == "heuristic"    # budget alone: no override
    assert tight["ts"] <= plan["ts"]
    assert tight["footprint_bytes"] <= int(0.5 * 2 ** 20) or tight["ts"] == 1
