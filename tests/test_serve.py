"""Continuous-batching serving layer: bucket policy, admission, dispatch
triggers (batch-full / timeout), exactly-once responses numerically equal
to per-cloud apply_single, compile-once per bucket, and the metrics
report — plus the hardened-failure layer: the admission guard
(validation, bounded lanes), fault isolation (fallback retry, circuit
breaker), deadlines, and deterministic chaos via FaultPlan."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine, serve
from repro.analysis import compile_cache_size
from repro.data.synthetic import make_cloud
from repro.engine import BlockSpec
from repro.models import pointnet2
from repro.serve import (AdmissionError, Bucket, BucketSet, FaultPlan,
                         PCNServer, QueueFullError, RequestError,
                         ServeMetrics, UnknownRequestError, ValidationError,
                         percentile_summary, synthetic_trace)

SPEC = replace(pointnet2.POINTNET2_C, blocks=(
    BlockSpec(24, 8, (16, 32)), BlockSpec(8, 8, (32, 48))))
BUCKETS = BucketSet.make([64, 96], batch=2)


class FakeClock:
    """Deterministic clock so timeout policy is testable without sleeps."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def eng_params():
    eng = engine.PCNEngine(SPEC, mode="lpcn", fc_backend="reference")
    return eng, eng.init(jax.random.PRNGKey(0))


def _cloud(n, seed=0):
    return np.asarray(make_cloud(np.random.default_rng(seed), n),
                      np.float32)


# ---- bucket policy ----------------------------------------------------------

def test_bucket_for_picks_tightest():
    bs = BucketSet.make([64, 96, 128], batch=4)
    assert bs.bucket_for(1).n_points == 64
    assert bs.bucket_for(64).n_points == 64
    assert bs.bucket_for(65).n_points == 96
    assert bs.bucket_for(128).n_points == 128


def test_bucket_admission_errors():
    bs = BucketSet.make([64], batch=4)
    with pytest.raises(AdmissionError, match="largest bucket is 64"):
        bs.bucket_for(65)
    with pytest.raises(AdmissionError, match="n >= 1"):
        bs.bucket_for(0)
    with pytest.raises(ValueError, match="duplicate bucket"):
        BucketSet.make([64, 64], batch=4)


def test_bucket_plan_quantiles_aligned():
    sizes = [50] * 90 + [500] * 10
    bs = BucketSet.plan(sizes, n_buckets=2, batch=4, align=64)
    assert all(b.n_points % 64 == 0 for b in bs)
    assert bs.max_points >= 500          # top edge covers the sample
    assert bs.buckets[0].n_points >= 50  # tight edge covers the mass


# ---- dispatch policy --------------------------------------------------------

def test_batch_full_fires_immediately(eng_params):
    """Reaching bucket capacity fires inside submit — no poll needed.
    (sync mode: the test asserts resolution immediately after
    submit returns.)"""
    eng, params = eng_params
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=10.0, clock=clock,
                    sync=True)
    r0 = srv.submit(_cloud(60, 0))
    assert not srv.ready(r0) and srv.pending() == 1
    r1 = srv.submit(_cloud(50, 1))       # same 64-bucket: batch full
    assert srv.ready(r0) and srv.ready(r1) and srv.pending() == 0
    assert srv.metrics.dispatches[-1].partial is False


def test_timeout_fires_partial_no_starvation(eng_params):
    """A lone request must be answered one timeout after arrival, by a
    partial batch padded with masked fill rows — not starve waiting for
    a batch that will never fill."""
    eng, params = eng_params
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=0.5, clock=clock,
                    sync=True)
    rid = srv.submit(_cloud(80, 2))      # 96-bucket, alone
    assert srv.poll() == []              # not due yet
    clock.advance(0.49)
    assert srv.poll() == []              # still inside the timeout
    clock.advance(0.02)
    assert srv.poll() == [rid]           # due: partial batch fires
    d = srv.metrics.dispatches[-1]
    assert d.partial and d.n_requests == 1 and d.bucket == (2, 96)
    rec = srv.metrics.requests[-1]
    assert rec.queue_wait_s == pytest.approx(0.51)


def test_fifo_within_bucket(eng_params):
    """Dispatch drains a lane front-first: the oldest requests ride the
    first batch."""
    eng, params = eng_params
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=10.0, clock=clock,
                    sync=True)
    rids = [srv.submit(_cloud(40, s)) for s in range(3)]
    # first two filled a batch and fired; the third still queues
    assert srv.ready(rids[0]) and srv.ready(rids[1])
    assert not srv.ready(rids[2]) and srv.pending() == 1
    assert srv.drain() == [rids[2]]


def test_admission_rejects_bad_requests(eng_params):
    eng, params = eng_params
    srv = PCNServer(eng, params, BUCKETS, timeout_s=1.0,
                    clock=FakeClock())
    with pytest.raises(AdmissionError, match="largest bucket"):
        srv.submit(_cloud(97))
    with pytest.raises(AdmissionError, match="n >= 1"):
        srv.submit(np.zeros((0, 3), np.float32))
    with pytest.raises(AdmissionError, match=r"\(N, 3\)"):
        srv.submit(np.zeros((4, 2), np.float32))
    assert srv.pending() == 0            # rejected requests never queue


def test_exactly_once_and_equivalence(eng_params):
    """Every admitted request is answered exactly once, with logits
    equal to engine.apply_single on its own cloud and key — including
    requests answered by a timeout-fired partial batch (fill rows are
    fully masked)."""
    eng, params = eng_params
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=0.1, clock=clock,
                    sync=True)
    sizes = (60, 90, 33, 64, 72)         # spans both buckets, odd count
    clouds = [_cloud(n, seed=10 + i) for i, n in enumerate(sizes)]
    keys = [jax.random.PRNGKey(100 + i) for i in range(len(sizes))]
    rids = [srv.submit(c, key=k) for c, k in zip(clouds, keys)]
    clock.advance(1.0)
    srv.poll()                           # leftovers fire as partials
    assert srv.pending() == 0
    assert srv.metrics.report()["partial_batches"] >= 1
    for rid, cloud, key in zip(rids, clouds, keys):
        got = srv.take(rid)
        ref, _ = eng.apply_single(params, jnp.asarray(cloud), key=key)
        np.testing.assert_allclose(got, np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        with pytest.raises(KeyError):    # answered exactly once
            srv.take(rid)


# ---- compile-once per bucket ------------------------------------------------

def test_compile_once_per_bucket():
    """A ragged trace spanning two buckets costs exactly one engine
    compilation per (bucket, spec, mode, backend), independent of the
    n_valid mix (the compile-count probe is repro.analysis's
    compile_cache_size — the jit cache size IS the compile count)."""
    eng = engine.PCNEngine(SPEC, mode="lpcn", fc_backend="reference")
    params = eng.init(jax.random.PRNGKey(1))
    assert compile_cache_size(eng) == 0
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=0.1, clock=clock)
    assert compile_cache_size(eng) == len(BUCKETS)   # warmup: one per bucket
    rng = np.random.default_rng(3)
    for n in (40, 64, 90, 17, 96, 65, 1, 50):     # every n_valid different
        srv.submit(_cloud(int(n), seed=int(rng.integers(1 << 30))))
        clock.advance(0.2)
        srv.poll()
    srv.drain()
    assert srv.pending() == 0
    used = {r.bucket for r in srv.metrics.requests}
    assert used == {(2, 64), (2, 96)}             # trace spanned both
    assert compile_cache_size(eng) == len(BUCKETS)   # compiled nothing new
    # the report records the same count
    assert srv.report()["compile_count"] == len(BUCKETS)


def test_lazy_warmup_compiles_on_first_use():
    eng = engine.PCNEngine(SPEC, mode="lpcn", fc_backend="reference")
    params = eng.init(jax.random.PRNGKey(2))
    srv = PCNServer(eng, params, BUCKETS, timeout_s=10.0,
                    clock=FakeClock(), warmup=False, sync=True)
    assert compile_cache_size(eng) == 0
    for s in range(2):
        srv.submit(_cloud(60, seed=20 + s))       # fills the 64-bucket
    assert compile_cache_size(eng) == 1              # only the used bucket


# ---- mesh validation --------------------------------------------------------

def test_rejects_buckets_not_dividing_mesh(eng_params):
    from repro.launch.mesh import local_mesh
    eng = engine.PCNEngine(SPEC, mode="lpcn", mesh=local_mesh())
    n_data = dict(eng.mesh.shape)["data"]
    if n_data == 1:                      # 1-device host: everything divides
        PCNServer(eng, eng_params[1], BucketSet.make([64], batch=3),
                  warmup=False)
        return
    with pytest.raises(ValueError, match="data mesh"):
        PCNServer(eng, eng_params[1],
                  BucketSet.make([64], batch=n_data + 1), warmup=False)


# ---- metrics ----------------------------------------------------------------

def test_percentile_summary_monotone():
    lat = percentile_summary(list(range(1, 101)))
    assert lat["p50"] <= lat["p95"] <= lat["p99"] <= lat["max"]
    empty = percentile_summary([])
    assert empty == {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0,
                     "max": 0.0}


def test_padding_waste_accounting():
    """Waste counts both row padding (Ni < N) and batch-fill slots."""
    m = ServeMetrics()
    b = Bucket(2, 100)
    # full batch: 60 + 40 valid of 200 padded
    m.record_dispatch(b, [(0, 60, 0.0), (1, 40, 0.0)], 1.0, 2.0)
    # partial batch: 50 valid of 200 padded (one whole fill row)
    m.record_dispatch(b, [(2, 50, 0.5)], 1.0, 2.0)
    rep = m.report()
    assert rep["requests"] == 3 and rep["dispatches"] == 2
    assert rep["full_batches"] == 1 and rep["partial_batches"] == 1
    assert rep["padding_waste_pct"] == pytest.approx(
        100.0 * (1 - 150 / 400))
    assert rep["per_bucket"]["2x100"] == {
        "dispatches": 2, "partial": 1, "requests": 3, "degraded": 0}
    # queue_wait of rid 2: dispatched at 1.0, arrived 0.5
    rec = [r for r in m.requests if r.rid == 2][0]
    assert rec.queue_wait_s == pytest.approx(0.5)
    assert rec.e2e_s == pytest.approx(1.5)


# ---- admission guard (validation + backpressure) ----------------------------

def test_validation_rejects_poisoned_clouds(eng_params):
    """NaN/Inf payloads are refused at the door with a structured
    ValidationError (and counted), long before any compiled kernel."""
    eng, params = eng_params
    srv = PCNServer(eng, params, BUCKETS, timeout_s=1.0, clock=FakeClock())
    bad = _cloud(50)
    bad[3, 1] = np.nan
    with pytest.raises(ValidationError, match="non-finite"):
        srv.submit(bad)
    inf = _cloud(50)
    inf[0, 0] = np.inf
    with pytest.raises(ValidationError, match="non-finite"):
        srv.submit(inf)
    with pytest.raises(ValidationError, match="not a floating point"):
        srv.submit(np.zeros((10, 3), np.int32))
    assert srv.pending() == 0
    assert srv.report()["faults"]["rejected_invalid"] == 3


def test_validation_coerces_float64(eng_params):
    """float64 clouds are coerced (not trusted to implicit downcasts)
    and serve normally."""
    eng, params = eng_params
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=0.1, clock=clock)
    key = jax.random.PRNGKey(5)
    rid = srv.submit(_cloud(40, 3).astype(np.float64), key=key)
    clock.advance(1.0)
    srv.poll()
    ref, _ = eng.apply_single(params, jnp.asarray(_cloud(40, 3)), key=key)
    np.testing.assert_allclose(srv.take(rid), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_bounded_lane_sheds_on_full_fifo(eng_params):
    """A lane at max_lane_depth sheds the NEWEST submit (tail drop)
    with QueueFullError; admitted requests keep their FIFO order and
    are all answered."""
    eng, params = eng_params
    clock = FakeClock()
    srv = PCNServer(eng, params, BucketSet.make([64], batch=4),
                    timeout_s=100.0, clock=clock, max_lane_depth=2)
    r0 = srv.submit(_cloud(30, 0))
    r1 = srv.submit(_cloud(30, 1))
    with pytest.raises(QueueFullError, match="lane is full"):
        srv.submit(_cloud(30, 2))
    with pytest.raises(QueueFullError):
        srv.submit(_cloud(30, 3))
    assert srv.pending() == 2            # shed requests never queued
    assert srv.drain() == [r0, r1]       # FIFO preserved for admitted
    assert srv.ready(r0) and srv.ready(r1)
    rep = srv.report()
    assert rep["faults"]["shed_queue_full"] == 2
    assert rep["requests"] == 2          # latency stats: admitted only


# ---- exactly-once bookkeeping (typed) ---------------------------------------

def test_take_unknown_rid_diagnosis(eng_params):
    """take() raises UnknownRequestError (a KeyError) with a hint that
    distinguishes pending / already-taken / never-submitted."""
    eng, params = eng_params
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=10.0, clock=clock)
    with pytest.raises(UnknownRequestError, match="never submitted"):
        srv.take(123)
    rid = srv.submit(_cloud(40, 1))
    with pytest.raises(KeyError):        # taxonomy keeps KeyError compat
        srv.take(rid)
    with pytest.raises(UnknownRequestError, match="still pending"):
        srv.take(rid)
    srv.drain()
    srv.take(rid)
    with pytest.raises(UnknownRequestError, match="already taken"):
        srv.take(rid)


# ---- fault isolation --------------------------------------------------------

def test_injected_failure_isolated_and_degraded(eng_params):
    """Chaos plan fails one batch mid-trace: untouched batches answer
    from the primary, the failed batch is retried exactly once on the
    fallback backend, and EVERY request still equals apply_single."""
    eng, params = eng_params
    clock = FakeClock()
    plan = FaultPlan.parse("fail@1")
    srv = PCNServer(eng, params, BUCKETS, timeout_s=0.1, clock=clock,
                    faults=plan, sync=True)
    keys = [jax.random.PRNGKey(100 + i) for i in range(6)]
    clouds = [_cloud(60, 30 + i) for i in range(6)]
    rids = [srv.submit(c, key=k) for c, k in zip(clouds, keys)]
    assert srv.pending() == 0            # three full batches, all fired
    assert plan.injected == [(1, "fail")]
    for rid, c, k in zip(rids, clouds, keys):
        ref, _ = eng.apply_single(params, jnp.asarray(c), key=k)
        np.testing.assert_allclose(srv.take(rid), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
    rep = srv.report()
    assert rep["faults"]["degraded_dispatches"] == 1
    assert rep["faults"]["failed_requests"] == 0
    assert rep["per_bucket"]["2x64"]["degraded"] == 1


def test_nan_poisoned_output_detected(eng_params):
    """A backend returning NaNs (nothing raised!) is a fault: detected,
    retried on the fallback, answered correctly."""
    eng, params = eng_params
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=0.1, clock=clock,
                    faults=FaultPlan.parse("nan@0"))
    key = jax.random.PRNGKey(7)
    rid0 = srv.submit(_cloud(50, 40), key=key)
    srv.submit(_cloud(50, 41))           # fills the batch -> fires
    got = srv.take(rid0)
    assert np.isfinite(got).all()
    ref, _ = eng.apply_single(params, jnp.asarray(_cloud(50, 40)), key=key)
    np.testing.assert_allclose(got, np.asarray(ref), rtol=1e-5, atol=1e-5)
    assert srv.report()["faults"]["degraded_dispatches"] == 1


def test_failure_without_fallback_surfaces_request_error(eng_params):
    """fallback=None: the failed batch's requests surface a structured
    RequestError via take (never forever-pending), other batches are
    untouched."""
    eng, params = eng_params
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=0.1, clock=clock,
                    faults=FaultPlan.parse("fail@0"), fallback=None,
                    sync=True)
    r0 = srv.submit(_cloud(50, 0))
    r1 = srv.submit(_cloud(50, 1))       # same batch: fails with r0
    r2 = srv.submit(_cloud(50, 2))
    r3 = srv.submit(_cloud(50, 3))       # second batch: healthy
    assert srv.pending() == 0
    assert srv.ready(r0) and srv.failed(r0) and srv.failed(r1)
    assert not srv.failed(r2) and not srv.failed(r3)
    with pytest.raises(RequestError, match="engine") as ei:
        srv.take(r0)
    assert ei.value.rid == r0 and ei.value.bucket == (2, 64)
    assert "InjectedFault" in ei.value.cause
    assert not ei.value.degraded_attempted
    with pytest.raises(RequestError):
        srv.take(r1)
    # failures pop exactly once, like responses
    with pytest.raises(UnknownRequestError, match="already taken"):
        srv.take(r0)
    assert np.isfinite(srv.take(r2)).all()
    rep = srv.report()
    assert rep["faults"]["failed_dispatches"] == 1
    assert rep["faults"]["failed_requests"] == 2


def test_breaker_opens_and_half_open_probe(eng_params):
    """Deterministic breaker walk under a FakeClock: K consecutive
    primary failures open the bucket's breaker (dispatches then skip
    the primary entirely — degraded, and the fault plan's step counter
    proves the primary was never called); after the cooldown a
    half-open probe finds the primary healthy and closes it."""
    eng, params = eng_params
    clock = FakeClock()
    plan = FaultPlan.parse("fail@0,fail@1")
    srv = PCNServer(eng, params, BucketSet.make([64], batch=2),
                    timeout_s=0.1, clock=clock, faults=plan,
                    breaker_fail_streak=2, breaker_cooldown_s=5.0,
                    sync=True)
    br = srv.breakers[(2, 64)]
    for i in range(4):                   # two batches, both injected
        srv.submit(_cloud(30, i))
    assert br.state == "open" and br.open_count == 1
    assert srv.report()["faults"]["breaker_opened"] == 1
    # open: dispatch goes straight to the fallback; primary untouched
    step_before = plan.step
    srv.submit(_cloud(30, 8))
    srv.submit(_cloud(30, 9))
    assert plan.step == step_before
    assert br.state == "open"
    # cooldown elapses -> half-open probe on the (now healthy) primary
    clock.advance(6.0)
    srv.submit(_cloud(30, 10))
    srv.submit(_cloud(30, 11))
    assert plan.step == step_before + 1  # the probe ran the primary
    assert br.state == "closed" and br.failures == 0
    # every request got a real answer throughout
    for rid in range(8):
        assert np.isfinite(srv.take(rid)).all()
    assert srv.report()["faults"]["degraded_dispatches"] == 3


def test_breaker_reopens_on_failed_probe(eng_params):
    """A half-open probe that fails re-opens the breaker (fresh
    cooldown) instead of closing it."""
    eng, params = eng_params
    clock = FakeClock()
    plan = FaultPlan.parse("fail@0,fail@1,fail@2")
    srv = PCNServer(eng, params, BucketSet.make([64], batch=2),
                    timeout_s=0.1, clock=clock, faults=plan,
                    breaker_fail_streak=2, breaker_cooldown_s=5.0,
                    sync=True)
    br = srv.breakers[(2, 64)]
    for i in range(4):
        srv.submit(_cloud(30, i))
    assert br.state == "open"
    clock.advance(6.0)
    srv.submit(_cloud(30, 5))
    srv.submit(_cloud(30, 6))            # probe consumes fail@2 -> reopen
    assert br.state == "open" and br.open_count == 2


def test_circuit_open_without_fallback_fails_fast(eng_params):
    """Open breaker + no fallback: requests fail fast with
    reason='circuit_open' — no engine call, no spinning."""
    eng, params = eng_params
    clock = FakeClock()
    plan = FaultPlan.parse("fail@0")
    srv = PCNServer(eng, params, BucketSet.make([64], batch=2),
                    timeout_s=0.1, clock=clock, faults=plan,
                    fallback=None, breaker_fail_streak=1,
                    breaker_cooldown_s=100.0, sync=True)
    srv.submit(_cloud(30, 0))
    srv.submit(_cloud(30, 1))            # breaker trips
    step_before = plan.step
    r2 = srv.submit(_cloud(30, 2))
    srv.submit(_cloud(30, 3))
    assert plan.step == step_before      # primary never called
    with pytest.raises(RequestError, match="circuit_open"):
        srv.take(r2)


# ---- deadlines --------------------------------------------------------------

def test_deadline_shed_at_poll(eng_params):
    """An expired queued request is shed at poll time — RequestError
    with reason='deadline', deadline_miss counted, no device compute
    spent — while unexpired queued requests still dispatch and answer."""
    eng, params = eng_params
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=100.0, clock=clock,
                    deadline_s=1.0)
    r0 = srv.submit(_cloud(40, 0))                   # default 1s deadline
    clock.advance(0.5)
    r1 = srv.submit(_cloud(90, 1), deadline_s=10.0)  # other bucket, own TTL
    clock.advance(1.0)                   # r0 expired, r1 alive
    resolved = srv.poll()
    assert r0 in resolved
    with pytest.raises(RequestError, match="deadline"):
        srv.take(r0)
    assert srv.pending() == 1            # r1 still queued, not shed
    srv.drain()
    assert np.isfinite(srv.take(r1)).all()
    rep = srv.report()
    assert rep["faults"]["deadline_miss"] == 1
    assert rep["requests"] == 1          # only r1 reached a dispatch


def test_drain_sheds_expired_and_clears_pending(eng_params):
    eng, params = eng_params
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=100.0, clock=clock)
    srv.submit(_cloud(40, 0), deadline_s=0.5)
    srv.submit(_cloud(90, 1), deadline_s=0.5)        # other bucket
    clock.advance(1.0)
    srv.drain()
    assert srv.pending() == 0
    assert srv.report()["faults"]["deadline_miss"] == 2


# ---- chaos trace: the acceptance criterion ----------------------------------

def test_chaos_trace_acceptance(eng_params):
    """The ISSUE-8 acceptance walk: under a seeded FaultPlan that fails
    >= 1 batch mid-trace, every non-injected request is answered equal
    to apply_single (<= 1e-5), injected ones surface structured errors,
    nothing deadlocks or leaks (pending() == 0 after drain), and the
    report records the shed/deadline/degraded counters."""
    eng, params = eng_params
    clock = FakeClock()
    plan = FaultPlan.bernoulli(seed=3, n_steps=8, p_fail=0.3)
    assert plan.events                    # the seed does schedule faults
    srv = PCNServer(eng, params, BUCKETS, timeout_s=0.1, clock=clock,
                    faults=plan, fallback=None, sync=True)
    sizes = (60, 90, 33, 64, 72, 96, 17, 50)
    clouds = [_cloud(n, seed=60 + i) for i, n in enumerate(sizes)]
    keys = [jax.random.PRNGKey(200 + i) for i in range(len(sizes))]
    rids = []
    for c, k in zip(clouds, keys):
        rids.append(srv.submit(c, key=k))
        clock.advance(0.2)
        srv.poll()
    srv.drain()
    assert srv.pending() == 0            # no leaked rids
    n_failed = 0
    for rid, c, k in zip(rids, clouds, keys):
        assert srv.ready(rid)            # every request has an outcome
        if srv.failed(rid):
            n_failed += 1
            with pytest.raises(RequestError) as ei:
                srv.take(rid)
            assert ei.value.rid == rid and ei.value.reason == "engine"
        else:
            ref, _ = eng.apply_single(params, jnp.asarray(c), key=k)
            np.testing.assert_allclose(srv.take(rid), np.asarray(ref),
                                       rtol=1e-5, atol=1e-5)
    rep = srv.report()
    assert n_failed >= 1                 # >= 1 batch really failed
    assert rep["faults"]["failed_requests"] == n_failed
    assert set(serve.FAULT_COUNTERS) <= set(rep["faults"])
    assert rep["fault_plan"]["injected"]  # the plan is in the report
    # rerunning the same seeded plan injects at the same steps
    plan2 = FaultPlan.bernoulli(seed=3, n_steps=8, p_fail=0.3)
    assert plan2.events == plan.events


def test_chaos_trace_with_fallback_answers_everything(eng_params):
    """Same chaos, fallback enabled: every request is answered exactly
    (the degraded path is numerically the reference backend)."""
    eng, params = eng_params
    clock = FakeClock()
    plan = FaultPlan.bernoulli(seed=3, n_steps=8, p_fail=0.3)
    srv = PCNServer(eng, params, BUCKETS, timeout_s=0.1, clock=clock,
                    faults=plan, sync=True)
    sizes = (60, 90, 33, 64, 72, 96, 17, 50)
    clouds = [_cloud(n, seed=60 + i) for i, n in enumerate(sizes)]
    keys = [jax.random.PRNGKey(200 + i) for i in range(len(sizes))]
    rids = []
    for c, k in zip(clouds, keys):
        rids.append(srv.submit(c, key=k))
        clock.advance(0.2)
        srv.poll()
    srv.drain()
    assert srv.pending() == 0
    for rid, c, k in zip(rids, clouds, keys):
        ref, _ = eng.apply_single(params, jnp.asarray(c), key=k)
        np.testing.assert_allclose(srv.take(rid), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
    rep = srv.report()
    assert rep["faults"]["degraded_dispatches"] >= 1
    assert rep["faults"]["failed_requests"] == 0


# ---- async in-flight dispatch ----------------------------------------------

def test_async_inflight_failure_resolves_request_error(eng_params):
    """An in-flight batch that fails (no fallback) resolves to the same
    structured RequestError at completion; take() blocks on the
    in-flight rid and then raises it."""
    eng, params = eng_params
    srv = PCNServer(eng, params, BUCKETS, timeout_s=0.1,
                    clock=FakeClock(), faults=FaultPlan.parse("fail@0"),
                    fallback=None)
    r0 = srv.submit(_cloud(50, 0))
    r1 = srv.submit(_cloud(50, 1))       # batch full -> fires in flight
    with pytest.raises(RequestError, match="engine") as ei:
        srv.take(r0)                     # blocks until completion
    assert ei.value.rid == r0 and "InjectedFault" in ei.value.cause
    with pytest.raises(RequestError):
        srv.take(r1)
    assert srv.pending() == 0
    rep = srv.report()
    assert rep["faults"]["failed_dispatches"] == 1
    assert rep["faults"]["failed_requests"] == 2


def test_async_breaker_trips_at_completion(eng_params):
    """Breaker verdicts land when in-flight batches complete: two
    injected failures joined by drain() trip the breaker exactly as in
    sync mode, and every request still gets a (degraded) answer."""
    eng, params = eng_params
    clock = FakeClock()
    plan = FaultPlan.parse("fail@0,fail@1")
    srv = PCNServer(eng, params, BucketSet.make([64], batch=2),
                    timeout_s=0.1, clock=clock, faults=plan,
                    breaker_fail_streak=2, breaker_cooldown_s=5.0)
    rids = [srv.submit(_cloud(30, i)) for i in range(4)]
    srv.drain()                          # joins the in-flight batches
    br = srv.breakers[(2, 64)]
    assert br.state == "open" and br.open_count == 1
    rep = srv.report()
    assert rep["faults"]["breaker_opened"] == 1
    assert rep["faults"]["degraded_dispatches"] == 2
    for rid in rids:
        assert np.isfinite(srv.take(rid)).all()


def test_async_deadline_expires_in_flight(eng_params):
    """Deadlines are enforced at completion time: a slow in-flight
    batch whose answers materialize past the deadline resolves them to
    RequestError(reason='deadline') — same counters as a queue-side
    shed — instead of handing back answers nobody waits for."""
    eng, params = eng_params
    clock = FakeClock()
    plan = FaultPlan.parse("slow@0:500", sleep=clock.advance)
    srv = PCNServer(eng, params, BUCKETS, timeout_s=0.1, clock=clock,
                    faults=plan)
    r0 = srv.submit(_cloud(50, 0), deadline_s=0.2)
    r1 = srv.submit(_cloud(50, 1), deadline_s=0.2)   # fires in flight
    srv.drain()
    for rid in (r0, r1):
        with pytest.raises(RequestError, match="deadline"):
            srv.take(rid)
    rep = srv.report()
    assert rep["faults"]["deadline_miss"] == 2
    assert rep["requests"] == 0          # no late answer was recorded


def test_async_drain_quiescence_no_leaked_futures(eng_params):
    """drain() joins everything: pending() == 0, the in-flight table
    and rid set are empty, every rid has an outcome, close() is clean.
    With max_in_flight=2 half the fires wait for a completion to pump
    them — the bound itself is exercised."""
    eng, params = eng_params
    srv = PCNServer(eng, params, BUCKETS, timeout_s=0.1,
                    clock=FakeClock(), max_in_flight=2)
    rids = [srv.submit(_cloud(40 + i, i)) for i in range(8)]
    srv.drain()
    assert srv.pending() == 0
    assert not srv._inflight and not srv._inflight_rids
    for rid in rids:
        assert srv.ready(rid)
        assert np.isfinite(srv.take(rid)).all()
    srv.close()
    assert srv._pool is None


def test_async_submit_overlaps_slow_inflight(eng_params):
    """The overlap the async layer exists for: while one bucket's batch
    stalls in flight, admission keeps landing and the other bucket
    dispatches, completes and is taken — nothing serializes behind the
    stall."""
    import threading as _threading
    eng, params = eng_params
    release = _threading.Event()
    plan = FaultPlan.parse("slow@0:1",
                           sleep=lambda _dt: release.wait(10.0))
    srv = PCNServer(eng, params, BUCKETS, timeout_s=10.0,
                    clock=FakeClock(), faults=plan)
    r0 = srv.submit(_cloud(40, 0))
    srv.submit(_cloud(40, 1))            # 64-bucket fires, then stalls
    r2 = srv.submit(_cloud(90, 2))
    srv.submit(_cloud(90, 3))            # 96-bucket fires concurrently
    out2 = srv.take(r2)                  # resolves during the stall
    assert np.isfinite(out2).all()
    assert not srv.ready(r0)             # the stalled batch: in flight
    release.set()
    srv.drain()
    assert np.isfinite(srv.take(r0)).all()
    assert srv.report()["overlap"]["inflight_depth_max"] >= 2


def test_async_chaos_equivalence_multi_inflight(eng_params):
    """Async chaos walk with several batches genuinely in flight: every
    request (fallback recovers the injected ones) still equals
    apply_single <= 1e-5 and the fault accounting matches the plan —
    identical semantics to the sync walk."""
    eng, params = eng_params
    clock = FakeClock()
    plan = FaultPlan.bernoulli(seed=7, n_steps=8, p_fail=0.2, p_nan=0.2)
    assert plan.events                   # the seed schedules faults
    srv = PCNServer(eng, params, BUCKETS, timeout_s=10.0, clock=clock,
                    faults=plan, max_in_flight=4,
                    breaker_fail_streak=99)  # keep every draw on the
                                             # primary: injected ==
                                             # degraded, order-free
    sizes = (60, 50, 90, 70, 33, 64, 96, 40, 72, 55, 80, 44, 61, 91)
    clouds = [_cloud(n, seed=80 + i) for i, n in enumerate(sizes)]
    keys = [jax.random.PRNGKey(300 + i) for i in range(len(sizes))]
    rids = [srv.submit(c, key=k) for c, k in zip(clouds, keys)]
    srv.drain()
    assert srv.pending() == 0
    for rid, c, k in zip(rids, clouds, keys):
        ref, _ = eng.apply_single(params, jnp.asarray(c), key=k)
        np.testing.assert_allclose(srv.take(rid), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
    rep = srv.report()
    assert rep["faults"]["failed_requests"] == 0
    assert rep["faults"]["degraded_dispatches"] == len(plan.injected)
    assert rep["faults"]["degraded_dispatches"] >= 1
    assert rep["overlap"]["inflight_depth_max"] >= 1
    assert rep["dispatch_mode"] == "async" and rep["max_in_flight"] == 4


def test_fault_plan_parse_and_slow():
    plan = FaultPlan.parse("fail@1,nan@3,slow@5:80")
    assert plan.events[1].kind == "fail"
    assert plan.events[3].kind == "nan"
    assert plan.events[5] == serve.Fault("slow", 80.0)
    with pytest.raises(ValueError, match="bad fault item"):
        FaultPlan.parse("explode@1")
    with pytest.raises(ValueError, match="duplicate fault step"):
        FaultPlan.parse("fail@1,nan@1")
    # slow: injected stall goes through the injectable sleep
    stalls = []
    plan = FaultPlan.parse("slow@0:40", sleep=stalls.append)
    out = plan.wrap(lambda b: np.ones(3))(None)
    assert np.all(out == 1.0) and stalls == [0.04]


def test_synthetic_trace_shape():
    ev = synthetic_trace(n_requests=50, rate_hz=100, n_median=128,
                         sigma=0.4, n_min=32, n_max=256, seed=7)
    assert len(ev) == 50 and ev[0].t == 0.0
    assert all(e2.t >= e1.t for e1, e2 in zip(ev, ev[1:]))
    assert all(32 <= e.n_points <= 256 for e in ev)
    # deterministic under the same seed
    ev2 = synthetic_trace(n_requests=50, rate_hz=100, n_median=128,
                          sigma=0.4, n_min=32, n_max=256, seed=7)
    assert ev == ev2
