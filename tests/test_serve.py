"""Continuous-batching serving layer: bucket policy, admission, dispatch
triggers (batch-full / timeout), exactly-once responses numerically equal
to per-cloud apply_single, compile-once per bucket, and the metrics
report."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine, serve
from repro.analysis import compile_cache_size
from repro.data.synthetic import make_cloud
from repro.engine import BlockSpec
from repro.models import pointnet2
from repro.serve import (AdmissionError, Bucket, BucketSet, PCNServer,
                         ServeMetrics, percentile_summary, synthetic_trace)

SPEC = replace(pointnet2.POINTNET2_C, blocks=(
    BlockSpec(24, 8, (16, 32)), BlockSpec(8, 8, (32, 48))))
BUCKETS = BucketSet.make([64, 96], batch=2)


class FakeClock:
    """Deterministic clock so timeout policy is testable without sleeps."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def eng_params():
    eng = engine.PCNEngine(SPEC, mode="lpcn", fc_backend="reference")
    return eng, eng.init(jax.random.PRNGKey(0))


def _cloud(n, seed=0):
    return np.asarray(make_cloud(np.random.default_rng(seed), n),
                      np.float32)


# ---- bucket policy ----------------------------------------------------------

def test_bucket_for_picks_tightest():
    bs = BucketSet.make([64, 96, 128], batch=4)
    assert bs.bucket_for(1).n_points == 64
    assert bs.bucket_for(64).n_points == 64
    assert bs.bucket_for(65).n_points == 96
    assert bs.bucket_for(128).n_points == 128


def test_bucket_admission_errors():
    bs = BucketSet.make([64], batch=4)
    with pytest.raises(AdmissionError, match="largest bucket is 64"):
        bs.bucket_for(65)
    with pytest.raises(AdmissionError, match="n >= 1"):
        bs.bucket_for(0)
    with pytest.raises(ValueError, match="duplicate bucket"):
        BucketSet.make([64, 64], batch=4)


def test_bucket_plan_quantiles_aligned():
    sizes = [50] * 90 + [500] * 10
    bs = BucketSet.plan(sizes, n_buckets=2, batch=4, align=64)
    assert all(b.n_points % 64 == 0 for b in bs)
    assert bs.max_points >= 500          # top edge covers the sample
    assert bs.buckets[0].n_points >= 50  # tight edge covers the mass


# ---- dispatch policy --------------------------------------------------------

def test_batch_full_fires_immediately(eng_params):
    """Reaching bucket capacity fires inside submit — no poll needed."""
    eng, params = eng_params
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=10.0, clock=clock)
    r0 = srv.submit(_cloud(60, 0))
    assert not srv.ready(r0) and srv.pending() == 1
    r1 = srv.submit(_cloud(50, 1))       # same 64-bucket: batch full
    assert srv.ready(r0) and srv.ready(r1) and srv.pending() == 0
    assert srv.metrics.dispatches[-1].partial is False


def test_timeout_fires_partial_no_starvation(eng_params):
    """A lone request must be answered one timeout after arrival, by a
    partial batch padded with masked fill rows — not starve waiting for
    a batch that will never fill."""
    eng, params = eng_params
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=0.5, clock=clock)
    rid = srv.submit(_cloud(80, 2))      # 96-bucket, alone
    assert srv.poll() == []              # not due yet
    clock.advance(0.49)
    assert srv.poll() == []              # still inside the timeout
    clock.advance(0.02)
    assert srv.poll() == [rid]           # due: partial batch fires
    d = srv.metrics.dispatches[-1]
    assert d.partial and d.n_requests == 1 and d.bucket == (2, 96)
    rec = srv.metrics.requests[-1]
    assert rec.queue_wait_s == pytest.approx(0.51)


def test_fifo_within_bucket(eng_params):
    """Dispatch drains a lane front-first: the oldest requests ride the
    first batch."""
    eng, params = eng_params
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=10.0, clock=clock)
    rids = [srv.submit(_cloud(40, s)) for s in range(3)]
    # first two filled a batch and fired; the third still queues
    assert srv.ready(rids[0]) and srv.ready(rids[1])
    assert not srv.ready(rids[2]) and srv.pending() == 1
    assert srv.drain() == [rids[2]]


def test_admission_rejects_bad_requests(eng_params):
    eng, params = eng_params
    srv = PCNServer(eng, params, BUCKETS, timeout_s=1.0,
                    clock=FakeClock())
    with pytest.raises(AdmissionError, match="largest bucket"):
        srv.submit(_cloud(97))
    with pytest.raises(AdmissionError, match="n >= 1"):
        srv.submit(np.zeros((0, 3), np.float32))
    with pytest.raises(AdmissionError, match=r"\(N, 3\)"):
        srv.submit(np.zeros((4, 2), np.float32))
    assert srv.pending() == 0            # rejected requests never queue


def test_exactly_once_and_equivalence(eng_params):
    """Every admitted request is answered exactly once, with logits
    equal to engine.apply_single on its own cloud and key — including
    requests answered by a timeout-fired partial batch (fill rows are
    fully masked)."""
    eng, params = eng_params
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=0.1, clock=clock)
    sizes = (60, 90, 33, 64, 72)         # spans both buckets, odd count
    clouds = [_cloud(n, seed=10 + i) for i, n in enumerate(sizes)]
    keys = [jax.random.PRNGKey(100 + i) for i in range(len(sizes))]
    rids = [srv.submit(c, key=k) for c, k in zip(clouds, keys)]
    clock.advance(1.0)
    srv.poll()                           # leftovers fire as partials
    assert srv.pending() == 0
    assert srv.metrics.report()["partial_batches"] >= 1
    for rid, cloud, key in zip(rids, clouds, keys):
        got = srv.take(rid)
        ref, _ = eng.apply_single(params, jnp.asarray(cloud), key=key)
        np.testing.assert_allclose(got, np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        with pytest.raises(KeyError):    # answered exactly once
            srv.take(rid)


# ---- compile-once per bucket ------------------------------------------------

def test_compile_once_per_bucket():
    """A ragged trace spanning two buckets costs exactly one engine
    compilation per (bucket, spec, mode, backend), independent of the
    n_valid mix (the compile-count probe is repro.analysis's
    compile_cache_size — the jit cache size IS the compile count)."""
    eng = engine.PCNEngine(SPEC, mode="lpcn", fc_backend="reference")
    params = eng.init(jax.random.PRNGKey(1))
    assert compile_cache_size(eng) == 0
    clock = FakeClock()
    srv = PCNServer(eng, params, BUCKETS, timeout_s=0.1, clock=clock)
    assert compile_cache_size(eng) == len(BUCKETS)   # warmup: one per bucket
    rng = np.random.default_rng(3)
    for n in (40, 64, 90, 17, 96, 65, 1, 50):     # every n_valid different
        srv.submit(_cloud(int(n), seed=int(rng.integers(1 << 30))))
        clock.advance(0.2)
        srv.poll()
    srv.drain()
    assert srv.pending() == 0
    used = {r.bucket for r in srv.metrics.requests}
    assert used == {(2, 64), (2, 96)}             # trace spanned both
    assert compile_cache_size(eng) == len(BUCKETS)   # compiled nothing new
    # the report records the same count
    assert srv.report()["compile_count"] == len(BUCKETS)


def test_lazy_warmup_compiles_on_first_use():
    eng = engine.PCNEngine(SPEC, mode="lpcn", fc_backend="reference")
    params = eng.init(jax.random.PRNGKey(2))
    srv = PCNServer(eng, params, BUCKETS, timeout_s=10.0,
                    clock=FakeClock(), warmup=False)
    assert compile_cache_size(eng) == 0
    for s in range(2):
        srv.submit(_cloud(60, seed=20 + s))       # fills the 64-bucket
    assert compile_cache_size(eng) == 1              # only the used bucket


# ---- mesh validation --------------------------------------------------------

def test_rejects_buckets_not_dividing_mesh(eng_params):
    from repro.launch.mesh import local_mesh
    eng = engine.PCNEngine(SPEC, mode="lpcn", mesh=local_mesh())
    n_data = dict(eng.mesh.shape)["data"]
    if n_data == 1:                      # 1-device host: everything divides
        PCNServer(eng, eng_params[1], BucketSet.make([64], batch=3),
                  warmup=False)
        return
    with pytest.raises(ValueError, match="data mesh"):
        PCNServer(eng, eng_params[1],
                  BucketSet.make([64], batch=n_data + 1), warmup=False)


# ---- metrics ----------------------------------------------------------------

def test_percentile_summary_monotone():
    lat = percentile_summary(list(range(1, 101)))
    assert lat["p50"] <= lat["p95"] <= lat["p99"] <= lat["max"]
    empty = percentile_summary([])
    assert empty == {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0,
                     "max": 0.0}


def test_padding_waste_accounting():
    """Waste counts both row padding (Ni < N) and batch-fill slots."""
    m = ServeMetrics()
    b = Bucket(2, 100)
    # full batch: 60 + 40 valid of 200 padded
    m.record_dispatch(b, [(0, 60, 0.0), (1, 40, 0.0)], 1.0, 2.0)
    # partial batch: 50 valid of 200 padded (one whole fill row)
    m.record_dispatch(b, [(2, 50, 0.5)], 1.0, 2.0)
    rep = m.report()
    assert rep["requests"] == 3 and rep["dispatches"] == 2
    assert rep["full_batches"] == 1 and rep["partial_batches"] == 1
    assert rep["padding_waste_pct"] == pytest.approx(
        100.0 * (1 - 150 / 400))
    assert rep["per_bucket"]["2x100"] == {
        "dispatches": 2, "partial": 1, "requests": 3}
    # queue_wait of rid 2: dispatched at 1.0, arrived 0.5
    rec = [r for r in m.requests if r.rid == 2][0]
    assert rec.queue_wait_s == pytest.approx(0.5)
    assert rec.e2e_s == pytest.approx(1.5)


def test_synthetic_trace_shape():
    ev = synthetic_trace(n_requests=50, rate_hz=100, n_median=128,
                         sigma=0.4, n_min=32, n_max=256, seed=7)
    assert len(ev) == 50 and ev[0].t == 0.0
    assert all(e2.t >= e1.t for e1, e2 in zip(ev, ev[1:]))
    assert all(32 <= e.n_points <= 256 for e in ev)
    # deterministic under the same seed
    ev2 = synthetic_trace(n_requests=50, rate_hz=100, n_median=128,
                          sigma=0.4, n_min=32, n_max=256, seed=7)
    assert ev == ev2
