"""Engine API: batched apply vs per-cloud blocks, FC backend agreement,
registries, jit compile-once, and the four-model zoo through the engine."""
from dataclasses import replace
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.analysis import compile_cache_size
from repro.data.synthetic import make_cloud
from repro.engine import Batch, BlockSpec, PCNParams, PCNSpec
from repro.models import MODEL_ZOO, dgcnn, pointnet2

KEY = jax.random.PRNGKey(0)

SMALL_PN2 = replace(pointnet2.POINTNET2_C, blocks=(
    BlockSpec(128, 16, (32, 64)), BlockSpec(32, 16, (64, 128))))


def _clouds(b, n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.stack([make_cloud(rng, n) for _ in range(b)]))


def test_init_returns_typed_pytree():
    params = engine.init(KEY, SMALL_PN2)
    assert isinstance(params, PCNParams)
    leaves = jax.tree_util.tree_leaves(params)
    assert leaves and all(hasattr(l, "shape") for l in leaves)
    # round-trips through tree ops (the optimizer/jit contract)
    p2 = jax.tree.map(lambda x: x, params)
    assert isinstance(p2, PCNParams)


@pytest.mark.parametrize("mode", ["traditional", "lpcn"])
def test_batched_apply_matches_per_cloud(mode):
    """engine.apply on a B=3 padded batch == the per-cloud block path
    (legacy model shim) cloud by cloud, bit-for-bit on CPU."""
    xyz = _clouds(3, 256)
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    params = engine.init(KEY, SMALL_PN2)
    batched = engine.apply(params, Batch.make(xyz, key=keys),
                           spec=SMALL_PN2, mode=mode)
    assert batched.shape == (3, 40)
    # legacy dict params route through apply_single unchanged
    legacy = engine.to_legacy(params, "pointnet2")
    for i in range(3):
        logits, _ = engine.apply_single(legacy, xyz[i], xyz[i], keys[i],
                                        spec=SMALL_PN2, mode=mode)
        np.testing.assert_allclose(np.asarray(batched[i]),
                                   np.asarray(logits),
                                   rtol=1e-5, atol=1e-5)


DEEP_PN2 = replace(pointnet2.POINTNET2_C, blocks=(
    BlockSpec(128, 16, (32, 32, 64)), BlockSpec(32, 16, (48, 48, 96))))


@pytest.mark.parametrize("mode", ["traditional", "lpcn"])
@pytest.mark.parametrize("spec", [SMALL_PN2, DEEP_PN2],
                         ids=["2layer", "3layer"])
def test_pallas_backend_matches_reference(mode, spec):
    """interpret-mode pallas kernels vs the jnp oracle, <= 1e-4 — both
    the direct 2-layer lowering and the >2-layer prologue path (the one
    the shipped POINTNET2/POINTNEXT specs take)."""
    xyz = _clouds(2, 256, seed=1)
    params = engine.init(KEY, spec)
    batch = Batch.make(xyz, key=jax.random.PRNGKey(3))
    ref = engine.apply(params, batch, spec=spec, mode=mode,
                       fc_backend="reference")
    pal = engine.apply(params, batch, spec=spec, mode=mode,
                       fc_backend="pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_pallas_backend_block_end_and_edge():
    """The split-sign / composed-linear kernel lowerings (block_end and
    single-layer edge MLPs) agree with the oracle too."""
    spec = replace(dgcnn.with_points(dgcnn.DGCNN_C, 128), blocks=(
        BlockSpec(128, 12, (32,), kind="edge", sampler="all"),
        BlockSpec(128, 12, (48,), kind="edge", sampler="all")))
    params = engine.init(KEY, spec)
    batch = Batch.make(_clouds(2, 128, seed=2), key=jax.random.PRNGKey(5))
    for mode in ("traditional", "lpcn"):
        ref = engine.apply(params, batch, spec=spec, mode=mode,
                           fc_backend="reference")
        pal = engine.apply(params, batch, spec=spec, mode=mode,
                           fc_backend="pallas")
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_jit_compiles_once():
    """One executable serves every batch of the same shape."""
    params = engine.init(KEY, SMALL_PN2)
    f = jax.jit(partial(engine.apply, spec=SMALL_PN2, mode="lpcn"))
    b1 = Batch.make(_clouds(2, 256, seed=3), key=jax.random.PRNGKey(1))
    b2 = Batch.make(_clouds(2, 256, seed=4), key=jax.random.PRNGKey(2))
    out1 = f(params, b1)
    out2 = f(params, b2)
    assert out1.shape == out2.shape == (2, 40)
    assert compile_cache_size(f) == 1
    assert bool(jnp.isfinite(out1).all() and jnp.isfinite(out2).all())


def test_engine_apply_no_retrace_across_input_forms():
    """Alternating raw (B, N, 3) arrays, Batch objects, typed-key Batches
    and legacy dict params of the same shapes must reuse ONE executable —
    everything is normalized before the cached jit."""
    params = engine.init(KEY, SMALL_PN2)
    eng = engine.PCNEngine(SMALL_PN2, mode="traditional")
    xyz = _clouds(2, 256, seed=11)
    eng.apply(params, xyz)                            # raw array
    eng.apply(params, Batch.make(xyz))                # Batch
    eng.apply(params, Batch.make(xyz, key=jax.random.key(5)))  # typed key
    eng.apply(engine.to_legacy(params, "pointnet2"),  # legacy dict params
              Batch.make(xyz))
    assert compile_cache_size(eng) == 1


def test_registry_rejects_duplicates_and_unknown():
    with pytest.raises(ValueError, match="duplicate sampler 'fps'"):
        engine.register_sampler("fps", lambda *a, **k: None)
    with pytest.raises(KeyError, match="unknown neighbor 'nope'"):
        engine.NEIGHBORS.get("nope")
    with pytest.raises(KeyError, match="unknown fc_backend"):
        engine.FC_BACKENDS.get("missing")
    # custom registration round-trips (and is listed in names());
    # clean up so the process-global registry stays re-runnable
    try:
        engine.register_sampler("test_first8",
                                lambda xyz, *, tree, n_centers, key:
                                jnp.arange(n_centers, dtype=jnp.int32))
        assert "test_first8" in engine.SAMPLERS.names()
    finally:
        engine.SAMPLERS._entries.pop("test_first8", None)


def test_all_zoo_models_through_engine():
    """Every model family produces finite logits through the engine."""
    specs = {
        "pointnet2_c": SMALL_PN2,
        "dgcnn_c": replace(dgcnn.with_points(dgcnn.DGCNN_C, 128), blocks=(
            BlockSpec(128, 12, (32,), kind="edge", sampler="all"),)),
        "pointnext_s": replace(MODEL_ZOO["pointnext_s"][1], blocks=(
            BlockSpec(64, 12, (32,)), BlockSpec(16, 12, (64,)))),
        "pointvector_l": replace(MODEL_ZOO["pointvector_l"][1], blocks=(
            BlockSpec(64, 12, (48,)), BlockSpec(16, 12, (96,)))),
    }
    rng = np.random.default_rng(9)
    for seed, (name, spec) in enumerate(specs.items()):
        f_in = spec.in_feats
        xyz = _clouds(2, 128, seed=seed)
        feats = xyz if f_in == 3 else jnp.concatenate(
            [xyz, jnp.asarray(rng.uniform(0, 1, (2, 128, f_in - 3)),
                              jnp.float32)], -1)
        params = engine.init(KEY, spec)
        out = engine.apply(params, Batch.make(xyz, feats), spec=spec)
        expect_b = 2
        assert out.shape[0] == expect_b and out.shape[-1] == spec.n_classes
        assert bool(jnp.isfinite(out).all()), name


def test_legacy_dict_params_accepted():
    """engine.apply accepts the old dict layouts (to_legacy round-trip)."""
    legacy = engine.to_legacy(engine.init(KEY, SMALL_PN2), "pointnet2")
    assert isinstance(legacy, dict)
    out = engine.apply(legacy, Batch.make(_clouds(2, 128, seed=6)),
                       spec=SMALL_PN2)
    assert out.shape == (2, 40)


def test_batch_from_clouds_pads():
    clouds = [np.asarray(make_cloud(np.random.default_rng(i), n))
              for i, n in enumerate((100, 128, 80))]
    b = Batch.from_clouds(clouds, key=KEY)
    assert b.xyz.shape == (3, 128, 3)
    assert b.n_valid.tolist() == [100, 128, 80]
    # padded rows repeat the last real point
    np.testing.assert_array_equal(np.asarray(b.xyz[0, 99]),
                                  np.asarray(b.xyz[0, 127]))


def test_batch_from_clouds_n_pad():
    """n_pad pads beyond the longest cloud (the serving dispatcher's
    bucket shape); a cloud already at n_pad passes through untouched."""
    clouds = [np.asarray(make_cloud(np.random.default_rng(i), n),
                         np.float32) for i, n in enumerate((100, 128))]
    b = Batch.from_clouds(clouds, key=KEY, n_pad=160)
    assert b.xyz.shape == (2, 160, 3)
    assert b.n_valid.tolist() == [100, 128]
    # Ni == n_pad edge: exact-size cloud is bitwise untouched
    b2 = Batch.from_clouds([clouds[1]], key=KEY, n_pad=128)
    assert b2.n_valid.tolist() == [128]
    np.testing.assert_array_equal(np.asarray(b2.xyz[0]), clouds[1])
    # n_pad shorter than the longest cloud must refuse, not truncate
    with pytest.raises(ValueError, match="shorter than the longest"):
        Batch.from_clouds(clouds, n_pad=64)


def test_batch_from_clouds_empty_cloud():
    """Ni == 0 edge: empty clouds (the dispatcher's batch-fill rows for
    partial batches) zero-fill and carry n_valid == 0 — fully masked, so
    they cannot perturb the real rows."""
    real = np.asarray(make_cloud(np.random.default_rng(0), 90), np.float32)
    b = Batch.from_clouds([real, np.zeros((0, 3), np.float32)],
                          key=KEY, n_pad=96)
    assert b.xyz.shape == (2, 96, 3)
    assert b.n_valid.tolist() == [90, 0]
    np.testing.assert_array_equal(np.asarray(b.xyz[1]), 0.0)
    assert b.xyz.dtype == jnp.float32
    # an all-empty batch has no longest cloud: n_pad is required
    with pytest.raises(ValueError, match="n_pad >= 1"):
        Batch.from_clouds([np.zeros((0, 3), np.float32)])
    Batch.from_clouds([np.zeros((0, 3), np.float32)], n_pad=8)
    with pytest.raises(ValueError, match="at least one cloud"):
        Batch.from_clouds([])


def test_validate_cloud_and_batch_validate():
    """validate_cloud (the serving admission guard's seam) rejects
    non-finite payloads and non-floating dtypes, coerces f64 -> f32;
    Batch.make/from_clouds expose the same checks via validate=."""
    from repro.engine import validate_cloud
    good = np.asarray(make_cloud(np.random.default_rng(0), 32), np.float32)
    np.testing.assert_array_equal(validate_cloud(good), good)
    # f64 coerces rather than trusting an implicit downcast
    assert validate_cloud(good.astype(np.float64)).dtype == np.float32
    with pytest.raises(ValueError, match="not a floating point"):
        validate_cloud(np.zeros((4, 3), np.int32))
    bad = good.copy()
    bad[5, 2] = np.nan
    with pytest.raises(ValueError, match=r"non-finite.*row\(s\) \[5\]"):
        validate_cloud(bad)
    # the per-cloud index lands in the message (serving diagnosis)
    with pytest.raises(ValueError, match=r"clouds\[1\]"):
        Batch.from_clouds([good, bad], validate=True)
    with pytest.raises(ValueError, match="non-finite"):
        Batch.make(bad[None], validate=True)
    # validate=True also coerces dtypes through the Batch constructors
    b = Batch.from_clouds([good.astype(np.float64)], validate=True)
    assert b.xyz.dtype == jnp.float32
    # default stays permissive: trusted in-process callers skip the scan
    Batch.make(bad[None])


def test_apply_with_reports_batched():
    params = engine.init(KEY, SMALL_PN2)
    logits, rep = engine.apply_with_reports(
        params, Batch.make(_clouds(3, 256, seed=8)), spec=SMALL_PN2)
    assert logits.shape == (3, 40)
    assert rep.lpcn_fetches.shape == (3,)
    assert int(rep.lpcn_fetches.sum()) <= int(rep.baseline_fetches.sum())


def test_engine_mesh_noop_bit_identical():
    """Regression for the "no mesh" fast path: a trivial local_mesh()
    (1 device -> ("data", "model") = (1, 1)) must not change a single
    bit vs mesh=None — the sharding constraints it inserts are inert on
    one device."""
    from repro.launch.mesh import local_mesh

    params = engine.init(KEY, SMALL_PN2)
    b = Batch.make(_clouds(2, 256, seed=11), key=jax.random.PRNGKey(1),
                   n_valid=jnp.asarray([256, 190], jnp.int32))
    for mode in ("traditional", "lpcn"):
        plain = engine.apply(params, b, spec=SMALL_PN2, mode=mode)
        meshed = engine.PCNEngine(SMALL_PN2, mode=mode,
                                  mesh=local_mesh()).apply(params, b)
        np.testing.assert_array_equal(np.asarray(plain),
                                      np.asarray(meshed))
    # the meshed engines above DID import repro.dist; the fast path's
    # import guarantee is about fresh processes — enforced by
    # test_no_mesh_path_never_imports_dist below
    from repro.engine.archs import EngineCtx
    assert EngineCtx.make().mesh is None


def test_no_mesh_path_never_imports_dist():
    """The mesh=None fast path must work without repro.dist ever being
    imported (environments without the scale-out subsystem, and the
    documented engine contract) — checked in a fresh subprocess so this
    process's earlier imports can't mask a regression."""
    import os
    import subprocess
    import sys

    code = """
import sys
import jax, jax.numpy as jnp
import numpy as np
from dataclasses import replace
from repro import engine
from repro.engine import Batch, BlockSpec
from repro.models import pointnet2

spec = replace(pointnet2.POINTNET2_C, blocks=(BlockSpec(16, 8, (16, 32)),))
params = engine.init(jax.random.PRNGKey(0), spec)
xyz = jnp.asarray(np.random.default_rng(0).normal(size=(2, 64, 3)),
                  jnp.float32)
out = engine.PCNEngine(spec).apply(params, Batch.make(xyz))
assert out.shape[0] == 2
assert "repro.dist" not in sys.modules, sorted(
    m for m in sys.modules if m.startswith("repro.dist"))
print("ok")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"


def test_engine_rejects_dataless_mesh():
    """An engine mesh must carry a "data" axis to shard batches along."""
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="'data' axis"):
        engine.PCNEngine(SMALL_PN2, mesh=mesh)
