"""Analysis targets: what `python -m repro.analysis` traces and checks.

A :class:`Target` is one (entry point, input shape class) pair: a
closure that traces it to a ``ClosedJaxpr`` (nothing executes), the
operand pytree a caller would pass (for the recompile-hazard leaf
scan), the static arguments (for the hashability check), and the
*point sizes* — the axis lengths that carry potentially-padded point
rows, which the masking lint treats as protected.

The default matrix mirrors ``tests/test_batched_fc.py``: all four
model families × both modes × the ``reference`` and batched ``pallas``
backends at reduced N=96 shapes, plus the serve dispatcher's
partial-batch ``Batch`` construction and the mesh-sharded entry point.
(The ``pallas_vmap`` A/B backend is excluded: its per-cloud kernels
are traced under vmap with mapped block dims that the static grid
checks can't see through; the batched path is the serving path.)
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable

import numpy as np

from repro.kernels.tiling import DEFAULT_VMEM_BUDGET_MB

MODELS = ("pointnet2", "dgcnn", "pointnext", "pointvector")
MODES = ("traditional", "lpcn")
BACKENDS = ("reference", "pallas")

_N = 96
_SIZES = (96, 70, 57)


@dataclass
class Target:
    name: str
    trace: Callable[[], Any]            # -> ClosedJaxpr
    operands: Any = None                # pytree for the R001/R002 leaf scan
    statics: dict = field(default_factory=dict)   # for the R003 check
    point_sizes: frozenset = frozenset()
    vmem_budget_mb: float = DEFAULT_VMEM_BUDGET_MB


def reduced_specs() -> dict:
    """The 4 reduced model specs the analyzer (and the batched-FC test
    matrix) runs at: N=96, two small blocks per family."""
    from repro.engine import BlockSpec
    from repro.models import MODEL_ZOO, dgcnn, pointnet2
    return {
        "pointnet2": replace(pointnet2.POINTNET2_C, blocks=(
            BlockSpec(48, 8, (16, 32)), BlockSpec(16, 8, (32, 48)))),
        "dgcnn": replace(dgcnn.with_points(dgcnn.DGCNN_C, _N), blocks=(
            BlockSpec(_N, 8, (24,), kind="edge", sampler="all"),
            BlockSpec(_N, 8, (32,), kind="edge", sampler="all"))),
        "pointnext": replace(MODEL_ZOO["pointnext_s"][1], blocks=(
            BlockSpec(48, 8, (24,)), BlockSpec(16, 8, (32,)))),
        "pointvector": replace(MODEL_ZOO["pointvector_l"][1], blocks=(
            BlockSpec(48, 8, (24,)), BlockSpec(16, 8, (48,)))),
    }


def spec_point_sizes(spec, n: int) -> frozenset:
    """Axis lengths where padded point rows can appear for ``spec`` at
    padded cloud length ``n``: the cloud axis, every neighbor axis, and
    center axes of blocks that keep all rows (``sampler="all"``).
    Downsampled center axes are fully valid by construction (the engine
    drops ``n_valid`` below a downsampling block) and are excluded."""
    sizes = {n}
    for b in spec.blocks:
        sizes.add(b.k)
        if b.sampler == "all":
            sizes.add(min(b.n_centers, n))
    return frozenset(sizes)


def _make_batch(spec, sizes=_SIZES, seed=0):
    import jax
    import jax.numpy as jnp
    from repro.data.synthetic import make_cloud
    from repro.engine import Batch
    rng = np.random.default_rng(seed)
    b = len(sizes)
    xyz = jnp.asarray(np.stack([make_cloud(rng, _N) for _ in range(b)]))
    f_in = spec.in_feats
    feats = xyz if f_in == 3 else jnp.concatenate(
        [xyz, jnp.asarray(rng.uniform(0, 1, (b, _N, f_in - 3)),
                          jnp.float32)], -1)
    return Batch.make(xyz, feats, key=jax.random.PRNGKey(7),
                      n_valid=jnp.asarray(sizes, jnp.int32))


def _engine_target(model: str, mode: str, backend: str, spec,
                   mesh=None, tag: str = "engine") -> Target:
    import jax
    from repro import engine
    from repro.engine import Batch

    batch = _make_batch(spec)
    params = engine.init(jax.random.PRNGKey(0), spec)
    statics = {"spec": spec, "mode": mode, "fc_backend": backend}

    def trace():
        def fn(params, xyz, feats, keys, n_valid):
            b = Batch(xyz=xyz, feats=feats, keys=keys, n_valid=n_valid)
            return engine.apply(params, b, spec=spec, mode=mode,
                                fc_backend=backend, mesh=mesh)
        return jax.make_jaxpr(fn)(params, batch.xyz, batch.feats,
                                  batch.keys, batch.n_valid)

    return Target(
        name=f"{tag}:{model}/{mode}/{backend}",
        trace=trace,
        operands={"params": params, "batch": batch},
        statics=statics,
        point_sizes=spec_point_sizes(spec, _N),
    )


def _serve_target(spec) -> Target:
    """The dispatcher's partial-batch path: numpy clouds + a stacked
    numpy key array through ``Batch.from_clouds`` (the PR-6 numpy-leaf
    site), then the bucket-shaped engine trace."""
    import jax
    from jax.random import key_data
    from repro import engine
    from repro.engine import Batch

    rng = np.random.default_rng(0)
    clouds = [np.asarray(rng.standard_normal((sz, 3)), np.float32)
              for sz in (96, 70)] + [np.zeros((0, 3), np.float32)]
    fill_key = key_data(jax.random.PRNGKey(0))
    keys = np.stack([key_data(jax.random.PRNGKey(i + 1))
                     for i in range(2)] + [fill_key]).astype(np.uint32)
    batch = Batch.from_clouds(clouds, key=keys, n_pad=_N)
    params = engine.init(jax.random.PRNGKey(0), spec)

    def trace():
        def fn(params, xyz, feats, keys, n_valid):
            b = Batch(xyz=xyz, feats=feats, keys=keys, n_valid=n_valid)
            return engine.apply(params, b, spec=spec, mode="lpcn",
                                fc_backend="pallas")
        return jax.make_jaxpr(fn)(params, batch.xyz, batch.feats,
                                  batch.keys, batch.n_valid)

    return Target(
        name="serve:pointnet2/lpcn/pallas",
        trace=trace,
        operands={"params": params, "batch": batch},
        statics={"spec": spec, "mode": "lpcn", "fc_backend": "pallas"},
        point_sizes=spec_point_sizes(spec, _N),
    )


def _dist_target(spec) -> Target:
    """The mesh-sharded entry point (PR 5): engine.apply(mesh=...) over
    whatever devices this process has."""
    import jax
    from repro.launch.mesh import make_mesh

    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev, 1), ("data", "model"))
    t = _engine_target("pointnet2", "lpcn", "reference", spec,
                       mesh=mesh, tag="dist")
    return t


# The level-2 SA pools reduce over neighbors gathered from FPS-downsampled
# centers, which are fully valid by construction (the engine's nv_levels
# goes None below the first downsampling block — see core/pipeline.py), so
# they intentionally run the unmasked kernel/reference path.  M001 cannot
# see that from the jaxpr (K=8 collides with the masked level-1 pools), so
# the three level-2 pool shapes of the reduced matrix are suppressed here,
# next to the matrix they belong to.  dgcnn (sampler="all") keeps masks
# live at every level and is checked unsuppressed.
# analysis: allow M001 */reduce_max(3x16x8x48)@axes(2) -- level-2 SA pool over fully-valid FPS centers (pointnet2/pointvector reference path)
# analysis: allow M001 */reduce_max(3x16x8x32)@axes(2) -- level-2 SA pool over fully-valid FPS centers (pointnext reference path)
# analysis: allow M001 */reduce_max(16x8x128)@axes(1) -- level-2 SA pool over fully-valid FPS centers (batched pallas kernel, lane-padded)
def default_targets(models=MODELS, modes=MODES, backends=BACKENDS,
                    include_serve: bool = True,
                    include_dist: bool = True) -> list[Target]:
    specs = reduced_specs()
    out = []
    for model in models:
        for mode in modes:
            for backend in backends:
                out.append(_engine_target(model, mode, backend, specs[model]))
    if include_serve:
        out.append(_serve_target(specs["pointnet2"]))
    if include_dist:
        out.append(_dist_target(specs["pointnet2"]))
    return out
