"""``python -m repro.analysis`` — run every rule family and report.

Usage:

    python -m repro.analysis                      # full matrix, report to stdout
    python -m repro.analysis --strict             # exit 1 on unsuppressed errors
    python -m repro.analysis --json results/analysis_report.json
    python -m repro.analysis --models pointnet2 --backends pallas --quick

``--quick`` restricts the matrix to one model family and skips the
executable R004 cache-growth probe (everything else is pure tracing —
no kernel runs either way).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .findings import Finding, RULES, active, apply_suppressions, scan_suppressions
from .kernels import kernel_findings, pallas_call_sites
from .masking import masked_reduction_findings
from .repolint import _iter_sources, repo_findings
from .retrace import leaf_findings, static_findings
from . import targets as T


def _src_suppressions(src_root: str | None):
    """Suppressions declared anywhere under src/repro apply to jaxpr-level
    (logical-location) findings via their fnmatch pattern."""
    if src_root is None:
        here = os.path.dirname(os.path.abspath(__file__))
        src_root = os.path.dirname(os.path.dirname(here))
    sups, meta = [], []
    for path in _iter_sources(src_root):
        s, m = scan_suppressions(path)
        sups.extend(s)
        meta.extend(m)
    return sups, meta


def analyze_targets(target_list, suppressions=()):
    """Trace each target and run the jaxpr rule families (K*, M001,
    R001–R003).  Returns ``(findings, kernel_inventory)``.

    Targets trace under ``plans.bypass()``: the analysis contract (and
    its shape-specific inline suppressions, e.g. the lane-padded M001
    allows in ``targets.py``) is pinned to the *heuristic* tile plans,
    independent of whatever ``results/tile_plans.json`` a host happens
    to carry.  Autotuned store entries are linted separately, at
    promotion time, by ``repro.launch.autotune``."""
    from repro.kernels import plans
    findings: list[Finding] = []
    inventory: list[dict] = []
    for t in target_list:
        try:
            with plans.bypass():
                closed = t.trace()
        except Exception as e:  # a target that cannot trace is itself a defect
            findings.append(Finding(
                "K003", f"target failed to trace: {type(e).__name__}: {e}",
                where=t.name))
            continue
        findings.extend(kernel_findings(
            closed, vmem_budget_mb=t.vmem_budget_mb, where=t.name))
        findings.extend(masked_reduction_findings(
            closed, point_sizes=t.point_sizes, where=t.name))
        if t.operands is not None:
            findings.extend(leaf_findings(t.operands, where=t.name))
        if t.statics:
            findings.extend(static_findings(t.statics, where=t.name))
        for site in pallas_call_sites(closed, where=t.name):
            inventory.append({
                "target": t.name, "site": site.where, "grid": list(site.grid),
                "dimension_semantics": (list(site.dimension_semantics)
                                        if site.dimension_semantics else None),
                "footprint_bytes": site.footprint_bytes,
                "vmem_budget_mb": t.vmem_budget_mb,
            })
    return apply_suppressions(findings, list(suppressions)), inventory


def retrace_exec_findings() -> list[Finding]:
    """R004: one small engine, several same-shape input forms (raw array
    vs Batch vs differing n_valid vs numpy-origin keys) must share one
    executable.  This is the only check that runs device code."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import engine
    from repro.engine import Batch

    from .retrace import cache_growth_findings

    spec = T.reduced_specs()["pointnet2"]
    eng = engine.PCNEngine(spec, mode="lpcn", fc_backend="reference")
    params = eng.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    xyz = jnp.asarray(rng.standard_normal((2, 96, 3)), jnp.float32)
    mixes = [
        (params, xyz),                                        # raw array
        (params, Batch.make(xyz, key=jax.random.PRNGKey(1))), # Batch
        (params, Batch.make(xyz, key=jax.random.PRNGKey(1),
                            n_valid=jnp.asarray([96, 40], jnp.int32))),
        (params, Batch.make(xyz,                              # numpy keys
                            key=np.stack([np.asarray(
                                jax.random.key_data(jax.random.PRNGKey(i)))
                                for i in range(2)]).astype(np.uint32))),
    ]
    return cache_growth_findings(
        eng.apply, mixes, expected=1,
        where="engine[pointnet2/lpcn/reference]/cache")


def build_report(findings, inventory, level: str) -> dict:
    errors = active(findings, "error")
    warnings = active(findings, "warning")
    return {
        "level": level,
        "rules": {rid: {"severity": sev, "description": desc}
                  for rid, (sev, desc) in RULES.items()},
        "kernel_sites": inventory,
        "findings": [f.to_dict() for f in findings],
        "summary": {
            "findings": len(findings),
            "errors": len(errors),
            "warnings": len(warnings),
            "suppressed": sum(f.suppressed for f in findings),
            "strict_ok": not errors,
        },
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis over the engine matrix + repo source")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 if any unsuppressed error-severity finding")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write the JSON report here")
    p.add_argument("--models", nargs="*", default=list(T.MODELS),
                   choices=list(T.MODELS))
    p.add_argument("--modes", nargs="*", default=list(T.MODES),
                   choices=list(T.MODES))
    p.add_argument("--backends", nargs="*", default=list(T.BACKENDS),
                   choices=list(T.BACKENDS))
    p.add_argument("--quick", action="store_true",
                   help="one model family, skip the executable R004 probe")
    p.add_argument("--no-exec", action="store_true",
                   help="skip the executable R004 cache-growth probe")
    p.add_argument("--no-repo", action="store_true",
                   help="skip the AST repo lint")
    args = p.parse_args(argv)

    models = args.models[:1] if args.quick else args.models
    sups, meta = _src_suppressions(None)

    target_list = T.default_targets(
        models=models, modes=args.modes, backends=args.backends,
        include_serve=not args.quick, include_dist=not args.quick)
    findings, inventory = analyze_targets(target_list, suppressions=sups)
    findings.extend(meta)
    if not args.no_repo:
        findings.extend(repo_findings())
    if not (args.quick or args.no_exec):
        findings.extend(apply_suppressions(retrace_exec_findings(), sups))

    level = "quick" if args.quick else "full"
    report = build_report(findings, inventory, level)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2)

    for f in findings:
        print(f)
    s = report["summary"]
    print(f"repro.analysis [{level}]: {len(target_list)} targets, "
          f"{len(inventory)} kernel sites, {s['findings']} findings "
          f"({s['errors']} errors, {s['warnings']} warnings, "
          f"{s['suppressed']} suppressed)")
    if args.strict and not s["strict_ok"]:
        print("STRICT: unsuppressed errors present", file=sys.stderr)
        return 1
    return 0
