"""Finding records, the rule catalog, and inline suppressions.

Every rule in ``repro.analysis`` reports through a :class:`Finding`:
a rule id, a severity, a human-readable message, and a *location*
string.  Locations are either ``path:line`` (AST rules) or a dotted
logical path like ``engine[pointnet2/lpcn/pallas]/fc0`` (jaxpr rules)
— suppression patterns match against this string with :mod:`fnmatch`.

Suppression syntax (inline comment, same line or the line above the
flagged source line; for jaxpr findings put it anywhere in the file
named by the finding's ``file`` attribute or pass patterns explicitly):

    # analysis: allow K002 -- ctr block streams the full 3-wide axis
    # analysis: allow M001 engine[*]/pool* -- centers are fully valid

The justification after ``--`` is mandatory: a suppression without one
does not take effect and is itself reported as ``S001``.
"""
from __future__ import annotations

import fnmatch
import re
from dataclasses import dataclass, field, asdict

ERROR = "error"
WARNING = "warning"

#: rule id -> (default severity, one-line description)
RULES: dict[str, tuple[str, str]] = {
    # kernel lint (analysis/kernels.py)
    "K001": (ERROR, "pallas_call block buffers exceed the declared VMEM budget"),
    "K002": (ERROR, "block last dim is neither 128-lane aligned nor the full array width"),
    "K003": (ERROR, "grid/index map addresses a tile fully outside the operand"),
    "K004": (ERROR, "resident operand (constant index map) does not cover its array"),
    "K005": (ERROR, "dimension_semantics inconsistent with the grid or output index maps"),
    # recompile-hazard lint (analysis/retrace.py)
    "R001": (ERROR, "numpy.ndarray leaf in a traced operand position (retraces per call site)"),
    "R002": (WARNING, "python scalar leaf in a traced operand position (weak-type hazard)"),
    "R003": (ERROR, "unhashable static argument (jit cannot cache on it)"),
    "R004": (ERROR, "jit shape-cache grew across representative input mixes"),
    # ragged-masking lint (analysis/masking.py)
    "M001": (ERROR, "reduction over a point axis without an n_valid mask / sentinel fill"),
    # repo lint (analysis/repolint.py)
    "A001": (ERROR, "jax.random.choice call (length-dependent host fallback; use index_uniform)"),
    "A002": (ERROR, "module-level repro.dist import reachable from the mesh=None fast path"),
    "A003": (ERROR, "wall-clock call inside traced/jitted package scope"),
    "A004": (ERROR, "blanket except in repro.serve that neither re-raises nor uses the caught error"),
    # meta
    "S001": (WARNING, "suppression comment without a '-- justification' is inactive"),
}


@dataclass
class Finding:
    rule: str
    message: str
    where: str            # "path:line" or a logical jaxpr location
    severity: str = ""    # defaults from RULES at __post_init__
    file: str | None = None
    line: int | None = None
    suppressed: bool = False
    justification: str | None = None

    def __post_init__(self):
        if not self.severity:
            self.severity = RULES.get(self.rule, (ERROR, ""))[0]

    def to_dict(self) -> dict:
        d = asdict(self)
        d["description"] = RULES.get(self.rule, ("", ""))[1]
        return d

    def __str__(self):
        tag = " [suppressed]" if self.suppressed else ""
        return f"{self.severity.upper()} {self.rule} {self.where}: {self.message}{tag}"


@dataclass(frozen=True)
class Suppression:
    rule: str
    pattern: str          # fnmatch pattern vs Finding.where ("*" = any)
    justification: str
    file: str
    line: int


_SUPPRESS_RE = re.compile(
    r"#\s*analysis:\s*allow\s+(?P<rule>[A-Z]\d{3})"
    r"(?:\s+(?P<pattern>[^\s#]+))?"
    r"(?:\s*--\s*(?P<why>.+?))?\s*$"
)


def scan_suppressions(path: str, text: str | None = None):
    """Collect inline suppressions from one source file.

    Returns ``(suppressions, meta_findings)`` where meta_findings holds
    an S001 for every justification-less (and therefore inactive)
    suppression comment.
    """
    if text is None:
        with open(path, "r", encoding="utf-8") as fh:
            text = fh.read()
    sups: list[Suppression] = []
    meta: list[Finding] = []
    for lineno, raw in enumerate(text.splitlines(), start=1):
        m = _SUPPRESS_RE.search(raw)
        if not m:
            continue
        why = (m.group("why") or "").strip()
        if not why:
            meta.append(Finding(
                "S001",
                f"suppression for {m.group('rule')} has no '-- justification'",
                where=f"{path}:{lineno}", file=path, line=lineno))
            continue
        sups.append(Suppression(
            rule=m.group("rule"), pattern=m.group("pattern") or "*",
            justification=why, file=path, line=lineno))
    return sups, meta


def _matches(sup: Suppression, finding: Finding) -> bool:
    if sup.rule != finding.rule:
        return False
    # AST findings are line-scoped: the comment must sit on the flagged
    # line or the line directly above it, in the same file.
    if finding.file is not None and finding.line is not None:
        return (sup.file == finding.file
                and sup.line in (finding.line, finding.line - 1)
                and fnmatch.fnmatch(finding.where, sup.pattern))
    # jaxpr/logical findings match purely on the location pattern.
    return fnmatch.fnmatch(finding.where, sup.pattern)


def apply_suppressions(findings, suppressions):
    """Mark findings matched by a suppression; returns the same list."""
    for f in findings:
        for s in suppressions:
            if _matches(s, f):
                f.suppressed = True
                f.justification = s.justification
                break
    return findings


def active(findings, severity: str | None = None):
    """Unsuppressed findings, optionally filtered by severity."""
    out = [f for f in findings if not f.suppressed]
    if severity is not None:
        out = [f for f in out if f.severity == severity]
    return out
