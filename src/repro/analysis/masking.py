"""Ragged-masking lint: reductions over point axes must be guarded.

The PR-2 bug class: a padded batch carries dead rows, and a
``reduce_max``/``reduce_sum`` over the point axis silently folds them
in.  The repo-wide contract is that every such reduction is *guarded*
— its operand passes through an ``n_valid``-style ``jnp.where`` (a
``select_n``) or a ±BIG/±inf sentinel fill immediately upstream.

This module runs a small dataflow over a traced jaxpr:

* a var becomes **guarded** when produced by ``select_n``, or when it
  is a sentinel constant (|value| ≥ 1e30 or infinite — the −BIG fill);
* guardedness propagates through elementwise/structural ops (any
  guarded operand guards the output);
* ``dot_general``/conv and the reductions themselves **consume** the
  guard — a matmul scrambles rows, so the mask must be re-applied
  before the next pool (exactly the repo idiom);
* **M001** fires on any float reduction (``reduce_max``,
  ``reduce_min``, ``reduce_sum``, ``argmax``, ``argmin``) over an axis
  whose size is in the target's *point-size set* with an unguarded
  operand.

Point sizes are the axis lengths where padding can live: the padded
cloud length N, every neighbor count K, and the center counts of
blocks whose sampler keeps all rows (downsampled center axes are fully
valid by construction — ``nv_levels`` goes ``None`` below a
downsampling block — so they are deliberately excluded).

The walk descends into ``pjit``/``scan``/``while``/``cond``/custom-JVP
sub-jaxprs and into Pallas kernel bodies (mapping operand guardedness
through the kernel's refs), so the in-kernel ``-BIG`` masked pools are
analyzed too.  Nothing executes.
"""
from __future__ import annotations

import numpy as np

from .findings import Finding

SENTINEL_ABS = 1e30

#: checked reduction primitive -> True (all carry an ``axes`` param)
CHECKED = ("reduce_max", "reduce_min", "reduce_sum", "argmax", "argmin")

#: primitives that consume (kill) guardedness
KILL = ("dot_general", "conv_general_dilated") + CHECKED

_SUB_KEYS = ("jaxpr", "call_jaxpr")


def _is_sentinel_value(v) -> bool:
    try:
        arr = np.asarray(v)
    except Exception:
        return False
    if arr.size == 0 or not np.issubdtype(arr.dtype, np.floating):
        return False
    return bool(np.any(np.isinf(arr)) or np.max(np.abs(arr)) >= SENTINEL_ABS)


class _Walker:
    def __init__(self, point_sizes, where):
        self.point_sizes = frozenset(int(p) for p in point_sizes)
        self.where = where
        self.findings: dict[tuple, Finding] = {}

    def _guard_of(self, v, guard):
        if hasattr(v, "val"):          # Literal
            return _is_sentinel_value(v.val)
        return guard.get(v, False)

    def run_closed(self, closed, in_guards):
        jx = getattr(closed, "jaxpr", closed)
        guard = {}
        consts = getattr(closed, "consts", None) or []
        for cv, cval in zip(jx.constvars, consts):
            guard[cv] = _is_sentinel_value(cval)
        for v, g in zip(jx.invars, in_guards):
            guard[v] = bool(g)
        self._walk(jx, guard)
        return [self._guard_of(v, guard) for v in jx.outvars]

    def _sub_closed(self, eqn):
        for key in _SUB_KEYS:
            v = eqn.params.get(key)
            if v is not None:
                return v
        return None

    def _check_reduce(self, eqn, operand_guarded):
        operand = eqn.invars[0]
        aval = getattr(operand, "aval", None)
        if aval is None or not np.issubdtype(np.dtype(aval.dtype), np.floating):
            return
        axes = eqn.params.get("axes", ())
        shape = tuple(aval.shape)
        hits = [a for a in axes if a < len(shape) and shape[a] in self.point_sizes]
        if hits and not operand_guarded:
            name = eqn.primitive.name
            sizes = [shape[a] for a in hits]
            axes_s = ",".join(str(int(a)) for a in axes)
            shape_s = "x".join(map(str, shape))
            key = (name, shape, tuple(int(a) for a in axes))
            # location is bracket-free so fnmatch suppression patterns
            # don't collide with character-class syntax
            self.findings.setdefault(key, Finding(
                "M001",
                f"{name} over point axis(es) {[int(a) for a in hits]} "
                f"(size {sizes}) of "
                f"f{np.dtype(aval.dtype).itemsize * 8}({shape_s}) "
                f"with no n_valid mask / sentinel fill on the operand",
                where=f"{self.where}/{name}({shape_s})@axes({axes_s})"))

    def _walk(self, jx, guard):
        for eqn in jx.eqns:
            name = eqn.primitive.name
            ins = [self._guard_of(v, guard) for v in eqn.invars]
            any_in = any(ins)

            if name in CHECKED:
                self._check_reduce(eqn, ins[0])
                for v in eqn.outvars:
                    guard[v] = False
                continue
            if name == "select_n":
                for v in eqn.outvars:
                    guard[v] = True
                continue
            if name in KILL:
                for v in eqn.outvars:
                    guard[v] = False
                continue

            if name == "pallas_call":
                self._walk_pallas(eqn, ins, guard)
                continue
            if name == "scan":
                body = eqn.params["jaxpr"]
                nc, ncar = eqn.params["num_consts"], eqn.params["num_carry"]
                g = list(ins)
                outs = self.run_closed(body, g)
                # one fixpoint-ish extra pass: feed carry guards back in
                g[nc:nc + ncar] = [a or b for a, b in
                                   zip(g[nc:nc + ncar], outs[:ncar])]
                outs = self.run_closed(body, g)
                for v, og in zip(eqn.outvars, outs):
                    guard[v] = og
                continue
            if name == "while":
                cn = eqn.params["cond_nconsts"]
                bn = eqn.params["body_nconsts"]
                carry = ins[cn + bn:]
                body_in = ins[cn:cn + bn] + carry
                outs = self.run_closed(eqn.params["body_jaxpr"], body_in)
                body_in = ins[cn:cn + bn] + [a or b for a, b in zip(carry, outs)]
                outs = self.run_closed(eqn.params["body_jaxpr"], body_in)
                self.run_closed(eqn.params["cond_jaxpr"], ins[:cn] + carry)
                for v, og in zip(eqn.outvars, outs):
                    guard[v] = og
                continue
            if name == "cond":
                branch_outs = [self.run_closed(br, ins[1:])
                               for br in eqn.params["branches"]]
                for i, v in enumerate(eqn.outvars):
                    guard[v] = any(bo[i] for bo in branch_outs if i < len(bo))
                continue

            sub = self._sub_closed(eqn)
            if sub is not None and hasattr(getattr(sub, "jaxpr", sub), "eqns"):
                if name in ("pjit", "closed_call", "core_call", "remat",
                            "checkpoint", "custom_jvp_call", "custom_vjp_call",
                            "custom_vjp_call_jaxpr"):
                    outs = self.run_closed(sub, ins)
                else:
                    # unknown higher-order primitive: analyze the body with
                    # all inputs guarded (no false positives inside) and
                    # pass guardedness through conservatively
                    outs = self.run_closed(sub, [True] * len(
                        getattr(sub, "jaxpr", sub).invars))
                    outs = [any_in for _ in eqn.outvars]
                for v, og in zip(eqn.outvars, outs):
                    guard[v] = bool(og)
                continue

            # default: elementwise/structural — any guarded operand
            # guards the output (reshape, broadcast, where-chains,
            # scatter canvases, concatenate, arithmetic, ...)
            for v in eqn.outvars:
                guard[v] = any_in

    def _walk_pallas(self, eqn, ins, guard):
        kj = eqn.params.get("jaxpr")
        kj = getattr(kj, "jaxpr", kj)
        if kj is None or not hasattr(kj, "eqns"):
            for v in eqn.outvars:
                guard[v] = any(ins)
            return
        kguard = {}
        # kernel invars: [index operands] + input refs + output refs
        # (+ scratch); eqn.invars covers the first two groups.
        for i, v in enumerate(kj.invars):
            kguard[v] = ins[i] if i < len(ins) else False
        # refs: `get` reads pass the ref's guardedness (default walk
        # handles it), `swap`/`masked_swap` writes update it
        self._walk_kernel(kj, kguard)
        for v in eqn.outvars:
            guard[v] = any(ins)

    def _walk_kernel(self, kj, kguard):
        for eqn in kj.eqns:
            name = eqn.primitive.name
            if name in ("swap", "masked_swap"):
                # write: ref absorbs the value's guardedness
                val_guard = any(self._guard_of(v, kguard)
                                for v in eqn.invars[1:])
                kguard[eqn.invars[0]] = val_guard
                for v in eqn.outvars:
                    kguard[v] = val_guard
                continue
            self._walk_single(eqn, kguard)

    def _walk_single(self, eqn, guard):
        tmp_jx = type("J", (), {"eqns": [eqn]})
        self._walk(tmp_jx, guard)


def masked_reduction_findings(closed_jaxpr, *, point_sizes,
                              where: str = "jaxpr") -> list[Finding]:
    """Run the M001 dataflow over ``closed_jaxpr``.

    ``point_sizes`` — axis lengths that hold potentially-padded point
    rows (cloud length N, neighbor counts K, all-sampler center counts).
    """
    w = _Walker(point_sizes, where)
    jx = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    w.run_closed(closed_jaxpr, [False] * len(jx.invars))
    return list(w.findings.values())
