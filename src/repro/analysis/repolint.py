"""Repo lint: AST-level forbidden-pattern rules over ``src/repro``.

* **A001** — ``jax.random.choice`` anywhere: its CPU lowering is
  length-dependent (gathers over the full operand) and it retraces per
  length class; the repo's samplers use ``index_uniform`` / Morton
  order instead.
* **A002** — a *module-level* ``repro.dist`` import in any module
  reachable (module-level import graph) from the ``mesh=None`` fast
  path roots (``repro.engine``, ``repro.serve``).  The compliant
  pattern is a function-level deferred import on the ``mesh`` branch
  (see ``engine/engine.py``), keeping single-device serving free of
  the dist subsystem.
* **A003** — wall-clock calls (``time.time``/``perf_counter``/
  ``monotonic``/..., ``datetime.now``) inside packages whose code runs
  under ``jit`` (``core``, ``kernels``, ``engine``, ``models``,
  ``nn``): a clock read at trace time is frozen into the executable.
  Host-side layers (``serve``, ``launch``, ``ckpt``, ``data``) may
  read clocks freely.
* **A004** — a bare ``except:`` (or blanket ``except Exception`` /
  ``except BaseException``) inside ``repro.serve`` whose handler body
  neither re-raises nor *uses* the caught exception (no ``as``-bound
  name referenced).  The fault-isolation layer's whole contract is
  that failures become structured :class:`RequestError` outcomes — a
  handler that swallows an error silently turns a failed request into
  a forever-pending one.  Converting handlers (``except Exception as
  e: ... RequestError(..., cause=repr(e))``) reference ``e`` and pass.
* **A005** — a dropped future inside ``repro.serve``: a ``.submit(...)``
  call whose result is discarded outright, or whose bound future is
  never consumed via ``.result`` / ``.exception`` /
  ``.add_done_callback`` (state checks like ``.done()`` / ``.cancel()``
  don't count — they never surface the stored exception).  An error
  raised on an executor thread lives only on the future; drop the
  future and the failure vanishes — A004's contract one layer up, for
  the async dispatch path.  Bindings that escape the scope (returned,
  passed, stored) hand the obligation to the consumer and pass, as do
  non-future ``.submit`` results used any other way.

Inline suppressions (``# analysis: allow A00x -- why``) on the flagged
line or the line above apply; see :mod:`repro.analysis.findings`.
"""
from __future__ import annotations

import ast
import os

from .findings import Finding, apply_suppressions, scan_suppressions

#: packages whose module code is (partially) traced under jit
TRACED_PACKAGES = ("repro.core", "repro.kernels", "repro.engine",
                   "repro.models", "repro.nn")

#: mesh=None fast-path roots for the A002 reachability check
FAST_PATH_ROOTS = ("repro.engine", "repro.serve")

#: package whose except handlers the A004 silent-swallow check covers
#: (the fault-isolation layer: errors must convert, never vanish)
ERROR_CONVERTING_PACKAGE = "repro.serve"

#: except-clause types A004 treats as blanket catches
_BLANKET_EXCEPTS = {"Exception", "BaseException", "builtins.Exception",
                    "builtins.BaseException"}

#: Future methods that surface the stored exception (A005 consumers)
_FUTURE_CONSUMERS = {"result", "exception", "add_done_callback"}

#: Future methods that DON'T — a binding used only through these still
#: drops any error the submitted work raised
_FUTURE_STATE_ATTRS = {"done", "cancel", "cancelled", "running"}

_WALLCLOCK = {
    "time.time", "time.perf_counter", "time.monotonic",
    "time.process_time", "time.thread_time", "time.perf_counter_ns",
    "time.time_ns", "time.monotonic_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
}


def _module_name(root: str, path: str) -> str:
    rel = os.path.relpath(path, root)
    parts = rel[:-3].split(os.sep)          # strip .py; starts with "repro"
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _iter_sources(src_root: str):
    pkg = os.path.join(src_root, "repro")
    for dirpath, _dirnames, filenames in os.walk(pkg):
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


class _ModuleScan(ast.NodeVisitor):
    """One file: alias map, module-level repro imports, flagged calls."""

    def __init__(self, module: str, path: str):
        self.module = module
        self.path = path
        self.aliases: dict[str, str] = {}       # local name -> dotted path
        self.top_imports: list[tuple[str, int]] = []   # (module, line)
        self.calls: list[tuple[str, int]] = []  # (resolved dotted call, line)
        self.swallows: list[tuple[int, str]] = []      # (line, clause) A004
        self.dropped_futures: list[tuple[int, str]] = []   # (line, desc) A005
        self._fn_depth = 0

    # -- imports ---------------------------------------------------------
    def _resolve_from(self, node: ast.ImportFrom) -> str | None:
        if node.level == 0:
            return node.module
        # relative import: anchor at this module's package
        base = self.module.split(".")
        if self.path.endswith("__init__.py"):
            base = base + ["_"]                  # package itself counts as level-1
        anchor = base[:-node.level]
        if node.module:
            anchor = anchor + node.module.split(".")
        return ".".join(anchor) if anchor else None

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            self.aliases[a.asname or a.name.split(".")[0]] = (
                a.name if a.asname else a.name.split(".")[0])
            if a.asname:
                self.aliases[a.asname] = a.name
            if self._fn_depth == 0 and a.name.startswith("repro"):
                self.top_imports.append((a.name, node.lineno))
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        mod = self._resolve_from(node)
        if mod:
            for a in node.names:
                self.aliases[a.asname or a.name] = f"{mod}.{a.name}"
            if self._fn_depth == 0 and mod.startswith("repro"):
                self.top_imports.append((mod, node.lineno))
                for a in node.names:
                    sub = f"{mod}.{a.name}"
                    self.top_imports.append((sub, node.lineno))
        self.generic_visit(node)

    # -- calls -----------------------------------------------------------
    def _dotted(self, node) -> str | None:
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        head = self.aliases.get(node.id, node.id)
        return ".".join([head] + list(reversed(parts)))

    def visit_Call(self, node: ast.Call):
        dotted = self._dotted(node.func)
        if dotted:
            self.calls.append((dotted, node.lineno))
        self.generic_visit(node)

    def visit_FunctionDef(self, node):
        self._fn_depth += 1
        self.generic_visit(node)
        self._fn_depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef

    # -- except handlers (A004) ------------------------------------------
    def visit_ExceptHandler(self, node: ast.ExceptHandler):
        types = ([] if node.type is None
                 else node.type.elts if isinstance(node.type, ast.Tuple)
                 else [node.type])
        blanket = node.type is None or any(
            self._dotted(t) in _BLANKET_EXCEPTS for t in types)
        if blanket:
            body_nodes = [n for stmt in node.body for n in ast.walk(stmt)]
            reraises = any(isinstance(n, ast.Raise) for n in body_nodes)
            uses_caught = node.name is not None and any(
                isinstance(n, ast.Name) and n.id == node.name
                for n in body_nodes)
            if not (reraises or uses_caught):
                clause = ("bare except" if node.type is None else
                          "except " + " | ".join(
                              filter(None, (self._dotted(t)
                                            for t in types))))
                self.swallows.append((node.lineno, clause))
        self.generic_visit(node)


def _is_submit_call(node) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "submit")


def _load_dotted(node) -> str | None:
    """Dotted path of a Name/Attribute chain (no call resolution)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    return ".".join([node.id] + list(reversed(parts)))


def _dropped_futures(tree) -> list[tuple[int, str]]:
    """(line, description) for every ``.submit(...)`` whose outcome can
    never surface: the call's result discarded as a bare expression
    statement, or bound to a name/attribute that is only ever touched
    through non-consuming state checks (or never again at all).  A
    binding that escapes — returned, passed as an argument, stored
    somewhere, or accessed through a non-Future attribute — hands the
    obligation on and passes."""
    out = []
    scopes = [(tree, tree.body)]
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scopes.append((node, node.body))
    for scope, body in scopes:
        # statements of THIS scope only; nested defs are their own scope
        stmts, stack = [], list(body)
        while stack:
            n = stack.pop()
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                continue
            stmts.append(n)
            stack.extend(ast.iter_child_nodes(n))
        # parent links over the full scope: a closure may consume the
        # future its enclosing function submitted
        parent = {c: p for p in ast.walk(scope)
                  for c in ast.iter_child_nodes(p)}
        for n in stmts:
            if isinstance(n, ast.Expr) and _is_submit_call(n.value):
                out.append((n.lineno, ".submit(...) result discarded"))
                continue
            if not (isinstance(n, ast.Assign) and len(n.targets) == 1
                    and _is_submit_call(n.value)):
                continue
            target = _load_dotted(n.targets[0])
            if target is None:
                continue
            consumed = False
            for m in ast.walk(scope):
                if (m is n.targets[0]
                        or not isinstance(getattr(m, "ctx", None), ast.Load)
                        or _load_dotted(m) != target):
                    continue
                p = parent.get(m)
                if isinstance(p, ast.Attribute):
                    if p.attr in _FUTURE_CONSUMERS:
                        consumed = True
                    elif p.attr not in _FUTURE_STATE_ATTRS:
                        consumed = True     # not a Future API: not ours
                else:
                    consumed = True         # escapes: consumer's problem
            if not consumed:
                out.append((n.lineno,
                            f"future {target!r} never consumed (no "
                            f".result/.exception/.add_done_callback)"))
    return out


def _scan_modules(src_root: str) -> dict[str, _ModuleScan]:
    scans = {}
    for path in _iter_sources(src_root):
        mod = _module_name(src_root, path)
        with open(path, "r", encoding="utf-8") as fh:
            text = fh.read()
        scan = _ModuleScan(mod, path)
        scan.source = text
        try:
            tree = ast.parse(text, filename=path)
        except SyntaxError as e:
            raise SyntaxError(f"{path}: {e}") from e
        scan.visit(tree)
        scan.dropped_futures = _dropped_futures(tree)
        scans[mod] = scan
    return scans


def _reachable(scans: dict[str, _ModuleScan], roots) -> set[str]:
    known = set(scans)
    seen, frontier = set(), [r for r in roots if r in known]
    while frontier:
        mod = frontier.pop()
        if mod in seen:
            continue
        seen.add(mod)
        # importing a module imports every package __init__ above it
        parts = mod.split(".")
        for i in range(1, len(parts)):
            parent = ".".join(parts[:i])
            if parent in known and parent not in seen:
                frontier.append(parent)
        for imp, _line in scans[mod].top_imports:
            if imp in known and imp not in seen:
                frontier.append(imp)
    return seen


def repo_findings(src_root: str | None = None) -> list[Finding]:
    """Run A001–A005 (plus S001 for malformed suppressions) over the
    repo source tree rooted at ``src_root`` (default: the ``src/``
    directory this package was imported from)."""
    if src_root is None:
        here = os.path.dirname(os.path.abspath(__file__))   # .../src/repro/analysis
        src_root = os.path.dirname(os.path.dirname(here))
    scans = _scan_modules(src_root)
    findings: list[Finding] = []
    suppressions = []
    for scan in scans.values():
        sups, meta = scan_suppressions(scan.path, scan.source)
        suppressions.extend(sups)
        findings.extend(meta)

    for mod, scan in sorted(scans.items()):
        for dotted, line in scan.calls:
            if dotted == "jax.random.choice":
                findings.append(Finding(
                    "A001",
                    "jax.random.choice is forbidden (length-dependent "
                    "lowering; use core.sampling.index_uniform)",
                    where=f"{scan.path}:{line}", file=scan.path, line=line))
            if dotted in _WALLCLOCK and mod.startswith(TRACED_PACKAGES):
                findings.append(Finding(
                    "A003",
                    f"wall-clock call {dotted} in traced package scope "
                    f"({mod}) — a clock read under jit is frozen at "
                    f"trace time; move it to the host-side caller",
                    where=f"{scan.path}:{line}", file=scan.path, line=line))
        if mod.startswith(ERROR_CONVERTING_PACKAGE):
            for line, clause in scan.swallows:
                findings.append(Finding(
                    "A004",
                    f"{clause} in {mod} neither re-raises nor uses the "
                    f"caught exception — the fault-isolation layer must "
                    f"convert failures to structured errors "
                    f"(RequestError / a counted rejection), never "
                    f"swallow them",
                    where=f"{scan.path}:{line}", file=scan.path, line=line))
            for line, desc in scan.dropped_futures:
                findings.append(Finding(
                    "A005",
                    f"{desc} in {mod} — an error raised on the executor "
                    f"thread lives only on the future; join it, read "
                    f".exception(), or attach a done-callback so the "
                    f"failure reaches the completion path",
                    where=f"{scan.path}:{line}", file=scan.path, line=line))

    reach = _reachable(scans, FAST_PATH_ROOTS)
    for mod in sorted(reach):
        for imp, line in scans[mod].top_imports:
            if imp == "repro.dist" or imp.startswith("repro.dist."):
                findings.append(Finding(
                    "A002",
                    f"module-level import of {imp} in {mod}, which is "
                    f"reachable from the mesh=None fast path — defer it "
                    f"into the mesh branch (see engine/engine.py)",
                    where=f"{scans[mod].path}:{line}",
                    file=scans[mod].path, line=line))
                break
    return apply_suppressions(findings, suppressions)
