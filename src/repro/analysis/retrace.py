"""Recompile-hazard lint: catch operands that defeat the jit cache.

``jax.jit`` caches on (abstract shapes, static values, *and leaf
types*): a ``numpy.ndarray`` leaf hashes to a different cache entry
than the equal ``jax.Array`` — PR 6 chased a per-bucket recompile down
to exactly that (numpy key stacks reaching ``Batch.make``).  These
rules find the hazard statically:

* **R001** — ``numpy.ndarray`` (or other non-``jax.Array`` array) leaf
  in a traced operand tree.
* **R002** — bare python scalar leaf in traced position (warning: same
  cache entry, but weak-type promotion can change results vs an
  explicit dtype).
* **R003** — unhashable value in a *static* argument position.
* **R004** — observed shape-cache growth across representative input
  mixes (the executable generalization of the compile-once fixtures in
  ``tests/test_engine.py`` / ``tests/test_serve.py``).

:func:`compile_cache_size` is the one implementation of the
compile-count probe those tests now share.
"""
from __future__ import annotations

import jax
import numpy as np

from .findings import Finding


def _leaf_paths(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        yield jax.tree_util.keystr(path), leaf


def leaf_findings(tree, where: str = "operands") -> list[Finding]:
    """R001/R002 over every leaf of a traced-operand pytree."""
    out: list[Finding] = []
    for path, leaf in _leaf_paths(tree):
        loc = f"{where}{path}"
        if isinstance(leaf, jax.Array):
            continue
        if isinstance(leaf, np.ndarray) or (
                hasattr(leaf, "shape") and hasattr(leaf, "dtype")
                and hasattr(leaf, "__array__")):
            out.append(Finding(
                "R001",
                f"{type(leaf).__module__}.{type(leaf).__name__} leaf "
                f"(shape {getattr(leaf, 'shape', '?')}) — each call site "
                f"passing a different leaf type gets its own jit cache "
                f"entry; canonicalize with jnp.asarray at the boundary",
                where=loc))
        elif isinstance(leaf, (bool, int, float, complex)) and not isinstance(
                leaf, np.generic):
            out.append(Finding(
                "R002",
                f"python {type(leaf).__name__} leaf {leaf!r} in traced "
                f"position — weak-type promotion hazard; wrap with "
                f"jnp.asarray(..., dtype=...)",
                where=loc))
    return out


def static_findings(statics: dict, where: str = "statics") -> list[Finding]:
    """R003 over values bound to static (hashed, not traced) argument
    positions."""
    out: list[Finding] = []
    for name, value in statics.items():
        try:
            hash(value)
        except TypeError:
            out.append(Finding(
                "R003",
                f"static argument {name!r} = {type(value).__name__} is "
                f"unhashable — jit cannot cache on it (freeze it: tuple, "
                f"frozen dataclass, or a registered hashable wrapper)",
                where=f"{where}.{name}"))
    return out


def compile_cache_size(fn) -> int:
    """Number of compiled entries behind ``fn``.

    Accepts a ``jax.jit``-wrapped callable (uses its ``_cache_size``),
    or any object exposing ``compile_count`` (e.g. ``PCNEngine``).
    This is the single compile-count probe shared by the compile-once
    tests and the R004 check.
    """
    if hasattr(fn, "_cache_size"):
        return int(fn._cache_size())
    if hasattr(fn, "compile_count"):
        return int(fn.compile_count)
    owner = getattr(fn, "__self__", None)   # bound method, e.g. eng.apply
    if owner is not None and hasattr(owner, "compile_count"):
        return int(owner.compile_count)
    raise TypeError(
        f"cannot read a compile-cache size from {type(fn).__name__}; "
        f"expected a jax.jit callable or an object with .compile_count")


def cache_growth_findings(fn, arg_sets, *, expected: int = 1,
                          where: str = "jit") -> list[Finding]:
    """R004: call ``fn`` once per argument tuple in ``arg_sets`` (all of
    one logical shape class) and flag if the cache ends up larger than
    ``expected``.  This executes the function — keep the inputs small."""
    for args in arg_sets:
        fn(*args)
    size = compile_cache_size(fn)
    if size > expected:
        return [Finding(
            "R004",
            f"shape cache grew to {size} entries across "
            f"{len(arg_sets)} same-shape input mixes (expected "
            f"{expected}) — some input form retraces",
            where=where)]
    return []
