"""Kernel lint: static checks over ``pallas_call`` equations in a jaxpr.

Nothing here executes a kernel.  We walk a (closed) jaxpr, collect
every ``pallas_call`` equation — descending into ``pjit`` / control-flow
sub-jaxprs — and check each call site's grid mapping:

* **K001** — the per-call VMEM block footprint (streamed operands
  double-buffered, resident operands single-buffered) must fit the
  declared budget.  This is the same byte model the tile planners in
  :mod:`repro.kernels.tiling` use, so plan and lint cannot drift.
* **K002** — every block's last dim must be a 128-lane multiple *or*
  the operand's full width (small side inputs like a 3-wide centre
  block legitimately stream their whole minor axis).
* **K003** — evaluating each operand's index map at the grid corners
  must never place a tile fully outside the operand (overhang of the
  final partial tile is fine; a whole out-of-bounds tile means the
  grid over-counts).
* **K004** — an operand whose index map is constant across the grid is
  VMEM-resident; its block must then cover the whole array, or part of
  the operand is silently unreachable.
* **K005** — ``dimension_semantics`` must match the grid rank, and any
  axis marked ``"parallel"`` must vary every *output* index map (two
  parallel grid steps writing one output block is a race).

The walker (:func:`pallas_call_sites`) is also the one implementation
of the dispatch-count invariant pinned by ``tests/test_batched_fc.py``
and the ``scripts/ci.sh`` batched-kernel smoke.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass

import numpy as np

from repro.kernels.tiling import LANE, block_bytes, call_footprint_bytes

from .findings import Finding

_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr", "branches")


def _subjaxprs(eqn):
    """Yield every sub-jaxpr of an equation (pjit, scan, cond, ...)."""
    for key in _SUBJAXPR_PARAMS:
        v = eqn.params.get(key)
        if v is None:
            continue
        for item in v if isinstance(v, (tuple, list)) else (v,):
            jx = getattr(item, "jaxpr", item)
            if hasattr(jx, "eqns"):
                yield jx


@dataclass
class OperandInfo:
    """Static view of one pallas_call operand (input or output)."""
    index: int
    array_shape: tuple[int, ...]
    block_shape: tuple[int, ...]
    dtype: np.dtype
    is_output: bool
    resident: bool                      # index map constant over the grid
    tile_indices: tuple[tuple[int, ...], ...]  # index-map outputs at probed grid pts

    @property
    def block_elems(self) -> int:
        return int(np.prod([d for d in self.block_shape if isinstance(d, int)] or [1]))


@dataclass
class KernelSite:
    """One pallas_call equation, statically summarized."""
    name: str
    grid: tuple[int, ...]
    dimension_semantics: tuple | None
    operands: list[OperandInfo]
    where: str

    @property
    def footprint_bytes(self) -> int:
        streamed = sum(block_bytes(o.block_shape, o.dtype) for o in self.operands
                       if not o.resident)
        resident = sum(block_bytes(o.block_shape, o.dtype) for o in self.operands
                       if o.resident)
        return call_footprint_bytes(streamed, resident)


def _grid_probe_points(grid):
    """Corner points of the grid (plus origin) — cheap but covers the
    first/last tile of every axis, which is where OOB and residency
    violations show up for the affine index maps this repo uses."""
    if not grid:
        return [()]
    axes = [sorted({0, max(0, int(g) - 1)}) for g in grid]
    pts = list(itertools.product(*axes))
    return pts[:64]  # bound the work for absurd ranks


def _eval_index_map(bm, point):
    from jax import core as jcore
    closed = bm.index_map_jaxpr
    out = jcore.eval_jaxpr(closed.jaxpr, closed.consts,
                           *[np.int32(p) for p in point])
    return tuple(int(v) for v in out)


def _site_from_eqn(eqn, where: str) -> KernelSite:
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    comp = eqn.params.get("compiler_params") or {}
    if hasattr(comp, "get"):
        sem = (comp.get("mosaic") or {}).get("dimension_semantics")
    else:  # dataclass-style compiler params on other jax versions
        sem = getattr(getattr(comp, "mosaic", None), "dimension_semantics", None)
    name = "pallas_call"
    nsi = eqn.params.get("name_and_src_info")
    if nsi is not None:
        name = getattr(nsi, "name", str(nsi))

    points = _grid_probe_points(grid)
    num_inputs = getattr(gm, "num_inputs", None)
    operands = []
    for i, bm in enumerate(gm.block_mappings):
        arr = bm.array_shape_dtype
        block = tuple(d if isinstance(d, int) else 1 for d in bm.block_shape)
        try:
            tiles = tuple(_eval_index_map(bm, p) for p in points)
        except Exception:
            tiles = ()
        resident = bool(tiles) and len(set(tiles)) == 1
        operands.append(OperandInfo(
            index=i,
            array_shape=tuple(int(d) for d in arr.shape),
            block_shape=block,
            dtype=np.dtype(arr.dtype),
            is_output=(num_inputs is not None and i >= num_inputs),
            resident=resident,
            tile_indices=tiles,
        ))
    return KernelSite(name=name, grid=grid, dimension_semantics=sem,
                      operands=operands, where=where)


def pallas_call_sites(jaxpr, where: str = "jaxpr") -> list[KernelSite]:
    """Collect every pallas_call site in ``jaxpr`` (a ``Jaxpr`` or
    ``ClosedJaxpr``), descending into pjit/scan/cond/while sub-jaxprs."""
    jx = getattr(jaxpr, "jaxpr", jaxpr)
    sites: list[KernelSite] = []
    counters: dict[str, int] = {}

    def walk(j):
        for eqn in j.eqns:
            if eqn.primitive.name == "pallas_call":
                nsi = eqn.params.get("name_and_src_info")
                base = getattr(nsi, "name", "pallas_call") if nsi else "pallas_call"
                k = counters.get(base, 0)
                counters[base] = k + 1
                sites.append(_site_from_eqn(eqn, f"{where}/{base}#{k}"))
                # kernel bodies can in principle nest pallas_calls; they
                # don't in this repo, so don't descend into eqn.params.
                continue
            for sub in _subjaxprs(eqn):
                walk(sub)

    walk(jx)
    return sites


def count_pallas_calls(jaxpr, grids: list | None = None) -> int:
    """Dispatch-count invariant: number of pallas_call sites.  If
    ``grids`` is given, each site's grid tuple is appended (the shape
    the migrated ``tests/test_batched_fc.py`` walker reported)."""
    sites = pallas_call_sites(jaxpr)
    if grids is not None:
        grids.extend(s.grid for s in sites)
    return len(sites)


def check_kernel_site(site: KernelSite, *, vmem_budget_mb: float) -> list[Finding]:
    out: list[Finding] = []
    budget = int(vmem_budget_mb * 2**20)
    fp = site.footprint_bytes
    if fp > budget:
        out.append(Finding(
            "K001",
            f"block footprint {fp / 2**20:.2f} MiB exceeds the "
            f"{vmem_budget_mb:.2f} MiB VMEM budget (grid={site.grid})",
            where=site.where))

    for o in site.operands:
        if not o.block_shape or not o.array_shape:
            continue
        last_blk, last_arr = o.block_shape[-1], o.array_shape[-1]
        role = "output" if o.is_output else f"operand {o.index}"
        if last_blk % LANE != 0 and last_blk != last_arr:
            out.append(Finding(
                "K002",
                f"{role}: block last dim {last_blk} is neither a multiple of "
                f"{LANE} nor the full array width {last_arr} "
                f"(block={o.block_shape}, array={o.array_shape})",
                where=site.where))

        # block_shape may omit leading mapped dims relative to the array
        # (vmapped calls); align the two shapes from the right.
        nd = min(len(o.block_shape), len(o.array_shape))
        blk = o.block_shape[-nd:]
        arr = o.array_shape[-nd:]
        for tile in o.tile_indices:
            if len(tile) != nd:
                break
            for d, (ti, bd, ad) in enumerate(zip(tile, blk, arr)):
                if ti < 0 or ti * bd >= ad:
                    out.append(Finding(
                        "K003",
                        f"{role}: index map emits tile index {ti} on dim {d} "
                        f"(block {bd}, array {ad}) — tile starts outside the "
                        f"operand",
                        where=site.where))
                    break
            else:
                continue
            break  # one K003 per operand is enough

        if o.resident and not o.is_output:
            if len(tilezip := list(zip(o.block_shape[-nd:], o.array_shape[-nd:]))):
                covered = all(bd >= ad for bd, ad in tilezip)
                at_origin = all(i == 0 for i in (o.tile_indices[0] if o.tile_indices else ()))
                if not (covered and at_origin):
                    out.append(Finding(
                        "K004",
                        f"operand {o.index} is resident (constant index map "
                        f"{o.tile_indices[0] if o.tile_indices else '?'}) but its block "
                        f"{o.block_shape} does not cover the array {o.array_shape}",
                        where=site.where))

    sem = site.dimension_semantics
    if sem is not None:
        if len(sem) != len(site.grid):
            out.append(Finding(
                "K005",
                f"dimension_semantics {tuple(sem)} has rank {len(sem)} but the "
                f"grid {site.grid} has rank {len(site.grid)}",
                where=site.where))
        else:
            for axis, s in enumerate(sem):
                if s != "parallel" or site.grid[axis] <= 1:
                    continue
                for o in site.operands:
                    if not o.is_output or len(o.tile_indices) < 2:
                        continue
                    # does this output's index map vary along `axis`?
                    pts = _grid_probe_points(site.grid)
                    by_rest = {}
                    varies = False
                    for p, t in zip(pts, o.tile_indices):
                        rest = tuple(v for a, v in enumerate(p) if a != axis)
                        if rest in by_rest and by_rest[rest] != t:
                            varies = True
                            break
                        by_rest.setdefault(rest, t)
                    if not varies:
                        out.append(Finding(
                            "K005",
                            f"grid axis {axis} is 'parallel' but output "
                            f"{o.index}'s index map does not vary along it "
                            f"(parallel iterations would race on one block)",
                            where=site.where))
    return out


def kernel_findings(jaxpr, *, vmem_budget_mb: float, where: str = "jaxpr") -> list[Finding]:
    """Run K001–K005 over every pallas_call site in ``jaxpr``."""
    out: list[Finding] = []
    for site in pallas_call_sites(jaxpr, where=where):
        out.extend(check_kernel_site(site, vmem_budget_mb=vmem_budget_mb))
    return out
