"""repro.analysis — static analysis for the PCN engine's contracts.

Four rule families over traced jaxprs and repo source (no kernel ever
executes):

=======  ==========================================================
family   rules
=======  ==========================================================
kernel   K001 VMEM budget · K002 lane alignment · K003 grid/index
         coverage · K004 resident-operand coverage · K005
         dimension_semantics sanity
retrace  R001 numpy leaf · R002 python-scalar leaf · R003
         unhashable static · R004 shape-cache growth
masking  M001 unguarded reduction over a point axis
repo     A001 jax.random.choice · A002 dist import on the fast
         path · A003 wall-clock under trace · A004 silent
         error-swallowing except in the serving layer
=======  ==========================================================

CLI: ``python -m repro.analysis [--strict] [--json PATH]``; inline
suppressions: ``# analysis: allow K002 [pattern] -- justification``.
"""
from .findings import (ERROR, WARNING, Finding, RULES, Suppression, active,
                       apply_suppressions, scan_suppressions)
from .kernels import (KernelSite, OperandInfo, check_kernel_site,
                      count_pallas_calls, kernel_findings, pallas_call_sites)
from .masking import masked_reduction_findings
from .repolint import repo_findings
from .retrace import (cache_growth_findings, compile_cache_size,
                      leaf_findings, static_findings)
from .targets import (Target, default_targets, reduced_specs,
                      spec_point_sizes)

__all__ = [
    "ERROR", "WARNING", "Finding", "RULES", "Suppression", "active",
    "apply_suppressions", "scan_suppressions",
    "KernelSite", "OperandInfo", "check_kernel_site", "count_pallas_calls",
    "kernel_findings", "pallas_call_sites",
    "masked_reduction_findings",
    "repo_findings",
    "cache_growth_findings", "compile_cache_size", "leaf_findings",
    "static_findings",
    "Target", "default_targets", "reduced_specs", "spec_point_sizes",
]
