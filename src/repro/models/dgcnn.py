"""DGCNN [48] — EdgeConv benchmark, (c) classification / (s) segmentation.

EdgeConv: every point is a center (sampler="all"), k=20, MLP input
[f_j − f_i, f_i].  Accelerator-standard simplification (as in Mesorasi /
EdgePC): the neighbor graph is built in coordinate space for all layers
(the original paper rebuilds it in feature space; DS accelerators gather
spatially).  DGCNN(c) applies activation at block end, which makes L-PCN's
delta compensation exact (paper §VI-E).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (BlockSpec, PCNSpec, apply_head, init_model,
                     run_blocks, total_report)
from repro.core.mlp import apply_mlp

DGCNN_C = PCNSpec(
    name="dgcnn_c",
    blocks=(
        BlockSpec(1024, 20, (64,), kind="edge", sampler="all"),
        BlockSpec(1024, 20, (64,), kind="edge", sampler="all"),
        BlockSpec(1024, 20, (128,), kind="edge", sampler="all"),
        BlockSpec(1024, 20, (256,), kind="edge", sampler="all"),
    ),
    head_dims=(512, 256),
    n_classes=40,
    activation="block_end",   # -> exact delta compensation (paper §VI-E)
)

DGCNN_S = PCNSpec(
    name="dgcnn_s",
    blocks=(
        BlockSpec(8192, 20, (64,), kind="edge", sampler="all"),
        BlockSpec(8192, 20, (64,), kind="edge", sampler="all"),
        BlockSpec(8192, 20, (64,), kind="edge", sampler="all"),
    ),
    head_dims=(256, 128),
    n_classes=20,
    in_feats=6,
    task="seg",
    activation="block_end",
)


def with_points(spec: PCNSpec, n: int) -> PCNSpec:
    """Rescale an `all`-sampler spec to an n-point cloud."""
    from dataclasses import replace
    return replace(spec, blocks=tuple(
        BlockSpec(n, b.k, b.mlp_dims, b.radius, b.kind, b.sampler,
                  b.neighbor) for b in spec.blocks))


def init(key, spec=DGCNN_C):
    return init_model(key, spec)


def apply(params, spec, xyz, feats, key, mode: str = "lpcn",
          isl_kw: dict | None = None, with_report: bool = False):
    """EdgeConv stack; every layer keeps all N points (no downsampling)."""
    reports = []
    f = feats
    per_layer = []
    for b, mlp in zip(spec.blocks, params["blocks"]):
        key, sub = jax.random.split(key)
        from .common import lpcn_cfg_for
        from repro.core.pipeline import lpcn_block
        cfg = lpcn_cfg_for(b, mode, isl_kw or {})
        out = lpcn_block(cfg, mlp, xyz, f, sub, with_report=with_report)
        f = out.features
        per_layer.append(f)
        if with_report and out.report is not None:
            reports.append(out.report)
    cat = jnp.concatenate(per_layer, axis=-1)
    if spec.task == "cls":
        g = cat.max(axis=0)
        return apply_head(params, g), total_report(reports)
    g = cat.max(axis=0, keepdims=True)
    per_point = jnp.concatenate(
        [cat, jnp.broadcast_to(g, cat.shape[:1] + g.shape[1:])], axis=-1)
    return apply_head(params, per_point), total_report(reports)


def init_for_task(key, spec):
    """Head input dim differs from the generic initializer (concat of all
    EdgeConv outputs [+ global]), so rebuild the head accordingly."""
    from repro.core.mlp import init_mlp
    params = init_model(key, spec)
    cat_dim = sum(b.mlp_dims[-1] for b in spec.blocks)
    head_in = cat_dim if spec.task == "cls" else 2 * cat_dim
    key, sub = jax.random.split(key)
    params["head"] = init_mlp(sub, [head_in, *spec.head_dims,
                                    spec.n_classes], "per_layer")
    return params
