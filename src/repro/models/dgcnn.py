"""DGCNN [48] — EdgeConv benchmark, (c) classification / (s) segmentation.

EdgeConv: every point is a center (sampler="all"), k=20, MLP input
[f_j − f_i, f_i].  Accelerator-standard simplification (as in Mesorasi /
EdgePC): the neighbor graph is built in coordinate space for all layers
(the original paper rebuilds it in feature space; DS accelerators gather
spatially).  DGCNN(c) applies activation at block end, which makes L-PCN's
delta compensation exact (paper §VI-E).
"""
from __future__ import annotations

from .common import BlockSpec, PCNSpec

DGCNN_C = PCNSpec(
    name="dgcnn_c",
    blocks=(
        BlockSpec(1024, 20, (64,), kind="edge", sampler="all"),
        BlockSpec(1024, 20, (64,), kind="edge", sampler="all"),
        BlockSpec(1024, 20, (128,), kind="edge", sampler="all"),
        BlockSpec(1024, 20, (256,), kind="edge", sampler="all"),
    ),
    head_dims=(512, 256),
    n_classes=40,
    activation="block_end",   # -> exact delta compensation (paper §VI-E)
)

DGCNN_S = PCNSpec(
    name="dgcnn_s",
    blocks=(
        BlockSpec(8192, 20, (64,), kind="edge", sampler="all"),
        BlockSpec(8192, 20, (64,), kind="edge", sampler="all"),
        BlockSpec(8192, 20, (64,), kind="edge", sampler="all"),
    ),
    head_dims=(256, 128),
    n_classes=20,
    in_feats=6,
    task="seg",
    activation="block_end",
)


def with_points(spec: PCNSpec, n: int) -> PCNSpec:
    """Rescale an `all`-sampler spec to an n-point cloud."""
    from dataclasses import replace
    return replace(spec, blocks=tuple(
        BlockSpec(n, b.k, b.mlp_dims, b.radius, b.kind, b.sampler,
                  b.neighbor) for b in spec.blocks))

# The PR-1 ``init``/``apply``/``init_for_task`` dict shims completed
# their one-more-cycle deprecation window and are gone: use
# ``repro.engine.init`` (builds the task-correct concat head) /
# ``engine.apply`` / ``engine.apply_single``.
