"""PointVector [12] — vector-representation PointNet++ variant (§VI-D).

PointVector-L aggregates neighbor features through a *vector-attention*
style linear combination before pooling.  Crucially for L-PCN, the variant
evaluated in the paper applies its activation at the END of each building
block (paper §VI-E), so cached pre-activation results are compensated
exactly: CONV(A−B) = CONV(A) − CONV(B).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mlp import apply_mlp, init_mlp
from repro.core.pipeline import lpcn_block
from .common import (BlockSpec, PCNSpec, apply_head, feature_propagation,
                     lpcn_cfg_for, total_report)

POINTVECTOR_L = PCNSpec(
    name="pointvector_l",
    blocks=(
        BlockSpec(2048, 32, (96,), radius=0.1),
        BlockSpec(512, 32, (192,), radius=0.2),
        BlockSpec(128, 32, (384,), radius=0.4),
        BlockSpec(32, 32, (768,), radius=0.8),
    ),
    head_dims=(256, 128),
    n_classes=13,
    in_feats=6,
    task="seg",
    activation="block_end",   # -> exact delta compensation (paper §VI-E)
)


def init(key, spec=POINTVECTOR_L, stem_dim: int = 64):
    params = {"stem": None, "blocks": [], "vector": [], "head": None}
    key, sub = jax.random.split(key)
    params["stem"] = init_mlp(sub, [spec.in_feats, stem_dim], "per_layer")
    f = stem_dim
    for b in spec.blocks:
        key, s1, s2 = jax.random.split(key, 3)
        params["blocks"].append(
            init_mlp(s1, [3 + f, *b.mlp_dims], spec.activation))
        f = b.mlp_dims[-1]
        # vector branch: per-center linear recombination post-pooling
        params["vector"].append(init_mlp(s2, [f, f], "per_layer"))
    key, sub = jax.random.split(key)
    params["head"] = init_mlp(sub, [f, *spec.head_dims, spec.n_classes],
                              "per_layer")
    return params


def apply(params, spec, xyz, feats, key, mode: str = "lpcn",
          isl_kw: dict | None = None, with_report: bool = False):
    reports = []
    f = apply_mlp(params["stem"], feats)
    cur_xyz = xyz
    xyz_levels = [xyz]
    for b, mlp, vec in zip(spec.blocks, params["blocks"], params["vector"]):
        key, sub = jax.random.split(key)
        cfg = lpcn_cfg_for(b, mode, isl_kw or {})
        out = lpcn_block(cfg, mlp, cur_xyz, f, sub, with_report=with_report)
        f = jax.nn.relu(apply_mlp(vec, out.features))   # vector recombine
        cur_xyz = out.center_xyz
        xyz_levels.append(cur_xyz)
        if with_report and out.report is not None:
            reports.append(out.report)
    for lvl in range(len(spec.blocks) - 1, -1, -1):
        f = feature_propagation(xyz_levels[lvl], xyz_levels[lvl + 1], f)
    return apply_head(params, f), total_report(reports)
