"""PointVector [12] — vector-representation PointNet++ variant (§VI-D).

PointVector-L aggregates neighbor features through a *vector-attention*
style linear combination before pooling.  Crucially for L-PCN, the variant
evaluated in the paper applies its activation at the END of each building
block (paper §VI-E), so cached pre-activation results are compensated
exactly: CONV(A−B) = CONV(A) − CONV(B).
"""
from __future__ import annotations

from .common import BlockSpec, PCNSpec

POINTVECTOR_L = PCNSpec(
    name="pointvector_l",
    blocks=(
        BlockSpec(2048, 32, (96,), radius=0.1),
        BlockSpec(512, 32, (192,), radius=0.2),
        BlockSpec(128, 32, (384,), radius=0.4),
        BlockSpec(32, 32, (768,), radius=0.8),
    ),
    head_dims=(256, 128),
    n_classes=13,
    in_feats=6,
    task="seg",
    activation="block_end",   # -> exact delta compensation (paper §VI-E)
)


def init(key, spec=POINTVECTOR_L, stem_dim: int = 64):
    """DEPRECATED shim: legacy dict params (use ``repro.engine.init``)."""
    from repro import engine
    from repro.engine.archs import _init_pointvector
    return engine.to_legacy(_init_pointvector(key, spec, stem_dim),
                            "pointvector")


def apply(params, spec, xyz, feats, key, mode: str = "lpcn",
          isl_kw: dict | None = None, with_report: bool = False):
    """DEPRECATED shim: routes through ``repro.engine.apply_single``."""
    from repro import engine
    return engine.apply_single(params, xyz, feats, key, spec=spec,
                               mode=mode, isl_kw=isl_kw,
                               with_report=with_report)
