"""PointVector [12] — vector-representation PointNet++ variant (§VI-D).

PointVector-L aggregates neighbor features through a *vector-attention*
style linear combination before pooling.  Crucially for L-PCN, the variant
evaluated in the paper applies its activation at the END of each building
block (paper §VI-E), so cached pre-activation results are compensated
exactly: CONV(A−B) = CONV(A) − CONV(B).
"""
from __future__ import annotations

from .common import BlockSpec, PCNSpec

POINTVECTOR_L = PCNSpec(
    name="pointvector_l",
    blocks=(
        BlockSpec(2048, 32, (96,), radius=0.1),
        BlockSpec(512, 32, (192,), radius=0.2),
        BlockSpec(128, 32, (384,), radius=0.4),
        BlockSpec(32, 32, (768,), radius=0.8),
    ),
    head_dims=(256, 128),
    n_classes=13,
    in_feats=6,
    task="seg",
    activation="block_end",   # -> exact delta compensation (paper §VI-E)
)

# The PR-1 ``init``/``apply`` dict shims completed their one-more-cycle
# deprecation window and are gone: use ``repro.engine.init`` /
# ``engine.apply`` / ``engine.apply_single``.
