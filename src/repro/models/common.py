"""Shared PCN model machinery — spec re-export layer.

The typed, batch-first API lives in :mod:`repro.engine`; this module
re-exports the spec types from there so historical ``from
repro.models.common import BlockSpec`` imports keep working.  The PR-1
dict-based helpers (``init_model`` / ``run_blocks`` / ``global_pool`` /
``apply_head``) completed their one-more-cycle deprecation window and
are gone — use ``engine.init`` / ``engine.apply`` /
``engine.apply_single`` (and ``engine.to_legacy`` where an old dict
layout is genuinely needed).
"""
from __future__ import annotations

from repro.engine.spec import BlockSpec, PCNSpec, block_in_dim  # noqa: F401
