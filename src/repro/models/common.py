"""Shared PCN model machinery — DEPRECATED compatibility layer.

The typed, batch-first API lives in :mod:`repro.engine`; this module
re-exports the spec types from there and keeps the historical dict-based
helpers as thin shims so old call sites keep working.  New code should
use ``engine.init`` / ``engine.apply`` / ``engine.PCNEngine``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mlp import apply_mlp
from repro.core.pipeline import LPCNConfig, lpcn_block
from repro.core.workload import WorkloadReport
from repro.engine.spec import BlockSpec, PCNSpec, block_in_dim  # noqa: F401


def init_model(key: jax.Array, spec: PCNSpec):
    """DEPRECATED: legacy dict-layout init; routes through
    ``repro.engine`` (generic SA-stack family) and converts back."""
    from repro import engine
    from repro.engine.archs import _init_pointnet2
    return engine.to_legacy(_init_pointnet2(key, spec), "pointnet2")


def lpcn_cfg_for(b: BlockSpec, mode: str, isl_kw: dict) -> LPCNConfig:
    return LPCNConfig(n_centers=b.n_centers, k=b.k, sampler=b.sampler,
                      neighbor=b.neighbor, radius=b.radius, mode=mode,
                      block_kind=b.kind, **isl_kw)


def run_blocks(params, spec: PCNSpec, xyz, feats, key, mode: str,
               isl_kw: dict | None = None, with_report: bool = False):
    """DEPRECATED (use ``repro.engine``): run the block stack on ONE
    cloud.  Returns (center_xyz, center_f, reports, per_block_outputs)."""
    isl_kw = isl_kw or {}
    reports, saved = [], []
    cur_xyz, cur_f = xyz, feats
    for b, mlp in zip(spec.blocks, params["blocks"]):
        key, sub = jax.random.split(key)
        cfg = lpcn_cfg_for(b, mode, isl_kw)
        out = lpcn_block(cfg, mlp, cur_xyz, cur_f, sub,
                         with_report=with_report)
        saved.append((cur_xyz, cur_f, out))
        cur_xyz, cur_f = out.center_xyz, out.features
        if with_report and out.report is not None:
            reports.append(out.report)
    return cur_xyz, cur_f, reports, saved


def global_pool(params, spec: PCNSpec, center_xyz, center_f):
    """Final global SA: one subset containing every remaining center —
    the paper's example of a no-overlap layer (processed traditionally)."""
    if params["global"] is None:
        return center_f.max(axis=0)
    centroid = center_xyz.mean(axis=0)
    x = jnp.concatenate([center_xyz - centroid, center_f], axis=-1)
    h = apply_mlp(params["global"], x)
    return h.max(axis=0)


def feature_propagation(xyz_dst, xyz_src, f_src, k: int = 3):
    """DEPRECATED alias of :func:`repro.engine.feature_propagation`."""
    from repro.engine.archs import feature_propagation as fp
    return fp(xyz_dst, xyz_src, f_src, k)


def apply_head(params, f):
    return apply_mlp(params["head"], f)


def total_report(reports) -> WorkloadReport | None:
    if not reports:
        return None
    return WorkloadReport.total(reports)
