"""Shared PCN model machinery: block stacks, feature propagation, heads.

A model is (init(key, spec) -> params, apply(params, xyz, feats, key,
mode) -> (logits, reports)).  Every gather/MLP block routes through
``core.pipeline.lpcn_block`` so the Islandization Unit plugs into each
model uniformly (the paper's "seamlessly integrated" claim).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

from repro.core.mlp import MLP, Dense, apply_mlp, init_mlp
from repro.core.pipeline import LPCNConfig, lpcn_block
from repro.core.workload import WorkloadReport


@dataclass(frozen=True)
class BlockSpec:
    """One building block (SA or EdgeConv) of a PCN."""
    n_centers: int
    k: int
    mlp_dims: tuple            # hidden+out dims, input inferred
    radius: float = 0.2
    kind: str = "sa"           # sa | edge
    sampler: str = "fps"
    neighbor: str = "pointacc"


@dataclass(frozen=True)
class PCNSpec:
    """A whole point-cloud network."""
    name: str
    blocks: tuple              # tuple[BlockSpec]
    head_dims: tuple           # classifier / per-point head
    n_classes: int
    in_feats: int = 3          # input feature dim (xyz counts as features)
    task: str = "cls"          # cls | seg
    global_mlp: tuple = ()     # final global SA mlp (cls only)
    activation: str = "per_layer"   # per_layer | block_end (paper §VI-E)


def block_in_dim(kind: str, f_prev: int) -> int:
    return (3 + f_prev) if kind == "sa" else (2 * f_prev)


def init_model(key: jax.Array, spec: PCNSpec):
    """-> params dict: per-block MLPs + global MLP + head."""
    params = {"blocks": [], "global": None, "head": None}
    f = spec.in_feats
    for b in spec.blocks:
        key, sub = jax.random.split(key)
        dims = [block_in_dim(b.kind, f), *b.mlp_dims]
        params["blocks"].append(init_mlp(sub, dims, spec.activation))
        f = b.mlp_dims[-1]
    if spec.task == "cls":
        key, sub = jax.random.split(key)
        gdims = [3 + f, *spec.global_mlp] if spec.global_mlp else None
        if gdims:
            params["global"] = init_mlp(sub, gdims, spec.activation)
            f = spec.global_mlp[-1]
    key, sub = jax.random.split(key)
    params["head"] = init_mlp(sub, [f, *spec.head_dims, spec.n_classes],
                              "per_layer")
    return params


def lpcn_cfg_for(b: BlockSpec, mode: str, isl_kw: dict) -> LPCNConfig:
    return LPCNConfig(n_centers=b.n_centers, k=b.k, sampler=b.sampler,
                      neighbor=b.neighbor, radius=b.radius, mode=mode,
                      block_kind=b.kind, **isl_kw)


def run_blocks(params, spec: PCNSpec, xyz, feats, key, mode: str,
               isl_kw: dict | None = None, with_report: bool = False):
    """Run the block stack on ONE cloud.  Returns (center_xyz, center_f,
    reports, per_block_outputs)."""
    isl_kw = isl_kw or {}
    reports, saved = [], []
    cur_xyz, cur_f = xyz, feats
    for b, mlp in zip(spec.blocks, params["blocks"]):
        key, sub = jax.random.split(key)
        cfg = lpcn_cfg_for(b, mode, isl_kw)
        out = lpcn_block(cfg, mlp, cur_xyz, cur_f, sub,
                         with_report=with_report)
        saved.append((cur_xyz, cur_f, out))
        cur_xyz, cur_f = out.center_xyz, out.features
        if with_report and out.report is not None:
            reports.append(out.report)
    return cur_xyz, cur_f, reports, saved


def global_pool(params, spec: PCNSpec, center_xyz, center_f):
    """Final global SA: one subset containing every remaining center —
    the paper's example of a no-overlap layer (processed traditionally)."""
    if params["global"] is None:
        return center_f.max(axis=0)
    centroid = center_xyz.mean(axis=0)
    x = jnp.concatenate([center_xyz - centroid, center_f], axis=-1)
    h = apply_mlp(params["global"], x)
    return h.max(axis=0)


def feature_propagation(xyz_dst, xyz_src, f_src, k: int = 3):
    """PointNet++ FP layer: inverse-distance 3-NN interpolation of source
    center features onto destination points (segmentation upsampling)."""
    d = jnp.sum((xyz_dst[:, None, :] - xyz_src[None, :, :]) ** 2, -1)
    neg, idx = jax.lax.top_k(-d, k)
    w = 1.0 / jnp.maximum(-neg, 1e-8)
    w = w / w.sum(-1, keepdims=True)
    return (f_src[idx] * w[..., None]).sum(axis=1)


def apply_head(params, f):
    return apply_mlp(params["head"], f)


def total_report(reports) -> WorkloadReport | None:
    if not reports:
        return None
    return WorkloadReport.total(reports)
