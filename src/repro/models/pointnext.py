"""PointNeXt [40] — scalability-oriented PointNet++ variant (paper §VI-D).

PointNeXt-S: Stem MLP (per-point feature expansion — the paper's example of
an unoptimizable no-overlap layer, ~0.1% of FLOPs) followed by SA stages
with InvResMLP residual blocks.  Radii double per stage; every SA gather
routes through the Islandization Unit.
"""
from __future__ import annotations

from .common import BlockSpec, PCNSpec

POINTNEXT_S = PCNSpec(
    name="pointnext_s",
    blocks=(
        BlockSpec(2048, 32, (64,), radius=0.1),
        BlockSpec(512, 32, (128,), radius=0.2),
        BlockSpec(128, 32, (256,), radius=0.4),
        BlockSpec(32, 32, (512,), radius=0.8),
    ),
    head_dims=(256, 128),
    n_classes=13,
    in_feats=6,
    task="seg",
    global_mlp=(),
)

STEM_DIM = 32

# The PR-1 ``init``/``apply`` dict shims completed their one-more-cycle
# deprecation window and are gone: use ``repro.engine.init`` /
# ``engine.apply`` / ``engine.apply_single``.
