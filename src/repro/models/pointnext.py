"""PointNeXt [40] — scalability-oriented PointNet++ variant (paper §VI-D).

PointNeXt-S: Stem MLP (per-point feature expansion — the paper's example of
an unoptimizable no-overlap layer, ~0.1% of FLOPs) followed by SA stages
with InvResMLP residual blocks.  Radii double per stage; every SA gather
routes through the Islandization Unit.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.mlp import MLP, apply_mlp, init_mlp
from repro.core.pipeline import lpcn_block
from .common import (BlockSpec, PCNSpec, apply_head, feature_propagation,
                     lpcn_cfg_for, total_report)

POINTNEXT_S = PCNSpec(
    name="pointnext_s",
    blocks=(
        BlockSpec(2048, 32, (64,), radius=0.1),
        BlockSpec(512, 32, (128,), radius=0.2),
        BlockSpec(128, 32, (256,), radius=0.4),
        BlockSpec(32, 32, (512,), radius=0.8),
    ),
    head_dims=(256, 128),
    n_classes=13,
    in_feats=6,
    task="seg",
    global_mlp=(),
)

STEM_DIM = 32


def init(key, spec=POINTNEXT_S, stem_dim: int = STEM_DIM):
    params = {"stem": None, "blocks": [], "invres": [], "head": None}
    key, sub = jax.random.split(key)
    params["stem"] = init_mlp(sub, [spec.in_feats, stem_dim], "per_layer")
    f = stem_dim
    for b in spec.blocks:
        key, s1, s2 = jax.random.split(key, 3)
        params["blocks"].append(
            init_mlp(s1, [3 + f, *b.mlp_dims], spec.activation))
        f = b.mlp_dims[-1]
        # InvResMLP: pointwise expansion x4 + projection, residual
        params["invres"].append(init_mlp(s2, [f, 4 * f, f], "per_layer"))
    key, sub = jax.random.split(key)
    params["head"] = init_mlp(sub, [f, *spec.head_dims, spec.n_classes],
                              "per_layer")
    return params


def apply(params, spec, xyz, feats, key, mode: str = "lpcn",
          isl_kw: dict | None = None, with_report: bool = False):
    reports = []
    # Stem MLP: per-point, no gather -> no overlap to exploit (counted in
    # benchmarks as untouched workload, paper §VI-C Limitation).
    f = apply_mlp(params["stem"], feats)
    cur_xyz = xyz
    xyz_levels = [xyz]
    for b, mlp, inv in zip(spec.blocks, params["blocks"], params["invres"]):
        key, sub = jax.random.split(key)
        cfg = lpcn_cfg_for(b, mode, isl_kw or {})
        out = lpcn_block(cfg, mlp, cur_xyz, f, sub, with_report=with_report)
        h = out.features
        f = h + apply_mlp(inv, h)          # InvResMLP residual
        cur_xyz = out.center_xyz
        xyz_levels.append(cur_xyz)
        if with_report and out.report is not None:
            reports.append(out.report)
    # decoder: FP all the way back (segmentation)
    for lvl in range(len(spec.blocks) - 1, -1, -1):
        f = feature_propagation(xyz_levels[lvl], xyz_levels[lvl + 1], f)
    return apply_head(params, f), total_report(reports)
