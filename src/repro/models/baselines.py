"""Workload-reduction baselines the paper compares against (§VI-C).

Mesorasi [16] — Delayed-Aggregation: precompute MLP(p, f) for every input
point into a Point Feature Table (PFT), plus MLP(c, 0) per center; a
subset's result is approximated by gather-combine:

    MLP(p − c, f)  ≈  PFT[p] − MLP(c, 0)        (exact iff MLP is linear)

This is "fully approximate" (every position approximated), whereas L-PCN
approximates only reused positions.  Its cost model: N + S MLP evals and a
PFT of N × F_out intermediate bytes whose re-fetch traffic becomes the
bottleneck (paper Fig. 17's off-chip setting) — modeled in
benchmarks/perfmodel.py.

GDPCA [5] — Geometry-aware Differential Update reduces input *bit width*
(not eval count) for a Bit-Pragmatic FCU; it has no JAX-visible FLOP
change, so its speedup lives entirely in the perf model
(benchmarks/perfmodel.py, `gdpca_fc_speedup`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mlp import MLP, apply_mlp, post_pool_activation
from repro.core.workload import WorkloadReport


def mesorasi_fc(mlp: MLP, xyz, feats, nbr_idx, centers_xyz,
                center_feats=None, kind: str = "sa"):
    """Delayed-Aggregation FC step.  Returns (S, F_out) like
    fc_traditional; approximation error appears through nonlinearity."""
    if kind == "sa":
        # PFT over all points: MLP(p, f); center table: MLP(c, 0)
        pft_in = jnp.concatenate([xyz, feats], axis=-1)
        pft = apply_mlp(mlp, pft_in)                       # (N, Fout)
        c_in = jnp.concatenate(
            [centers_xyz, jnp.zeros((centers_xyz.shape[0],
                                     feats.shape[1]), feats.dtype)], -1)
        c_tab = apply_mlp(mlp, c_in)                       # (S, Fout)
        gathered = pft[nbr_idx]                            # (S, K, Fout)
        combined = gathered - c_tab[:, None, :]
    else:  # edge: MLP(f_j - f_i, f_i) ~ MLP(f_j, 0) - MLP(f_i, 0) + MLP(0, f_i)
        D = feats.shape[1]
        z = jnp.zeros_like(feats)
        pft = apply_mlp(mlp, jnp.concatenate([feats, z], -1))   # (N, Fout)
        cf = center_feats
        zc = jnp.zeros_like(cf)
        c_neg = apply_mlp(mlp, jnp.concatenate([cf, zc], -1))
        c_self = apply_mlp(mlp, jnp.concatenate([zc, cf], -1))
        combined = pft[nbr_idx] - c_neg[:, None, :] + c_self[:, None, :]
    pooled = combined.max(axis=1)
    return post_pool_activation(mlp, pooled)


def mesorasi_workload(n_points: int, n_subsets: int, k: int
                      ) -> WorkloadReport:
    """Mesorasi's eval/fetch counts for one layer: N PFT evals + S center
    evals; every position re-fetches its PFT row (the delayed-aggregation
    phase traffic)."""
    base = n_subsets * k
    evals = n_points + n_subsets
    return WorkloadReport(
        baseline_fetches=base, lpcn_fetches=base,   # PFT refetch ≈ base
        baseline_mlp_evals=base, lpcn_mlp_evals=evals,
        n_subsets=n_subsets, n_islands_used=0, k=k)
