"""PCN benchmark models (paper Table I + §VI-D) and FC baselines."""
from . import pointnet2, dgcnn, pointnext, pointvector, baselines
from .common import BlockSpec, PCNSpec

MODEL_ZOO = {
    "pointnet2_c": (pointnet2, pointnet2.POINTNET2_C),
    "pointnet2_ps": (pointnet2, pointnet2.POINTNET2_PS),
    "pointnet2_s": (pointnet2, pointnet2.POINTNET2_S),
    "dgcnn_c": (dgcnn, dgcnn.DGCNN_C),
    "dgcnn_s": (dgcnn, dgcnn.DGCNN_S),
    "pointnext_s": (pointnext, pointnext.POINTNEXT_S),
    "pointvector_l": (pointvector, pointvector.POINTVECTOR_L),
}
