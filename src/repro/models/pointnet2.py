"""PointNet++ [39] — the paper's primary benchmark (3 task variants).

(c)  classification, ModelNet40, 1024 pts:  SA(512,32) SA(128,64) + global
(ps) part segmentation, ShapeNet, 2048 pts: SA stack + FP decoder
(s)  semantic segmentation, S3DIS, 4096 pts

Block shapes follow the original SSG configs (and paper Fig. 4a).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (BlockSpec, PCNSpec, apply_head, feature_propagation,
                     global_pool, init_model, run_blocks, total_report)

POINTNET2_C = PCNSpec(
    name="pointnet2_c",
    blocks=(
        BlockSpec(512, 32, (64, 64, 128), radius=0.2),
        BlockSpec(128, 64, (128, 128, 256), radius=0.4),
    ),
    global_mlp=(256, 512, 1024),
    head_dims=(512, 256),
    n_classes=40,
)

POINTNET2_PS = PCNSpec(
    name="pointnet2_ps",
    blocks=(
        BlockSpec(512, 32, (64, 64, 128), radius=0.2),
        BlockSpec(128, 64, (128, 128, 256), radius=0.4),
    ),
    head_dims=(256, 128),
    n_classes=50,
    task="seg",
)

POINTNET2_S = PCNSpec(
    name="pointnet2_s",
    blocks=(
        BlockSpec(1024, 32, (32, 32, 64), radius=0.1),
        BlockSpec(256, 32, (64, 64, 128), radius=0.2),
        BlockSpec(64, 32, (128, 128, 256), radius=0.4),
    ),
    head_dims=(256, 128),
    n_classes=13,
    in_feats=6,
    task="seg",
)


def init(key, spec=POINTNET2_C):
    return init_model(key, spec)


def apply(params, spec, xyz, feats, key, mode: str = "lpcn",
          isl_kw: dict | None = None, with_report: bool = False):
    """One cloud -> (logits, total WorkloadReport | None).

    cls:  (n_classes,) logits.   seg: (N, n_classes) per-point logits.
    """
    cx, cf, reports, saved = run_blocks(params, spec, xyz, feats, key,
                                        mode, isl_kw, with_report)
    if spec.task == "cls":
        g = global_pool(params, spec, cx, cf)
        return apply_head(params, g), total_report(reports)
    # segmentation: FP decoder back up the saved pyramid
    f = cf
    xyz_levels = [s[0] for s in saved] + [cx]
    for lvl in range(len(saved) - 1, -1, -1):
        src_xyz = xyz_levels[lvl + 1]
        dst_xyz = xyz_levels[lvl]
        f = feature_propagation(dst_xyz, src_xyz, f)
    return apply_head(params, f), total_report(reports)
