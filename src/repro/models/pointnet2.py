"""PointNet++ [39] — the paper's primary benchmark (3 task variants).

(c)  classification, ModelNet40, 1024 pts:  SA(512,32) SA(128,64) + global
(ps) part segmentation, ShapeNet, 2048 pts: SA stack + FP decoder
(s)  semantic segmentation, S3DIS, 4096 pts

Block shapes follow the original SSG configs (and paper Fig. 4a).
"""
from __future__ import annotations

from .common import BlockSpec, PCNSpec

POINTNET2_C = PCNSpec(
    name="pointnet2_c",
    blocks=(
        BlockSpec(512, 32, (64, 64, 128), radius=0.2),
        BlockSpec(128, 64, (128, 128, 256), radius=0.4),
    ),
    global_mlp=(256, 512, 1024),
    head_dims=(512, 256),
    n_classes=40,
)

POINTNET2_PS = PCNSpec(
    name="pointnet2_ps",
    blocks=(
        BlockSpec(512, 32, (64, 64, 128), radius=0.2),
        BlockSpec(128, 64, (128, 128, 256), radius=0.4),
    ),
    head_dims=(256, 128),
    n_classes=50,
    task="seg",
)

POINTNET2_S = PCNSpec(
    name="pointnet2_s",
    blocks=(
        BlockSpec(1024, 32, (32, 32, 64), radius=0.1),
        BlockSpec(256, 32, (64, 64, 128), radius=0.2),
        BlockSpec(64, 32, (128, 128, 256), radius=0.4),
    ),
    head_dims=(256, 128),
    n_classes=13,
    in_feats=6,
    task="seg",
)

# The PR-1 ``init``/``apply`` dict shims completed their one-more-cycle
# deprecation window and are gone: use ``repro.engine.init`` /
# ``engine.apply`` (batched) / ``engine.apply_single`` (one cloud);
# ``engine.to_legacy(params, "pointnet2")`` renders the old dict layout.
