"""Checkpoint manager: atomic, sharded, step-tagged, restart/elastic-safe.

Layout (one directory per step):
    <root>/step_000120/
        meta.json            — step, config hash, tree structure, shapes,
                               data-pipeline state, mesh shape at save
        host_000.npz         — this host's param/opt shards (flat leaves)
        COMMIT               — written LAST; a checkpoint without COMMIT is
                               ignored (atomicity under preemption)

Elastic restarts: leaves are saved as FULL arrays per host (single-host
dev container) or per-shard with index metadata (multi-host, addressable
shards).  On restore, arrays are re-sharded to the *current* mesh via
device_put — a checkpoint taken on 256 chips restores onto 512 (and vice
versa) because layout metadata is device-count-independent.

Fault tolerance contract (used by launch/train.py):
  * save every N steps + on SIGTERM (preemption hook)
  * restore() returns (step, params, opt_state, data_state) or None
  * keep the newest K checkpoints, delete older ones only AFTER the new
    COMMIT exists (never fewer than one committed checkpoint on disk).
"""
from __future__ import annotations

import json
import os
import shutil
import time

import jax
import ml_dtypes
import numpy as np

# npz can't hold bf16 etc. natively: store as uint16/uint8 views and
# record the logical dtype in meta.json
_VIEW = {"bfloat16": (np.uint16, ml_dtypes.bfloat16),
         "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn)}


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _paths(tree):
    return [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                 for k in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, params, opt_state, data_state: dict,
             extra: dict | None = None):
        d = os.path.join(self.root, f"step_{step:09d}")
        tmp = d + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        tree = {"params": params, "opt": opt_state}
        leaves, _ = _flatten(tree)
        names = _paths(tree)
        arrays = {}
        dtypes = {}
        for name, leaf in zip(names, leaves):
            a = np.asarray(jax.device_get(leaf))
            dtypes[name] = str(a.dtype)
            if str(a.dtype) in _VIEW:
                a = a.view(_VIEW[str(a.dtype)][0])
            arrays[name] = a
        np.savez(os.path.join(tmp, "host_000.npz"), **arrays)

        meta = {
            "step": step,
            "time": time.time(),
            "data_state": data_state,
            "n_devices": len(jax.devices()),
            "leaf_names": names,
            "leaf_dtypes": dtypes,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        os.replace(tmp, d)      # atomic publish
        self._gc()
        return d

    # -- restore ------------------------------------------------------------

    def latest_step(self) -> int | None:
        steps = []
        for n in os.listdir(self.root):
            if n.startswith("step_") and not n.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, n, "COMMIT")):
                    steps.append(int(n.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, params_like, opt_like, shardings=None):
        """-> (step, params, opt_state, data_state) or None.
        ``params_like``/``opt_like``: trees with the target structure
        (shapes validated).  ``shardings``: optional matching trees of
        NamedShardings for the *current* mesh (elastic re-shard)."""
        step = self.latest_step()
        if step is None:
            return None
        d = os.path.join(self.root, f"step_{step:09d}")
        meta = json.load(open(os.path.join(d, "meta.json")))
        data = np.load(os.path.join(d, "host_000.npz"))

        tree = {"params": params_like, "opt": opt_like}
        names = _paths(tree)
        leaves, treedef = _flatten(tree)
        sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                     if shardings is not None else [None] * len(leaves))
        dtypes = meta.get("leaf_dtypes", {})
        out = []
        for name, like, sh in zip(names, leaves, sh_leaves):
            arr = data[name]
            saved_dt = dtypes.get(name, str(arr.dtype))
            if saved_dt in _VIEW:
                arr = arr.view(_VIEW[saved_dt][1])
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"checkpoint leaf {name}: shape {arr.shape} != "
                    f"expected {like.shape}")
            arr = arr.astype(like.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        restored = jax.tree_util.tree_unflatten(treedef, out)
        return (meta["step"], restored["params"], restored["opt"],
                meta["data_state"])

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.root)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.root, n, "COMMIT")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"),
                          ignore_errors=True)
