"""Analytic per-device memory for every cell (no compile — fast).

    PYTHONPATH=src python -m repro.launch.memreport
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json      # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, SUBQUADRATIC, get_config  # noqa: E402
from repro.launch import memmodel  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/memmodel.json")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    dp = 32 if args.multi_pod else 16
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, sp in SHAPES.items():
            if sname == "long_500k" and arch not in SUBQUADRATIC:
                continue
            if sp.kind == "train":
                mb = max(min(16, sp.global_batch // dp), 1)
                accum = 2 if cfg.family == "moe" else 4
                r = memmodel.train_footprint(cfg, sname, mesh, mb,
                                             accum_bytes=accum)
            elif sp.kind == "decode":
                r = memmodel.decode_footprint(cfg, sname, mesh)
            else:  # prefill: no grads/opt/residual pyramid, last-token head
                full = memmodel.train_footprint(cfg, sname, mesh, 1)
                r = {
                    "params_bytes": full["params_bytes"],
                    "working_set_bytes": full["working_set_bytes"]
                    + full["residuals_bytes"] // max(cfg.n_layers, 1) * 2,
                    "total_bytes": full["params_bytes"]
                    + full["working_set_bytes"]
                    + full["residuals_bytes"] // max(cfg.n_layers, 1) * 2,
                }
                r["fits_16GiB"] = r["total_bytes"] < 16 * 2 ** 30
            r.update(arch=arch, shape=sname,
                     gib=round(r["total_bytes"] / 2**30, 2))
            out.append(r)
            print(f"{arch:28s} {sname:12s} {r['gib']:7.2f} GiB/chip "
                  f"fits={r['fits_16GiB']}")
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    json.dump(out, open(args.out, "w"), indent=1, default=str)


if __name__ == "__main__":
    main()
