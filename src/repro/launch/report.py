"""Final report generator: merge dry-run JSONs -> EXPERIMENTS-ready
markdown (dry-run summary + roofline table + memory table).

    PYTHONPATH=src python -m repro.launch.report
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import ARCH_IDS, SHAPES, SUBQUADRATIC
from repro.launch.roofline import build_rows, to_markdown


def merge(paths):
    recs = {}
    for p in paths:
        if not os.path.exists(p):
            continue
        for r in json.load(open(p)):
            recs[(r["arch"], r["shape"], r.get("multi_pod", False))] = r
    return list(recs.values())


def coverage(records, multi_pod):
    total = ok = skipped = err = missing = 0
    missing_cells = []
    for a in ARCH_IDS:
        for s in SHAPES:
            total += 1
            r = next((x for x in records
                      if x["arch"] == a and x["shape"] == s
                      and x.get("multi_pod", False) == multi_pod), None)
            if r is None:
                missing += 1
                missing_cells.append(f"{a}×{s}")
            elif r["status"] == "ok":
                ok += 1
            elif r["status"] == "skipped":
                skipped += 1
            else:
                err += 1
                missing_cells.append(f"{a}×{s}(ERR)")
    return dict(total=total, ok=ok, skipped=skipped, error=err,
                missing=missing, missing_cells=missing_cells)


def memory_table(path="results/memmodel.json"):
    if not os.path.exists(path):
        return "(memmodel.json missing)"
    rows = json.load(open(path))
    out = ["| arch | shape | GiB/chip (analytic) | fits 16 GiB |",
           "|---|---|---|---|"]
    for r in rows:
        out.append(f"| {r['arch']} | {r['shape']} | "
                   f"{r.get('gib','?')} | {r['fits_16GiB']} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    records = merge(["results/dryrun.json", "results/dryrun_2pod.json"])
    parts = []
    for mp in (False, True):
        cov = coverage(records, mp)
        mesh = "2×16×16 (512 chips)" if mp else "16×16 (256 chips)"
        parts.append(f"\n### Dry-run coverage — {mesh}\n")
        parts.append(
            f"{cov['ok']} ok / {cov['skipped']} skipped (documented "
            f"long_500k full-attention skips) / {cov['error']} error / "
            f"{cov['missing']} not-yet-compiled of {cov['total']} cells.")
        if cov["missing_cells"]:
            parts.append("Outstanding: " + ", ".join(cov["missing_cells"]))
    parts.append("\n### Roofline table — single-pod (per-chip terms)\n")
    parts.append(to_markdown(build_rows(records, False)))
    parts.append("\n### Roofline table — multi-pod\n")
    parts.append(to_markdown(build_rows(records, True)))
    parts.append("\n### Analytic per-device memory (launch/memmodel.py)\n")
    parts.append(memory_table())
    text = "\n".join(parts)
    if args.out:
        open(args.out, "a").write(text)
    print(text)


if __name__ == "__main__":
    main()
