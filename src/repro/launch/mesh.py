"""Production mesh builders (assignment-fixed shapes).

Functions, not module constants: importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / small-scale runs)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def local_mesh():
    """Whatever this host has (1 device on the dev container)."""
    n = len(jax.devices())
    return make_mesh((1, n), ("data", "model"))
