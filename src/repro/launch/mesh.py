"""Production mesh builders (assignment-fixed shapes).

Functions, not module constants: importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax


def _mk(shape, axes):
    """jax.make_mesh across jax versions: newer releases take (and
    default) ``axis_types``; 0.4.x does not have the argument."""
    try:
        return jax.make_mesh(tuple(shape), tuple(axes))
    except TypeError:  # pragma: no cover — future jax requiring types
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / small-scale runs)."""
    return _mk(shape, axes)


def local_mesh():
    """Whatever this host has (1 device on the dev container).

    Note the PCN engine does NOT need this on a single device: pass
    ``mesh=None`` (the default) to ``PCNEngine`` for the explicit
    no-mesh fast path — same numerics, no sharding machinery.
    """
    n = len(jax.devices())
    return make_mesh((1, n), ("data", "model"))


def data_mesh(n_data: int | None = None):
    """1-D data-parallel ("data", "model"=1) mesh for the PCN engine's
    sharded serving path.  Raises an actionable error when more shards
    are requested than this host has devices."""
    have = len(jax.devices())
    n = have if n_data is None else n_data
    if n < 1:
        raise ValueError(f"n_data must be >= 1, got {n}")
    if n > have:
        raise ValueError(
            f"requested a {n}-way data mesh but only {have} JAX "
            f"device(s) are visible; on CPU, force fake devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"(set BEFORE the first jax import) or lower the request "
            f"(e.g. serve --mesh-data {have})")
    return make_mesh((n, 1), ("data", "model"))
