"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes, record memory / cost / collective analysis.

MUST set the device-count flag before ANY other import (jax locks device
count on first init).
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, SUBQUADRATIC, get_config  # noqa: E402
from repro.dist import sharding as shd                                # noqa: E402
from repro.launch.mesh import make_production_mesh                    # noqa: E402
from repro.lm import model_zoo as zoo                                 # noqa: E402
from repro.lm import steps                                            # noqa: E402
from repro.optim import adamw                                         # noqa: E402

HW = dict(peak_bf16=197e12, hbm_bw=819e9, ici_bw=50e9)

COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=?|"  # op name (we re-parse shapes below)
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}


def _bytes_of_shape_str(s: str) -> int:
    """'bf16[2,16,512]{...}' -> byte count (0 for tuple/token types)."""
    m = _SHAPE_RE.match(s.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the per-device program.

    Returns {op_kind: bytes} + {"total": ...}.  Operand shapes are parsed
    from the op's own output shape (collectives are shape-preserving for
    all-reduce/all-to-all/permute; all-gather output > input — we use the
    output, the wire cost upper bound).
    """
    out: dict = {}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(
            r"%?\S+\s*=\s*((?:\([^)]*\))|\S+)\s+"
            r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?", ls)
        if not m:
            continue
        shape_str, kind = m.groups()
        if shape_str.startswith("("):
            nbytes = sum(_bytes_of_shape_str(p)
                         for p in shape_str[1:-1].split(","))
        else:
            nbytes = _bytes_of_shape_str(shape_str)
        out[kind] = out.get(kind, 0) + nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def build_lowered(arch: str, shape_name: str, multi_pod: bool,
                  microbatches: int | None = None):
    cfg = get_config(arch)
    sp = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = (2 * 16) if multi_pod else 16
    key = jax.random.PRNGKey(0)

    with shd.use_mesh(mesh, sp=cfg.seq_shard_blocks,
                      profile=cfg.shard_profile):
        params_shape = jax.eval_shape(lambda k: zoo.init(k, cfg), key)
        p_sh = shd.param_shardings(params_shape, mesh, cfg.moe_shard)

        if sp.kind == "train":
            mb = (microbatches if microbatches is not None
                  else max(min(16, sp.global_batch // dp), 1))
            opt_cfg = adamw.AdamWConfig()
            opt_shape = jax.eval_shape(
                lambda p: adamw.init_state(opt_cfg, p), params_shape)
            o_sh = shd.param_shardings(opt_shape, mesh, cfg.moe_shard)
            batch = zoo.input_specs(cfg, sp.seq_len, sp.global_batch,
                                    "train")
            b_sh = shd.batch_shardings(batch, mesh)
            step_fn = steps.make_train_step(
                cfg, opt_cfg, microbatches=mb,
                accum_dtype=jnp.bfloat16 if cfg.family == "moe"
                else jnp.float32,
                param_shardings=p_sh)
            fn = jax.jit(step_fn,
                         in_shardings=(p_sh, o_sh, b_sh, None),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
            return fn.lower(params_shape, opt_shape, batch,
                            jnp.zeros((), jnp.int32)), cfg, mesh, mb

        if sp.kind == "prefill":
            batch = zoo.input_specs(cfg, sp.seq_len, sp.global_batch,
                                    "prefill")
            b_sh = shd.batch_shardings(batch, mesh)
            step_fn = steps.make_prefill_step(cfg)
            fn = jax.jit(step_fn, in_shardings=(p_sh, b_sh))
            return fn.lower(params_shape, batch), cfg, mesh, 1

        # decode: one token against a cache of sp.seq_len
        cache_shape = zoo.cache_specs(cfg, sp.global_batch, sp.seq_len)
        c_sh = shd.cache_shardings(cache_shape, mesh)
        tok = jax.ShapeDtypeStruct((sp.global_batch,), jnp.int32)
        step_fn = steps.make_decode_step(cfg)
        fn = jax.jit(step_fn,
                     in_shardings=(p_sh, shd.batch_shardings(tok, mesh),
                                   c_sh, None),
                     out_shardings=(shd.batch_shardings(tok, mesh), None,
                                    c_sh),
                     donate_argnums=(2,))
        return fn.lower(params_shape, tok, cache_shape,
                        jnp.zeros((), jnp.int32)), cfg, mesh, 1


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             want_text: bool = True) -> dict:
    t0 = time.time()
    sp = SHAPES[shape_name]
    if shape_name == "long_500k" and arch not in SUBQUADRATIC:
        return {"arch": arch, "shape": shape_name,
                "multi_pod": multi_pod, "status": "skipped",
                "reason": "full-attention arch; 500k needs sub-quadratic "
                          "mixing (DESIGN.md §4)"}
    try:
        # COST variant: no grad-accum scan (mb=1) so XLA cost analysis and
        # the HLO collective schedule cover the FULL step (lax.scan bodies
        # are counted once — verified empirically; see DESIGN.md §6).
        lowered, cfg, mesh, _ = build_lowered(arch, shape_name, multi_pod,
                                              microbatches=1)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ca = compiled.cost_analysis() or {}
        colls = collective_bytes(compiled.as_text()) if want_text else {}

        # MEM variant: the production grad-accum config — memory truth.
        # (single-pod only: the roofline/memory table is single-pod per
        # the assignment; the multi-pod pass proves compile + sharding.
        # DRYRUN_SKIP_MEM_VARIANT=1 skips it — the analytic model in
        # launch/memmodel.py covers the fits-proof, anchored by the cells
        # where both were measured.)
        if (sp.kind == "train" and not multi_pod
                and not os.environ.get("DRYRUN_SKIP_MEM_VARIANT")):
            lowered_m, _, _, mb = build_lowered(arch, shape_name,
                                                multi_pod)
            ma = lowered_m.compile().memory_analysis()
        else:
            mb = 1
            ma = compiled.memory_analysis()
        chips = len(mesh.devices.flatten())
        pc = cfg.param_counts()

        flops = float(ca.get("flops", 0.0))
        byts = float(ca.get("bytes accessed", 0.0))
        rec = {
            "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "status": "ok", "chips": chips, "microbatches": mb,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "hlo_flops_per_chip": flops,
            "hlo_bytes_per_chip": byts,
            "collective_bytes_per_chip": colls,
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            },
            "params_total": pc["total"], "params_active": pc["active"],
            "compute_s": flops / HW["peak_bf16"],
            "memory_s": byts / HW["hbm_bw"],
            "collective_s": colls.get("total", 0) / HW["ici_bw"],
        }
        terms = {k: rec[k] for k in ("compute_s", "memory_s",
                                     "collective_s")}
        rec["dominant"] = max(terms, key=terms.get)
        return rec
    except Exception as e:  # noqa: BLE001 — record, don't crash the sweep
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["multi_pod"]) for r in results
            if r.get("status") in ("ok", "skipped")}

    for mp in meshes:
        for a in archs:
            for s in shapes:
                if (a, s, mp) in done:
                    continue
                rec = run_cell(a, s, mp)
                results = [r for r in results
                           if not (r["arch"] == a and r["shape"] == s
                                   and r["multi_pod"] == mp)]
                results.append(rec)
                os.makedirs(os.path.dirname(args.out), exist_ok=True)
                json.dump(results, open(args.out, "w"), indent=1)
                status = rec["status"]
                extra = (f"dom={rec.get('dominant')} "
                         f"compile={rec.get('compile_s')}s"
                         if status == "ok" else
                         rec.get("reason", rec.get("error", ""))[:120])
                print(f"[{'2pod' if mp else '1pod'}] {a} × {s}: "
                      f"{status} {extra}", flush=True)


if __name__ == "__main__":
    main()
