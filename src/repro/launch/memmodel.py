"""Analytic per-device HBM model — the TPU "fits in 16 GiB" proof.

The XLA-CPU ``memory_analysis()`` of the dry-run overstates TPU memory in
two documented ways: (1) the CPU backend upconverts every bf16 GEMM
operand to f32 (temporary full-weight copies that do not exist on TPU);
(2) the CPU thunk scheduler runs independent chunks concurrently, keeping
all their score tensors live (TPU executes sequentially, reusing one
chunk's buffers).  This model computes the schedule-faithful footprint:

  state  = params(bf16) + grads(accum dtype) + adam m/v (state dtype)
           — all sharded exactly as dist/sharding.py shards them
  live activations (train, per microbatch, remat per layer):
           layer-boundary residuals (saved) + one layer's working set
  caches (decode): KV/state caches, sharded as cache_shardings
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from repro.configs import SHAPES
from repro.dist import sharding as shd
from repro.lm import model_zoo as zoo
from repro.lm.config import ArchConfig


def _tree_device_bytes(shapes_tree, shardings_tree, mesh) -> int:
    """Sum of per-device bytes over a (ShapeDtypeStruct, NamedSharding)
    tree pair."""
    total = 0
    flat_s = jax.tree_util.tree_leaves(shapes_tree)
    flat_h = jax.tree_util.tree_leaves(
        shardings_tree, is_leaf=lambda x: hasattr(x, "spec"))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for s, h in zip(flat_s, flat_h):
        n = 1
        spec = tuple(h.spec) + (None,) * (len(s.shape) - len(h.spec))
        for dim, entry in zip(s.shape, spec):
            if entry is None:
                n *= dim
            else:
                axes = entry if isinstance(entry, tuple) else (entry,)
                div = 1
                for a in axes:
                    div *= sizes[a]
                n *= -(-dim // div)     # ceil: GSPMD pads
        total += n * s.dtype.itemsize
    return total


def train_footprint(cfg: ArchConfig, shape_name: str, mesh,
                    microbatches: int, accum_bytes: int = 4,
                    opt_state_bytes: int = 2) -> dict:
    """Per-device bytes for one training step (production schedule)."""
    sp = SHAPES[shape_name]
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda k: zoo.init(k, cfg), key)
    p_sh = shd.param_shardings(params, mesh, cfg.moe_shard)
    param_b = _tree_device_bytes(params, p_sh, mesh)
    n_params_dev = param_b // 2       # bf16 params
    grads_b = n_params_dev * accum_bytes
    opt_b = 2 * n_params_dev * opt_state_bytes   # m and v

    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)

    rows_per_dev = max(sp.global_batch // (dp * microbatches), 1)
    seq = sp.seq_len
    d = cfg.d_model
    # residual stream saved at every layer boundary (remat policy), seq
    # sharded over model between blocks (SP)
    resid = (cfg.n_layers + cfg.enc_layers) * rows_per_dev \
        * (-(-seq // tp)) * d * 2
    # one layer's working set: attention scores chunk (f32) + mlp hidden
    if cfg.family == "ssm":
        q = min(cfg.ssd_chunk, seq)
        nc = max(seq // q, 1)
        work = rows_per_dev * nc * q * q * (-(-cfg.ssm_heads // tp)) * 4 \
            + rows_per_dev * nc * (-(-cfg.ssm_heads // tp)) \
            * cfg.ssm_headdim * cfg.ssm_state * 4
    else:
        from repro.nn.attention import CHUNK_Q_ABOVE, N_Q_CHUNKS
        qc = seq if seq <= CHUNK_Q_ABOVE else seq // N_Q_CHUNKS
        heads_dev = -(-cfg.n_heads // tp)
        work = rows_per_dev * heads_dev * qc * seq * 4
        ff = cfg.moe_d_ff or cfg.d_ff
        work += rows_per_dev * seq * max(-(-ff // tp), d) * 2
    # logits for one microbatch (vocab sharded over model)
    logits = rows_per_dev * seq * (-(-cfg.vocab // tp)) * 4

    total = param_b + grads_b + opt_b + resid + work + logits
    return {
        "params_bytes": param_b, "grads_bytes": grads_b,
        "opt_bytes": opt_b, "residuals_bytes": resid,
        "working_set_bytes": work, "logits_bytes": logits,
        "total_bytes": total, "fits_16GiB": total < 16 * 2 ** 30,
    }


def decode_footprint(cfg: ArchConfig, shape_name: str, mesh) -> dict:
    """Per-device bytes for one decode step (params + caches + small
    working set)."""
    sp = SHAPES[shape_name]
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda k: zoo.init(k, cfg), key)
    p_sh = shd.param_shardings(params, mesh, cfg.moe_shard)
    param_b = _tree_device_bytes(params, p_sh, mesh)
    cache = zoo.cache_specs(cfg, sp.global_batch, sp.seq_len)
    c_sh = shd.cache_shardings(cache, mesh)
    cache_b = _tree_device_bytes(cache, c_sh, mesh)
    work = sp.global_batch * cfg.d_model * 4 * 8
    total = param_b + cache_b + work
    return {"params_bytes": param_b, "cache_bytes": cache_b,
            "working_set_bytes": work, "total_bytes": total,
            "fits_16GiB": total < 16 * 2 ** 30}
