"""Roofline report: read results/dryrun.json -> per-cell three-term
roofline table (markdown), dominant bottleneck, MODEL_FLOPS ratio, and a
one-line lever per cell.

    PYTHONPATH=src python -m repro.launch.roofline [--json results/dryrun.json]
"""
from __future__ import annotations

import argparse
import json

from repro import HW
from repro.configs import SHAPES, get_config

CHIP_PEAK = HW["peak_bf16_flops"]
HBM_BW = HW["hbm_bw"]
ICI_BW = HW["ici_bw"]


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs: train = 6·N_active·tokens (fwd+bwd);
    prefill = 2·N_active·tokens; decode = 2·N_active·batch (one token).
    (Attention score FLOPs intentionally excluded — the ratio then shows
    attention+remat+padding overhead explicitly.)"""
    cfg = get_config(arch)
    sp = SHAPES[shape_name]
    n_act = cfg.param_counts()["active"]
    if sp.kind == "train":
        return 6.0 * n_act * sp.global_batch * sp.seq_len
    if sp.kind == "prefill":
        return 2.0 * n_act * sp.global_batch * sp.seq_len
    return 2.0 * n_act * sp.global_batch


def lever(rec: dict) -> str:
    d = rec["dominant"]
    kind = SHAPES[rec["shape"]].kind
    if d == "collective_s":
        cb = rec["collective_bytes_per_chip"]
        top = max((k for k in cb if k != "total"), key=lambda k: cb[k])
        return (f"cut {top} bytes (weight-gather caching / larger "
                f"per-device batch / TP->DP rebalance)")
    if d == "memory_s":
        if kind == "decode":
            return "decode is HBM-bound by design: KV/state streaming; " \
                   "quantize cache or batch more requests"
        return "raise arithmetic intensity: fuse/flash attention, " \
               "bigger microbatch, bf16 scores"
    return "compute-bound: good; next is MXU util (tile shapes, fusion)"


def build_rows(records: list, multi_pod: bool = False) -> list:
    rows = []
    for r in records:
        if r.get("multi_pod") != multi_pod:
            continue
        if r["status"] == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "skipped": r["reason"]})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "error": r.get("error", "?")[:80]})
            continue
        chips = r["chips"]
        mf = model_flops(r["arch"], r["shape"])
        hlo_total = r["hlo_flops_per_chip"] * chips
        terms = dict(compute_s=r["compute_s"], memory_s=r["memory_s"],
                     collective_s=r["collective_s"])
        dom = max(terms, key=terms.get)
        bound = max(terms.values())
        # roofline fraction: useful-FLOPs time at peak / bound term
        ideal_s = mf / (chips * CHIP_PEAK)
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": terms["compute_s"],
            "memory_s": terms["memory_s"],
            "collective_s": terms["collective_s"],
            "dominant": dom,
            "model_flops": mf,
            "hlo_flops": hlo_total,
            "useful_ratio": mf / max(hlo_total, 1),
            "roofline_frac": ideal_s / max(bound, 1e-30),
            "lever": lever(r),
        })
    return rows


def to_markdown(rows: list) -> str:
    out = ["| arch | shape | compute_s | memory_s | collective_s | "
           "dominant | MODEL/HLO | roofline-frac | lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | — | {r['skipped'][:60]} |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"ERROR | — | — | {r['error']} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} | "
            f"{r['lever'][:70]} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/dryrun.json")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)
    records = json.load(open(args.json))
    rows = build_rows(records, args.multi_pod)
    print(to_markdown(rows))


if __name__ == "__main__":
    main()
