"""Serving driver: batched inference loops runnable on the dev container.

Two families share one CLI, dispatched on ``--arch``:

  * PCN serving (the L-PCN path) — batched point-cloud inference through
    ``repro.engine``: one compiled executable (spec/mode/backend static)
    fed padded (B, N, 3) batches, continuous throughput loop.

        PYTHONPATH=src python -m repro.launch.serve --arch pointnet2_c \
            --batch 4 --points 1024 --mode lpcn --backend reference

    ``--mesh-data N`` serves through the mesh-sharded path instead: an
    (N, 1) ("data", "model") mesh splits each batch N ways (batch must
    divide; on CPU force fake devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).  Without it
    the engine takes the single-device fast path and ``repro.dist`` is
    never imported.

  * PCN trace serving — the continuous-batching layer (``repro.serve``):
    replay a synthetic ragged arrival trace (Poisson arrivals at
    ``--rate`` req/s, log-normal cloud sizes with median ``--points``)
    through the admission queue / size buckets / timeout dispatcher and
    report per-request p50/p95/p99 latency, throughput, padding waste
    and the fault counters as JSON.  Composes with ``--mesh-data``
    (bucket batches must divide the mesh) and ``--kernel-kw``
    unchanged.  The hardened-serving knobs ride along: ``--faults``
    injects a deterministic chaos plan into primary dispatches,
    ``--max-queue`` bounds each bucket lane (shed-on-full),
    ``--deadline-ms`` stamps per-request TTLs, ``--fallback`` picks the
    degraded backend ('' disables it).  Dispatch is async by default
    (up to ``--max-in-flight`` batches in flight, admission/padding
    overlapping device compute); ``--sync`` restores the blocking
    dispatcher as the A/B baseline.

        PYTHONPATH=src python -m repro.launch.serve --arch pointnet2_c \
            --trace 64 --rate 200 --buckets 512,1024 --batch 4 \
            --timeout-ms 10 --faults "fail@1,nan@3" \
            --serve-json results/serve_trace.json

  * LM serving — batched prefill + decode loop with continuous-batching
    slots (unchanged behavior).

        PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b \
            --reduced --batch 4 --prompt-len 16 --gen 8
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _pcn_engine(args):
    """Shared PCN setup: spec (optionally reduced), mesh, engine, params."""
    import jax

    from repro import engine
    from repro.models import MODEL_ZOO

    _, spec = MODEL_ZOO[args.arch]
    if args.reduced:
        from dataclasses import replace
        spec = replace(spec, blocks=tuple(
            replace(b, n_centers=min(b.n_centers, max(args.points // 4, 16)),
                    k=min(b.k, 16)) for b in spec.blocks))
    mesh = None
    if args.mesh_data:
        # data_mesh raises an actionable error (how to force CPU devices /
        # lower the request) when the host has fewer devices than asked
        from repro.launch.mesh import data_mesh
        mesh = data_mesh(args.mesh_data)
        if args.batch % args.mesh_data:
            raise SystemExit(
                f"--batch {args.batch} does not divide over a "
                f"{args.mesh_data}-way data mesh; pick a batch that is a "
                f"multiple of --mesh-data")
    kernel_kw = json.loads(args.kernel_kw) if args.kernel_kw else None
    eng = engine.PCNEngine(spec, mode=args.mode, fc_backend=args.backend,
                           kernel_kw=kernel_kw, mesh=mesh)
    return spec, mesh, eng, eng.init(jax.random.PRNGKey(0))


def serve_pcn(args):
    """Batched PCN inference through the engine (one jit, many batches)."""
    import jax
    import jax.numpy as jnp

    from repro import engine
    from repro.data.synthetic import make_cloud

    spec, mesh, eng, params = _pcn_engine(args)

    rng = np.random.default_rng(0)
    f = spec.in_feats

    def make_batch(step: int):
        xyz = np.stack([make_cloud(rng, args.points)
                        for _ in range(args.batch)])
        feats = None
        if f > 3:
            feats = np.concatenate(
                [xyz, rng.uniform(0, 1, (args.batch, args.points, f - 3))
                 .astype(np.float32)], -1)
        return engine.Batch.make(
            jnp.asarray(xyz), None if feats is None else jnp.asarray(feats),
            key=jax.random.PRNGKey(step))

    # compile once (spec/mode/backend are static; shape fixed by the batch)
    t0 = time.perf_counter()
    logits = eng.apply(params, make_batch(0))
    logits.block_until_ready()
    compile_s = time.perf_counter() - t0

    # pre-build batches so the timed loop measures engine throughput, not
    # host-side cloud synthesis.  Each step blocks on its own result:
    # only syncing once at the end would hide per-step latency entirely
    # (the first timed step absorbs the whole queued dispatch backlog),
    # making latency percentiles meaningless — the throughput cost of
    # per-step syncing is the dispatch gap, which is what a serving
    # latency number must include anyway.
    batches = [make_batch(step) for step in range(1, min(args.steps, 4) + 1)]
    from repro.serve import percentile_summary
    step_ms = []
    for step in range(args.steps):
        t1 = time.perf_counter()
        logits = eng.apply(params, batches[step % len(batches)])
        logits.block_until_ready()
        step_ms.append(1e3 * (time.perf_counter() - t1))
    dt = max(sum(step_ms) / 1e3, 1e-9)
    n = args.steps * args.batch
    lat = percentile_summary(step_ms)
    per_dev = "" if mesh is None else (
        f", {n / dt / args.mesh_data:.1f} clouds/s/device over "
        f"{args.mesh_data} devices")
    print(f"{eng}: compiled in {compile_s:.2f}s; served {n} clouds in "
          f"{dt:.2f}s ({n / dt:.1f} clouds/s, batch={args.batch}, "
          f"N={args.points}{per_dev})")
    print(f"per-step latency ms: p50={lat['p50']:.2f} p95={lat['p95']:.2f} "
          f"p99={lat['p99']:.2f} mean={lat['mean']:.2f} max={lat['max']:.2f}")
    print("logits", tuple(logits.shape))
    return logits


def serve_trace(args):
    """Replay a synthetic ragged arrival trace through the
    continuous-batching layer (``repro.serve``) and write the latency /
    throughput / padding-waste / fault report as JSON.

    ``--faults "fail@1,nan@3,slow@5:80"`` injects a deterministic chaos
    schedule into the primary engine callables (the fallback retry path
    stays clean); ``--max-queue`` bounds each bucket lane
    (shed-on-full), ``--deadline-ms`` stamps every request with a TTL
    past which poll sheds it.  Shed requests count in the report's
    ``faults`` section rather than aborting the replay.
    """
    from repro import serve
    from repro.data.synthetic import make_cloud

    spec, mesh, eng, params = _pcn_engine(args)
    if args.buckets:
        sizes = sorted({int(s) for s in args.buckets.split(",")})
        buckets = serve.BucketSet.make(sizes, batch=args.batch)
    else:
        # no explicit sizes: plan quantile buckets from the trace itself
        probe = serve.synthetic_trace(
            n_requests=max(args.trace, 64), rate_hz=args.rate,
            n_median=args.points, sigma=args.size_sigma, seed=args.seed)
        buckets = serve.BucketSet.plan(
            [e.n_points for e in probe], n_buckets=2, batch=args.batch)
    events = serve.synthetic_trace(
        n_requests=args.trace, rate_hz=args.rate, n_median=args.points,
        sigma=args.size_sigma, n_max=buckets.max_points, seed=args.seed)

    faults = serve.FaultPlan.parse(args.faults) if args.faults else None
    t0 = time.perf_counter()
    server = serve.PCNServer(
        eng, params, buckets, timeout_s=args.timeout_ms / 1e3,
        faults=faults,
        max_lane_depth=args.max_queue or None,
        deadline_s=(args.deadline_ms / 1e3) if args.deadline_ms else None,
        fallback=args.fallback or None,
        max_in_flight=args.max_in_flight, sync=args.sync)
    warmup_s = time.perf_counter() - t0

    rng = np.random.default_rng(args.seed)
    f = spec.in_feats

    def make_request(n, i):
        xyz = np.asarray(make_cloud(rng, n), np.float32)
        feats = None if f <= 3 else np.concatenate(
            [xyz, rng.uniform(0, 1, (n, f - 3)).astype(np.float32)], -1)
        return xyz, feats

    rids = serve.replay(server, events, make_request)
    server.close()                       # join + release the executor
    admitted = [r for r in rids if r is not None]
    answered = sum(server.ready(r) and not server.failed(r)
                   for r in admitted)
    failed = sum(server.failed(r) for r in admitted)
    report = server.report(arch=args.arch, mode=args.mode,
                           backend=args.backend, rate_hz=args.rate,
                           mesh_data=args.mesh_data or None,
                           warmup_s=warmup_s, answered=answered,
                           failed=failed,
                           shed=len(rids) - len(admitted))
    lat = report["latency_ms"]["e2e"]
    fl = report["faults"]
    per_dev = "" if mesh is None else f" over {args.mesh_data} devices"
    dmode = ("sync" if args.sync
             else f"async(max_in_flight={args.max_in_flight})")
    print(f"{eng}: {buckets}, timeout={args.timeout_ms:.1f}ms, {dmode}; "
          f"warmed {len(buckets)} buckets in {warmup_s:.2f}s; answered "
          f"{answered}/{len(rids)} requests{per_dev}")
    ov = report["overlap"]
    print(f"throughput {report['throughput_rps']:.1f} req/s "
          f"(offered {args.rate:.1f}), padding waste "
          f"{report['padding_waste_pct']:.1f}%, dispatches "
          f"{report['dispatches']} ({report['partial_batches']} partial), "
          f"overlap {ov['overlap_pct']:.1f}% "
          f"(depth<={ov['inflight_depth_max']}, "
          f"idle gap {ov['idle_gap_ms']:.1f}ms)")
    print(f"e2e latency ms: p50={lat['p50']:.2f} p95={lat['p95']:.2f} "
          f"p99={lat['p99']:.2f} max={lat['max']:.2f}")
    print(f"faults: degraded={fl['degraded_dispatches']} "
          f"failed={fl['failed_requests']} "
          f"shed_queue_full={fl['shed_queue_full']} "
          f"deadline_miss={fl['deadline_miss']} "
          f"breaker_opened={fl['breaker_opened']}")
    if args.serve_json:
        os.makedirs(os.path.dirname(args.serve_json) or ".", exist_ok=True)
        with open(args.serve_json, "w") as fh:
            json.dump(report, fh, indent=1)
        print(f"report written to {args.serve_json}")
    return report


def serve_lm(args):
    """Batched prefill + decode loop with continuous-batching slots."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.dist import sharding as shd
    from repro.launch.mesh import local_mesh
    from repro.lm import model_zoo as zoo
    from repro.lm import steps as steps_mod

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = local_mesh()
    rng = np.random.default_rng(0)

    with shd.use_mesh(mesh):
        key = jax.random.PRNGKey(0)
        params = zoo.init(key, cfg)
        frames = None
        if cfg.family == "audio":
            frames = 0.01 * jnp.ones(
                (args.batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        cache = zoo.make_cache(cfg, params, args.batch, args.cache_len,
                               frames=frames)
        decode = jax.jit(steps_mod.make_decode_step(cfg),
                         donate_argnums=(2,))

        # "prefill" by teacher-forcing the prompt through decode slots
        # (token-by-token; the batched prefill path is exercised in the
        # dry-run and tests)
        prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                               dtype=np.int32)
        tok = jnp.asarray(prompts[:, 0])
        t0 = time.time()
        for pos in range(args.prompt_len - 1):
            _, _, cache = decode(params, tok, cache, jnp.int32(pos))
            tok = jnp.asarray(prompts[:, pos + 1])
        out = []
        for g in range(args.gen):
            tok, logits, cache = decode(params, tok, cache,
                                        jnp.int32(args.prompt_len + g))
            out.append(np.asarray(tok))
        dt = time.time() - t0
        gen = np.stack(out, 1)
        print(f"generated {gen.shape} tokens in {dt:.2f}s "
              f"({args.batch*args.gen/dt:.1f} tok/s)")
        print(gen)
        return gen


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    # LM options
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=64)
    # PCN options
    ap.add_argument("--points", type=int, default=1024)
    ap.add_argument("--mode", default="lpcn",
                    choices=["lpcn", "traditional"])
    ap.add_argument("--backend", default="reference")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--mesh-data", type=int, default=0,
                    help="serve through an (N, 1) data mesh (0 = "
                         "single-device fast path, no repro.dist import)")
    ap.add_argument("--kernel-kw", default=None,
                    help='JSON kernel knob, e.g. \'{"ts": 32}\' '
                         "(passed to PCNEngine(kernel_kw=...))")
    # PCN trace-serving options (--trace N turns the mode on)
    ap.add_argument("--trace", type=int, default=0,
                    help="replay a synthetic ragged trace of N requests "
                         "through the continuous-batching layer")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--size-sigma", type=float, default=0.35,
                    help="log-normal size spread (median = --points)")
    ap.add_argument("--buckets", default=None,
                    help="comma-separated bucket pad sizes, e.g. "
                         "'512,1024' (default: quantile-planned from "
                         "the trace); per-bucket batch is --batch")
    ap.add_argument("--timeout-ms", type=float, default=10.0,
                    help="partial-batch dispatch timeout")
    ap.add_argument("--faults", default=None,
                    help="deterministic fault plan for the primary "
                         "engine path, e.g. 'fail@1,nan@3,slow@5:80' "
                         "(kind@dispatch-step[:arg_ms])")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="per-bucket lane depth bound; submits into a "
                         "full lane are shed (0 = unbounded)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline; expired queued requests "
                         "are shed at poll time (0 = none)")
    ap.add_argument("--fallback", default="reference",
                    help="FC backend for the one-shot degraded retry of "
                         "a failed batch ('' disables)")
    ap.add_argument("--max-in-flight", type=int, default=4,
                    help="how many fired batches may be in flight at "
                         "once (async dispatch; admission, host padding "
                         "and device compute overlap across buckets)")
    ap.add_argument("--sync", action="store_true",
                    help="fully-blocking dispatch (the pre-async "
                         "behavior) — the A/B baseline for "
                         "--max-in-flight")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--serve-json", default="results/serve_trace.json",
                    help="where the trace report JSON goes ('' = skip)")
    args = ap.parse_args(argv)

    from repro.models import MODEL_ZOO
    if args.arch in MODEL_ZOO:
        return serve_trace(args) if args.trace else serve_pcn(args)
    if args.mesh_data:
        raise SystemExit(
            "--mesh-data is the PCN engine's sharded path; the LM path "
            "builds its mesh from the host via launch.mesh.local_mesh() "
            "(force devices with XLA_FLAGS=--xla_force_host_platform_"
            "device_count=N instead)")
    try:
        return serve_lm(args)
    except ModuleNotFoundError as e:
        raise SystemExit(
            f"--arch {args.arch!r} is not a PCN model "
            f"({', '.join(sorted(MODEL_ZOO))}) and the LM serving path "
            f"needs a missing module ({e.name})") from e


if __name__ == "__main__":
    main()
