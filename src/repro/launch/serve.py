"""Serving driver: batched prefill + decode loop with continuous-batching
slots (small-scale runnable on the dev container).

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --batch 4 --prompt-len 16 --gen 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.dist import sharding as shd
from repro.launch.mesh import local_mesh
from repro.lm import model_zoo as zoo
from repro.lm import steps as steps_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = local_mesh()
    rng = np.random.default_rng(0)

    with shd.use_mesh(mesh):
        key = jax.random.PRNGKey(0)
        params = zoo.init(key, cfg)
        frames = None
        if cfg.family == "audio":
            frames = 0.01 * jnp.ones(
                (args.batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        cache = zoo.make_cache(cfg, params, args.batch, args.cache_len,
                               frames=frames)
        decode = jax.jit(steps_mod.make_decode_step(cfg),
                         donate_argnums=(2,))

        # "prefill" by teacher-forcing the prompt through decode slots
        # (token-by-token; the batched prefill path is exercised in the
        # dry-run and tests)
        prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                               dtype=np.int32)
        tok = jnp.asarray(prompts[:, 0])
        t0 = time.time()
        for pos in range(args.prompt_len - 1):
            _, _, cache = decode(params, tok, cache, jnp.int32(pos))
            tok = jnp.asarray(prompts[:, pos + 1])
        out = []
        for g in range(args.gen):
            tok, logits, cache = decode(params, tok, cache,
                                        jnp.int32(args.prompt_len + g))
            out.append(np.asarray(tok))
        dt = time.time() - t0
        gen = np.stack(out, 1)
        print(f"generated {gen.shape} tokens in {dt:.2f}s "
              f"({args.batch*args.gen/dt:.1f} tok/s)")
        print(gen)
        return gen


if __name__ == "__main__":
    main()
