"""Training driver: mesh setup, data, checkpoint/restart, train loop.

Runs real steps on whatever devices exist (the dev container: 1 CPU
device with a reduced config; a pod: the production mesh).  Demonstrates
the full fault-tolerance loop: restore-if-present, periodic atomic saves,
preemption-signal save, deterministic data resume.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 20 --batch 8 --seq 128 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.configs import get_config
from repro.data.loader import TokenStream
from repro.dist import compress as compress_mod
from repro.dist import sharding as shd
from repro.launch.mesh import local_mesh, make_production_mesh
from repro.lm import model_zoo as zoo
from repro.lm import steps as steps_mod
from repro.optim import adamw


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compress", default=None, choices=["int8", "topk"],
                    help="compress cross-replica gradient traffic with "
                         "this dist.compress codec (error feedback rides "
                         "in opt_state['ef'])")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = (make_production_mesh() if args.production_mesh
            else local_mesh())
    opt_cfg = adamw.AdamWConfig(state_dtype="float32")

    with shd.use_mesh(mesh):
        key = jax.random.PRNGKey(args.seed)
        params = zoo.init(key, cfg)
        opt_state = adamw.init_state(opt_cfg, params)
        if args.compress:
            # seed the error feedback BEFORE jit so the state structure
            # is stable (see dist.compress.make_compressor)
            opt_state["ef"] = compress_mod.init_error_feedback(params)
        p_sh = shd.param_shardings(params, mesh, cfg.moe_shard)
        o_sh = shd.param_shardings(opt_state, mesh, cfg.moe_shard)
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt_state = jax.tree.map(jax.device_put, opt_state, o_sh)

        stream = TokenStream(vocab=cfg.vocab, batch=args.batch,
                             seq_len=args.seq, seed=args.seed)
        start_step = 0
        mgr = CheckpointManager(args.ckpt) if args.ckpt else None
        if mgr is not None:
            restored = mgr.restore(params, opt_state, shardings={
                "params": p_sh, "opt": o_sh})
            if restored is not None:
                start_step, params, opt_state, dstate = restored
                stream = TokenStream.from_state(
                    dstate, vocab=cfg.vocab, batch=args.batch,
                    seq_len=args.seq)
                print(f"[restore] resumed at step {start_step}")

        train_step = steps_mod.make_train_step(
            cfg, opt_cfg, microbatches=args.microbatches,
            param_shardings=p_sh,
            compressor=(compress_mod.make_compressor(args.compress)
                        if args.compress else None))
        jstep = jax.jit(train_step, donate_argnums=(0, 1))

        stop = {"now": False}

        def _sig(_s, _f):  # preemption hook: save and exit cleanly
            stop["now"] = True
        signal.signal(signal.SIGTERM, _sig)

        losses = []
        for step in range(start_step, args.steps):
            batch = {"tokens": jnp.asarray(stream.next())}
            if cfg.family == "vlm":
                batch["patches"] = jnp.zeros(
                    (args.batch, cfg.prefix_tokens, cfg.d_model),
                    jnp.bfloat16)
            if cfg.family == "audio":
                batch["frames"] = 0.01 * jnp.ones(
                    (args.batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
            t0 = time.time()
            params, opt_state, metrics = jstep(
                params, opt_state, batch, jnp.int32(step))
            loss = float(metrics["loss"])
            losses.append(loss)
            print(f"step {step}: loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"dt={time.time()-t0:.2f}s", flush=True)
            if mgr is not None and (
                    (step + 1) % args.ckpt_every == 0 or stop["now"]
                    or step + 1 == args.steps):
                mgr.save(step + 1, params, opt_state, stream.state())
            if stop["now"]:
                print("[preempt] checkpoint saved; exiting")
                break
        return losses


if __name__ == "__main__":
    main()
