"""Measurement-driven tile-plan autotuner for the batched FC kernels.

Per ``(kernel, B, shape)`` cell the tuner enumerates candidate
``(TS/TH, lanes, vmem_budget_mb, dimension_semantics)`` plans, filters
them through the closed-form VMEM feasibility predicate
(``gather_mlp_footprint_elems`` / ``hub_reuse_footprint_elems``), times
the survivors with warmed, blocked executions (min-of-reps), lints the
winner with the ``repro.analysis`` kernel rules (K001–K005 — a plan
that would fail ``--strict`` is never promoted), and persists it to the
shape-keyed ``repro.kernels.plans`` store
(``results/tile_plans.json``).  The tile planners consult that store on
the default ``kernel_kw`` resolution path, so every later
``engine.apply`` / ``PCNEngine`` / ``FCBackend.*_batched`` call at a
tuned shape silently picks the measured winner up.

Why ``lanes`` is in the search space: the kernels zero-pad D/H/F to a
lane multiple.  128 is the only Mosaic-aligned choice on real TPU
hardware (and wins the measurement there), but in interpret mode the
padding FLOPs are real host work — e.g. d=35 → 128 inflates the first
matmul ~3.7× — which is exactly what kept the batched grid behind the
vmap dispatch at smoke shapes (ROADMAP item 1).  Measuring the knob
per host resolves both worlds without hardcoding either; K002 accepts
sub-128 blocks that span the full padded array width, which these
kernels always do.

Model cells are discovered by *tracing* ``engine.apply`` under
``plans.capture()`` — the tuner sees exactly the planner calls the
serving path makes, so the store keys match on lookup.

    PYTHONPATH=src python -m repro.launch.autotune \
        --models pointnet2_c --reduced --points 96 --batches 2,4 \
        --budget 12 --out results/tile_plans.json
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import numpy as np

from repro.kernels import plans
from repro.kernels.tiling import (DEFAULT_VMEM_BUDGET_MB, F32_BYTES, LANE,
                                  SUBLANE, gather_mlp_footprint_elems,
                                  hub_reuse_footprint_elems, round_up)

#: candidate knob values (every (tile, lanes, semantics) combination is
#: feasibility-filtered; vmem records the tightest admitting budget).
#: lanes=1 is "no padding at all" — the vmap dispatch's layout, which
#: interpret mode rewards and real TPU hardware rejects in measurement.
LANES_CANDIDATES = (1, 8, 32, LANE)
VMEM_CANDIDATES = (4.0, DEFAULT_VMEM_BUDGET_MB)
SEMANTICS_CANDIDATES = (("parallel", "arbitrary"),
                        ("arbitrary", "arbitrary"))

#: finalist re-timing: the top FINALISTS lint-clean screening survivors
#: are re-timed interleaved with the vmap baseline for FINAL_PASSES
#: alternating passes (see autotune_cell)
FINALISTS = 3
FINAL_PASSES = 4


def _tile_candidates(kernel: str, dims: dict) -> list[int]:
    """Preference-ordered tile sizes: gather_mlp favors big subset tiles
    (amortize the grid), hub_reuse small island tiles (the one-hot's TH²
    term); both include the full-axis tile."""
    if kernel == "gather_mlp":
        axis, base = dims["s"], SUBLANE
        tiles = []
        t = base
        while t <= axis:
            tiles.append(t)
            t *= 2
        if axis not in tiles:
            tiles.append(axis)
        return sorted(set(tiles), reverse=True)
    axis = dims["hn"]
    tiles, t = [], 1
    while t <= axis:
        tiles.append(t)
        t *= 2
    if axis not in tiles:
        tiles.append(axis)
    return sorted(set(tiles))


def _footprint_bytes(kernel: str, dims: dict, tile: int, lanes: int) -> int:
    dp = round_up(dims["d"], lanes)
    hp = round_up(dims["h"], lanes)
    fp = round_up(dims["f"], lanes)
    if kernel == "gather_mlp":
        elems = gather_mlp_footprint_elems(tile, dims["k"], dp, dims["dc"],
                                           hp, fp)
    else:
        elems = hub_reuse_footprint_elems(tile, dims["c"], dims["m"],
                                          dims["k"], dp, hp, fp)
    return F32_BYTES * elems


def _heuristic_knobs(kernel: str, dims: dict) -> dict:
    """The knobs the pure heuristic would pick for this cell (always
    candidate #0, so the winner can never lose to the default plan)."""
    if kernel == "gather_mlp":
        shape = (dims["s"], dims["k"], dims["d"], dims["dc"], dims["h"],
                 dims["f"])
    else:
        shape = (dims["hn"], dims["c"], dims["m"], dims["k"], dims["d"],
                 dims["h"], dims["f"])
    with plans.bypass():
        plan = _tile_plan(kernel)(*shape)
    return {"tile": plan[plans.TILE_FIELD[kernel]], "lanes": plan["lanes"],
            "vmem_budget_mb": plan["vmem_budget_mb"],
            "dimension_semantics": tuple(plan["dimension_semantics"])}


def _tile_plan(kernel: str):
    if kernel == "gather_mlp":
        from repro.kernels.gather_mlp.ops import gather_mlp_tile_plan
        return gather_mlp_tile_plan
    from repro.kernels.hub_reuse.ops import hub_reuse_tile_plan
    return hub_reuse_tile_plan


def candidate_plans(kernel: str, dims: dict, budget: int) -> list[dict]:
    """Feasibility-filtered, deduplicated, deterministic candidate list
    (at most ``budget`` entries; the heuristic's knobs always lead).

    Each candidate carries the *tightest* ``VMEM_CANDIDATES`` budget its
    closed-form footprint fits under — the budget the K001 lint and the
    stale-plan check will hold the promoted entry to."""
    out, seen = [], set()

    def admit(tile, lanes, sem, mb=None):
        key = (tile, lanes, sem)
        if key in seen:
            return
        fb = _footprint_bytes(kernel, dims, tile, lanes)
        if mb is None:
            mb = next((m for m in sorted(VMEM_CANDIDATES)
                       if fb <= int(m * 2 ** 20)), None)
            if mb is None:            # busts every budget: infeasible
                return
        elif fb > int(mb * 2 ** 20):
            return
        seen.add(key)
        out.append({"tile": int(tile), "lanes": int(lanes),
                    "vmem_budget_mb": float(mb),
                    "dimension_semantics": tuple(sem),
                    "footprint_bytes": fb})

    h = _heuristic_knobs(kernel, dims)
    admit(h["tile"], h["lanes"], h["dimension_semantics"],
          mb=h["vmem_budget_mb"])
    for sem in SEMANTICS_CANDIDATES:
        for tile in _tile_candidates(kernel, dims):
            for lanes in LANES_CANDIDATES:
                admit(tile, lanes, sem)
    return out[:max(int(budget), 1)]


# ---- synthetic cell operands ------------------------------------------------

def synth_cell_args(kernel: str, dims: dict, seed: int = 0):
    """Representative operands for one batched-kernel cell (masked
    variant — the serving path always passes ragged masks)."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    b, d, hdim, fout = dims["b"], dims["d"], dims["h"], dims["f"]
    w1 = jnp.asarray(rng.normal(size=(d, hdim)) * 0.1, jnp.float32)
    b1 = jnp.zeros((hdim,), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(hdim, fout)) * 0.1, jnp.float32)
    b2 = jnp.zeros((fout,), jnp.float32)
    if kernel == "gather_mlp":
        s, k, dc = dims["s"], dims["k"], dims["dc"]
        raw = jnp.asarray(rng.normal(size=(b, s, k, d)), jnp.float32)
        ctr = jnp.asarray(rng.normal(size=(b, s, dc)), jnp.float32)
        mask = jnp.asarray(rng.integers(0, 2, (b, s, k)), jnp.int32)
        return {"data": (raw, ctr), "weights": (w1, b1, w2, b2),
                "mask": mask}
    hn, c, m, k = dims["hn"], dims["c"], dims["m"], dims["k"]
    pool = jnp.asarray(rng.normal(size=(b, hn, c, d)), jnp.float32)
    slot = jnp.asarray(rng.integers(-1, c, (b, hn, m, k)), jnp.int32)
    comp = jnp.asarray(rng.normal(size=(b, hn, m, fout)) * 0.01,
                       jnp.float32)
    live = jnp.asarray(rng.integers(0, 2, (b, hn, m, k)), jnp.int32)
    return {"data": (pool, slot, comp), "weights": (w1, b1, w2, b2),
            "mask": live}


def _batched_call(kernel: str, args, knobs: dict | None):
    """A zero-arg callable running the batched op at explicit ``knobs``
    (None = the default resolution path: store hit or heuristic)."""
    kw = {}
    if knobs is not None:
        kw = {plans.TILE_FIELD[kernel]: knobs["tile"],
              "lanes": knobs["lanes"],
              "vmem_budget_mb": knobs["vmem_budget_mb"],
              "dimension_semantics": tuple(knobs["dimension_semantics"])}
    w1, b1, w2, b2 = args["weights"]
    if kernel == "gather_mlp":
        from repro.kernels.gather_mlp.ops import gather_mlp_batched
        raw, ctr = args["data"]
        return lambda: gather_mlp_batched(raw, ctr, w1, b1, w2, b2,
                                          mask=args["mask"], **kw)
    from repro.kernels.hub_reuse.ops import hub_reuse_batched
    pool, slot, comp = args["data"]
    return lambda: hub_reuse_batched(pool, slot, comp, w1, b1, w2, b2,
                                     live=args["mask"], **kw)


def _vmap_call(kernel: str, args):
    """The old dispatch: per-cloud kernel under jax.vmap (the baseline
    the batched plan must beat)."""
    import jax
    w1, b1, w2, b2 = args["weights"]
    if kernel == "gather_mlp":
        from repro.kernels.gather_mlp.ops import gather_mlp
        f = jax.jit(jax.vmap(
            lambda r, c, m: gather_mlp(r, c, w1, b1, w2, b2, mask=m)))
        raw, ctr = args["data"]
        return lambda: f(raw, ctr, args["mask"])
    from repro.kernels.hub_reuse.ops import hub_reuse
    f = jax.jit(jax.vmap(
        lambda p, sl, cp, lv: hub_reuse(p, sl, cp, w1, b1, w2, b2,
                                        live=lv)))
    pool, slot, comp = args["data"]
    return lambda: f(pool, slot, comp, args["mask"])


def measure(call, reps: int = 5) -> float:
    """Warmed (compile excluded), blocked, min-of-reps µs — min is the
    noise-robust statistic for a deterministic workload on a shared
    host."""
    import jax
    jax.block_until_ready(call())
    best = float("inf")
    for _ in range(max(int(reps), 1)):
        t0 = time.perf_counter()
        out = call()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def lint_knobs(kernel: str, dims: dict, knobs: dict, args=None) -> list:
    """Trace the batched op at ``knobs`` and run the K001–K005 kernel
    rules at the candidate's own VMEM budget.  Returns the findings (a
    non-empty list disqualifies the candidate from promotion)."""
    import jax
    from repro.analysis import check_kernel_site, pallas_call_sites
    if args is None:
        args = synth_cell_args(kernel, dims)
    call = _batched_call(kernel, args, knobs)
    closed = jax.make_jaxpr(call)()
    findings = []
    for site in pallas_call_sites(closed, where=f"autotune:{kernel}"):
        findings.extend(check_kernel_site(
            site, vmem_budget_mb=knobs["vmem_budget_mb"]))
    return findings


def autotune_cell(kernel: str, dims: dict, *, budget: int = 12,
                  reps: int = 5, seed: int = 0,
                  store: plans.PlanStore | None = None, timer=None,
                  log=None) -> dict:
    """Tune one cell and record the winner in ``store``.

    ``timer(call, knobs_or_None) -> µs`` is injectable (tests use a
    deterministic cost model); the default runs :func:`measure`.
    Candidates that fail to execute are dropped; a winner that fails
    the K001–K005 lint is skipped for the next-fastest clean one.  The
    returned entry carries the measurement context (heuristic and vmap
    baselines, search size) alongside the plan fields.

    The vmap baseline is a *candidate*, not just context: if it beats
    every grid finalist, the cell records a ``{"variant": "vmap"}``
    entry and the planners route it through the per-cloud dispatch (see
    ``repro.kernels.plans``) — a cell where the batched grid loses is
    pinned to the measured winner instead of silently running the
    loser.  The per-cloud kernel is the long-standing eager/vmap path,
    already covered by the K-lint via the analysis matrix, so variant
    entries skip the candidate lint gate.

    Timing runs in two stages: a screening pass ranks every candidate
    from one window each, then the top lint-clean finalists are
    re-timed interleaved with the vmap baseline over several
    alternating passes (min-merged).  Near-tied finalists — common,
    since the best few plans usually sit within a few percent of each
    other and of vmap — are thereby resolved on converged floors from
    a shared measurement window, not on whichever screening window
    happened to be quiet; the recorded ``measured_us`` / ``vmap_us``
    context comes from the finalist passes."""
    store = store if store is not None else plans.active_store()
    dims = {k: int(v) for k, v in dims.items()}
    args = synth_cell_args(kernel, dims, seed=seed)
    if timer is None:
        timer = lambda call, knobs: measure(call, reps=reps)

    cands = candidate_plans(kernel, dims, budget)
    timed = []
    for knobs in cands:
        try:
            us = float(timer(_batched_call(kernel, args, knobs), knobs))
        except Exception as e:
            if log:
                log(f"  candidate {knobs['tile']}/{knobs['lanes']} failed: "
                    f"{type(e).__name__}: {e}")
            continue
        timed.append((us, knobs))
    if not timed:
        raise RuntimeError(
            f"autotune: no candidate executed for "
            f"{plans.plan_key(kernel, dims)} (searched {len(cands)})")
    heuristic_us = timed[0][0]              # candidate #0 is the heuristic

    finalists = []
    for us, knobs in sorted(timed, key=lambda p: p[0]):
        findings = lint_knobs(kernel, dims, knobs, args=args)
        if findings:
            if log:
                log(f"  candidate {knobs['tile']}/{knobs['lanes']} rejected "
                    f"by lint: {[f.rule for f in findings]}")
            continue
        finalists.append([us, knobs])
        if len(finalists) == FINALISTS:
            break
    if not finalists:
        raise RuntimeError(
            f"autotune: every measured candidate failed the kernel lint "
            f"for {plans.plan_key(kernel, dims)}")

    # finalist passes: re-time the shortlist interleaved with the vmap
    # baseline, min-merging into the screening times
    calls = [_batched_call(kernel, args, f[1]) for f in finalists]
    vmap_call = _vmap_call(kernel, args)
    vmap_us = None
    for _ in range(FINAL_PASSES):
        for f, call in zip(finalists, calls):
            try:
                f[0] = min(f[0], float(timer(call, f[1])))
            except Exception:
                pass
        try:
            t = float(timer(vmap_call, None))
            vmap_us = t if vmap_us is None else min(vmap_us, t)
        except Exception:
            pass
    us, knobs = min(finalists, key=lambda f: f[0])
    if vmap_us is not None and vmap_us < us:
        # the per-cloud dispatch beat every grid candidate (typical for
        # hub cells with only a handful of islands): pin the measured
        # winner as a variant entry — the planners then route this cell
        # through jax.vmap of the per-cloud kernel instead of a grid
        # the measurement rejected
        entry = {
            "variant": "vmap",
            "provenance": "autotuned",
            "measured_us": vmap_us,
            "heuristic_us": heuristic_us,
            "vmap_us": vmap_us,
            "grid_us": us,
            "speedup_vs_heuristic": heuristic_us / max(vmap_us, 1e-9),
            "speedup_vs_grid": us / max(vmap_us, 1e-9),
            "searched": len(timed),
            "reps": reps,
            "seed": seed,
        }
        if kernel == "gather_mlp":
            entry["ts"] = 8       # the per-cloud kernel's subset tile
        store.record(kernel, dims, entry)
        if log:
            log(f"{plans.plan_key(kernel, dims)}: vmap variant promoted "
                f"-> {vmap_us:.0f}us (best grid {us:.0f}us, heuristic "
                f"{heuristic_us:.0f}us)")
        return entry
    entry = {
        plans.TILE_FIELD[kernel]: knobs["tile"],
        "lanes": knobs["lanes"],
        "vmem_budget_mb": knobs["vmem_budget_mb"],
        "dimension_semantics": list(knobs["dimension_semantics"]),
        "provenance": "autotuned",
        "footprint_bytes": knobs["footprint_bytes"],
        "measured_us": us,
        "heuristic_us": heuristic_us,
        "vmap_us": vmap_us,
        "speedup_vs_heuristic": heuristic_us / max(us, 1e-9),
        "speedup_vs_vmap": (None if vmap_us is None
                            else vmap_us / max(us, 1e-9)),
        "searched": len(timed),
        "reps": reps,
        "seed": seed,
    }
    store.record(kernel, dims, entry)
    if log:
        sv = entry["speedup_vs_vmap"]
        log(f"{plans.plan_key(kernel, dims)}: "
            f"{plans.TILE_FIELD[kernel]}={knobs['tile']} "
            f"lanes={knobs['lanes']} sem={knobs['dimension_semantics'][0]} "
            f"-> {us:.0f}us (heuristic {heuristic_us:.0f}us"
            + (f", vmap {vmap_us:.0f}us, speedup_vs_vmap {sv:.2f}"
               if vmap_us is not None else "") + ")")
    return entry


def ensure_plan(kernel: str, dims: dict, *,
                store: plans.PlanStore | None = None, **tune_kw) -> dict:
    """Return the stored plan for a cell, tuning it first on a miss."""
    store = store if store is not None else plans.active_store()
    dims = {k: int(v) for k, v in dims.items()}
    hit = store.lookup(kernel, **dims)
    if hit is not None:
        return hit
    return autotune_cell(kernel, dims, store=store, **tune_kw)


# ---- model-driven cell discovery --------------------------------------------

def model_cells(spec, batch: int, n: int, mode: str = "lpcn",
                seed: int = 0) -> list[tuple[str, dict]]:
    """The (kernel, dims) cells ``engine.apply(fc_backend="pallas")``
    resolves plans for at this (spec, B, N) — discovered by tracing the
    real forward under ``plans.capture()`` (and ``plans.bypass()``, so
    discovery itself never depends on the store's current contents)."""
    import jax
    import jax.numpy as jnp
    from repro import engine
    from repro.data.synthetic import make_cloud
    from repro.engine import Batch

    rng = np.random.default_rng(seed)
    xyz = jnp.asarray(np.stack([make_cloud(rng, n) for _ in range(batch)]))
    f_in = spec.in_feats
    feats = xyz if f_in == 3 else jnp.concatenate(
        [xyz, jnp.asarray(rng.uniform(0, 1, (batch, n, f_in - 3)),
                          jnp.float32)], -1)
    b_in = Batch.make(xyz, feats, key=jax.random.PRNGKey(seed))
    params = engine.init(jax.random.PRNGKey(0), spec)

    def fn(params, xyz, feats, keys, n_valid):
        b = Batch(xyz=xyz, feats=feats, keys=keys, n_valid=n_valid)
        return engine.apply(params, b, spec=spec, mode=mode,
                            fc_backend="pallas")

    with plans.bypass(), plans.capture() as used:
        jax.make_jaxpr(fn)(params, b_in.xyz, b_in.feats, b_in.keys,
                           b_in.n_valid)
    cells, seen = [], set()
    for rec in used:
        if rec["dims"].get("b") is None:
            continue
        key = plans.plan_key(rec["kernel"], rec["dims"])
        if key not in seen:
            seen.add(key)
            cells.append((rec["kernel"], rec["dims"]))
    return cells


def autotune_model(spec, batch: int, n: int, mode: str = "lpcn", *,
                   store: plans.PlanStore | None = None,
                   skip_existing: bool = True, seed: int = 0,
                   **tune_kw) -> list[dict]:
    """Tune every cell the model's batched forward resolves at (B, N)."""
    store = store if store is not None else plans.active_store()
    entries = []
    for kernel, dims in model_cells(spec, batch, n, mode=mode, seed=seed):
        if skip_existing and store.lookup(kernel, **dims) is not None:
            continue
        entries.append(autotune_cell(kernel, dims, store=store, seed=seed,
                                     **tune_kw))
    return entries


# ---- CLI --------------------------------------------------------------------

def _resolve_spec(name: str, points: int, reduced: bool):
    from dataclasses import replace
    from repro.models import MODEL_ZOO
    if name not in MODEL_ZOO:
        raise SystemExit(f"unknown model {name!r}; pick from "
                         f"{', '.join(sorted(MODEL_ZOO))}")
    _, spec = MODEL_ZOO[name]
    if reduced:
        spec = replace(spec, blocks=tuple(
            replace(b, n_centers=min(b.n_centers, max(points // 4, 16)),
                    k=min(b.k, 16)) for b in spec.blocks))
    return spec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.autotune",
        description="measure-and-cache tile plans for the batched FC "
                    "kernels (winners land in the plan store the engine "
                    "consults by default)")
    ap.add_argument("--models", default="pointnet2_c",
                    help="comma-separated MODEL_ZOO names")
    ap.add_argument("--batches", default="2,8",
                    help="comma-separated batch sizes (one cell set per B)")
    ap.add_argument("--points", type=int, default=512)
    ap.add_argument("--mode", default="lpcn",
                    choices=("traditional", "lpcn"))
    ap.add_argument("--reduced", action="store_true",
                    help="shrink blocks like launch/serve --reduced")
    ap.add_argument("--budget", type=int, default=12,
                    help="max candidates timed per cell")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--retune", action="store_true",
                    help="re-measure cells already in the store")
    ap.add_argument("--out", default=None,
                    help=f"plan store path (default "
                         f"$REPRO_TILE_PLANS or {plans.DEFAULT_PATH})")
    args = ap.parse_args(argv)

    out = args.out or plans.default_path()
    plans.configure(out)           # accumulate into the existing store
    store = plans.active_store()
    n_before = len(store)
    for mname in args.models.split(","):
        spec = _resolve_spec(mname.strip(), args.points, args.reduced)
        for b in (int(x) for x in args.batches.split(",")):
            print(f"== autotune {mname} B={b} N={args.points} "
                  f"mode={args.mode} ==", flush=True)
            autotune_model(spec, b, args.points, mode=args.mode,
                           store=store, skip_existing=not args.retune,
                           budget=args.budget, reps=args.reps,
                           seed=args.seed, log=print)
    path = store.save(out)
    print(f"plan store: {len(store)} entries "
          f"({len(store) - n_before} new) -> {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
