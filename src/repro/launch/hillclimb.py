"""Hillclimb harness: re-lower ONE cell with config overrides, report the
three roofline terms and the delta vs. the recorded baseline.

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch qwen2-72b --shape train_4k \
        --set seq_shard_blocks=False --tag no_sp

Results append to results/hillclimb.json with the tag, so EXPERIMENTS.md
§Perf can cite exact before/after numbers.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import json            # noqa: E402
import time            # noqa: E402

import jax             # noqa: E402

import repro.launch.dryrun as dr   # noqa: E402
from repro.configs import get_config, _ARCH_MODULES  # noqa: E402


def parse_override(s: str):
    k, v = s.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg overrides key=value")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--out", default="results/hillclimb.json")
    args = ap.parse_args(argv)

    overrides = dict(parse_override(s) for s in args.set)

    # monkeypatch get_config so build_lowered sees the overridden cfg
    base_cfg = get_config(args.arch)
    cfg = dataclasses.replace(base_cfg, **overrides)
    import repro.launch.dryrun as dmod
    orig = dmod.get_config
    dmod.get_config = lambda a, reduced=False: cfg if a == args.arch \
        else orig(a, reduced)
    try:
        t0 = time.time()
        rec = dr.run_cell(args.arch, args.shape, args.multi_pod)
    finally:
        dmod.get_config = orig

    rec["tag"] = args.tag
    rec["overrides"] = overrides
    rec["wall_s"] = round(time.time() - t0, 1)

    hist = []
    if os.path.exists(args.out):
        hist = json.load(open(args.out))
    hist.append(rec)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    json.dump(hist, open(args.out, "w"), indent=1)

    if rec["status"] == "ok":
        print(f"[{args.tag}] {args.arch} × {args.shape}"
              f"{' (2pod)' if args.multi_pod else ''}")
        for k in ("compute_s", "memory_s", "collective_s", "dominant"):
            print(f"  {k:14s} {rec[k]}")
        cb = rec["collective_bytes_per_chip"]
        print("  collectives  ",
              {k: f"{v/1e9:.2f}GB" for k, v in cb.items()})
    else:
        print(rec.get("error"), "\n", rec.get("trace", "")[-1500:])


if __name__ == "__main__":
    main()
