"""Attention: GQA/MQA, full-causal, block-local, cross; train + decode.

Shapes: hidden (B, S, D); per-head (B, S, H, Dh).  GQA is computed grouped
(no K/V expansion).  The Pallas flash kernel is used for long prefill when
``use_flash`` (beyond-paper perf path); the einsum path is the oracle and
the GSPMD-friendly default for the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain, constrain_heads
from .layers import lecun, rope

NEG = -2.0e38


def attn_params(key, d: int, n_heads: int, n_kv: int, head_dim: int,
                qkv_bias: bool, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": lecun(kq, (d, n_heads * head_dim), dtype),
        "wk": lecun(kk, (d, n_kv * head_dim), dtype),
        "wv": lecun(kv, (d, n_kv * head_dim), dtype),
        "wo": lecun(ko, (n_heads * head_dim, d), dtype, fan_in=n_heads * head_dim),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def _project_qkv(p, x, n_heads, n_kv, head_dim, positions, theta,
                 use_rope=True):
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    # heads over the model axis where the head count covers it (GSPMD
    # pads 40->48 fine, but padding few-KV-head tensors onto 16 devices
    # causes involuntary-remat permutes — see sharding.constrain_heads)
    q = constrain_heads(q.reshape(b, s, n_heads, head_dim), n_heads)
    k = constrain_heads(k.reshape(b, s, n_kv, head_dim), n_kv)
    v = constrain_heads(v.reshape(b, s, n_kv, head_dim), n_kv)
    if use_rope:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    return q, k, v


def _gqa_scores(q, k, scale):
    """q (B,S,H,Dh), k (B,T,Hkv,Dh) -> scores (B,Hkv,G,S,T), grouped."""
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, dh)
    return jnp.einsum("bshgd,bthd->bhgst", qg * scale, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(probs, v, b, s, h, dh):
    """probs (B,Hkv,G,S,T), v (B,T,Hkv,Dh) -> (B,S,H*Dh)."""
    o = jnp.einsum("bhgst,bthd->bshgd", probs, v)
    return o.reshape(b, s, h * dh)


CHUNK_Q_ABOVE = 8192   # chunk the query axis for long prefill
N_Q_CHUNKS = 8         # python-unrolled (exact FLOP accounting, no scan)


def causal_attention(p, x, n_heads, n_kv, head_dim, positions, theta,
                     softcap: float = 0.0, prefix_len: int = 0,
                     use_rope: bool = True):
    """Full causal self-attention (optionally with a bidirectional prefix —
    PaliGemma's image tokens attend fully within the prefix).

    For S > CHUNK_Q_ABOVE the query axis is processed in N_Q_CHUNKS
    python-unrolled chunks against the full K/V — the XLA-level
    flash-attention pattern: peak score memory drops S/NC-fold, FLOPs stay
    exact in cost analysis (a lax.scan would hide them), and causality
    additionally skips KV columns beyond each chunk's end."""
    b, s, d = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim, positions, theta,
                           use_rope)
    scale = head_dim ** -0.5

    def block(qc, q0, t_hi):
        """q chunk (B, QC, H, Dh) at offset q0 vs. keys [0, t_hi)."""
        qc_len = qc.shape[1]
        scores = _gqa_scores(qc, k[:, :t_hi], scale)   # (B,Hkv,G,QC,T')
        if softcap > 0:
            scores = jnp.tanh(scores / softcap) * softcap
        rows = q0 + jnp.arange(qc_len)[:, None]
        cols = jnp.arange(t_hi)[None, :]
        mask = rows >= cols
        if prefix_len > 0:
            mask = mask | ((rows < prefix_len) & (cols < prefix_len))
        scores = jnp.where(mask, scores, NEG)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        return _gqa_out(probs, v[:, :t_hi], b, qc_len, n_heads, head_dim)

    if s <= CHUNK_Q_ABOVE:
        o = block(q, 0, s)
    else:
        nc = N_Q_CHUNKS
        assert s % nc == 0
        qlen = s // nc
        o = jnp.concatenate(
            [block(q[:, i * qlen:(i + 1) * qlen], i * qlen,
                   (i + 1) * qlen) for i in range(nc)], axis=1)
    return o @ p["wo"]


def local_attention(p, x, n_heads, n_kv, head_dim, positions, theta,
                    window: int):
    """Block-local causal attention, exact for lookback ``window``.

    Sequence is tiled into blocks of `window`; each block attends to itself
    and the previous block with a per-position causal+window mask.  Memory
    is O(S·2w) instead of O(S²)."""
    b, s, d = x.shape
    w = min(window, s)
    assert s % w == 0, "local attention needs seq divisible by window"
    nb = s // w
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim, positions, theta)
    hkv = n_kv
    g = n_heads // n_kv
    scale = head_dim ** -0.5
    qb = (q * scale).reshape(b, nb, w, hkv, g, head_dim)
    kb = k.reshape(b, nb, w, hkv, head_dim)
    vb = v.reshape(b, nb, w, hkv, head_dim)
    # keys for block i: [block i-1 ++ block i]  (block 0 pads with zeros)
    kprev = jnp.pad(kb[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    vprev = jnp.pad(vb[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    k2 = jnp.concatenate([kprev, kb], axis=2)          # (B,nb,2w,Hkv,Dh)
    v2 = jnp.concatenate([vprev, vb], axis=2)
    scores = jnp.einsum("bnshgd,bnthd->bnhgst", qb, k2,
                        preferred_element_type=jnp.float32)
    rows = jnp.arange(w)[:, None]                       # in-block q pos
    cols = jnp.arange(2 * w)[None, :] - w               # key offset
    mask = (cols <= rows) & (cols > rows - w)           # causal, window w
    first = jnp.arange(nb)[:, None, None] == 0
    mask_b = mask[None, :, :] & (~first | (cols[None] >= 0))
    scores = jnp.where(mask_b[None, :, None, None, :, :], scores, NEG)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bnhgst,bnthd->bnshgd", probs, v2)
    o = o.reshape(b, s, n_heads * head_dim)
    return o @ p["wo"]


def cross_attention(p, x, kv_feats, n_heads, n_kv, head_dim):
    """Whisper decoder cross-attention (no RoPE, no mask); q-chunked for
    long decoder sequences like causal_attention."""
    b, s, d = x.shape
    t = kv_feats.shape[1]
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    k = (kv_feats @ p["wk"]).reshape(b, t, n_kv, head_dim)
    v = (kv_feats @ p["wv"]).reshape(b, t, n_kv, head_dim)

    def block(qc):
        scores = _gqa_scores(qc, k, head_dim ** -0.5)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        return _gqa_out(probs, v, b, qc.shape[1], n_heads, head_dim)

    if s <= CHUNK_Q_ABOVE:
        o = block(q)
    else:
        nc = N_Q_CHUNKS
        qlen = s // nc
        o = jnp.concatenate(
            [block(q[:, i * qlen:(i + 1) * qlen]) for i in range(nc)],
            axis=1)
    return o @ p["wo"]


def decode_cross_attention(p, x, cross_k, cross_v, n_heads, n_kv,
                           head_dim):
    """Decoder cross-attention against precomputed encoder K/V
    (cross_k/v (B, T, Hkv, Dh), computed once per request at prefill)."""
    b = x.shape[0]
    q = (x @ p["wq"]).reshape(b, 1, n_heads, head_dim)
    scores = _gqa_scores(q, cross_k, head_dim ** -0.5)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = _gqa_out(probs, cross_v, b, 1, n_heads, head_dim)
    return o @ p["wo"]


def cross_kv(p, kv_feats, n_kv, head_dim):
    """Precompute encoder K/V for decode."""
    b, t, _ = kv_feats.shape
    k = (kv_feats @ p["wk"]).reshape(b, t, n_kv, head_dim)
    v = (kv_feats @ p["wv"]).reshape(b, t, n_kv, head_dim)
    return k, v


def bidir_attention(p, x, n_heads, n_kv, head_dim):
    """Encoder self-attention (Whisper encoder): full bidirectional."""
    b, s, d = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, s, n_kv, head_dim)
    v = (x @ p["wv"]).reshape(b, s, n_kv, head_dim)
    scores = _gqa_scores(q, k, head_dim ** -0.5)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = _gqa_out(probs, v, b, s, n_heads, head_dim)
    return o @ p["wo"]


# ---------------------------------------------------------------------------
# decode (single new token against a cache)
# ---------------------------------------------------------------------------

def _quantize_kv(kv):
    """kv (B, 1, H, Dh) -> (int8 codes, (B, 1, H) f32 scale)."""
    scale = jnp.max(jnp.abs(kv.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(kv.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def decode_attention(p, x, cache_k, cache_v, pos, n_heads, n_kv, head_dim,
                     theta, window: int = 0, use_rope: bool = True,
                     softcap: float = 0.0, k_scale=None, v_scale=None):
    """x (B, 1, D); cache_k/v (B, T, Hkv, Dh) with valid [0, pos);
    returns (out (B,1,D), new_k, new_v[, new_k_scale, new_v_scale]).

    ``window`` > 0 -> ring-buffer cache of size T=window (local attention).
    ``k_scale``/``v_scale`` (B, T, Hkv) -> the cache is int8-quantized
    per (token, head); dequantization fuses into the attention reads, so
    cache HBM bytes halve vs bf16 (§Perf decode lever).
    """
    b, _, d = x.shape
    t = cache_k.shape[1]
    quant = k_scale is not None
    q = (x @ p["wq"]).reshape(b, 1, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, 1, n_kv, head_dim)
    v = (x @ p["wv"]).reshape(b, 1, n_kv, head_dim)
    if "bq" in p:
        q = q + p["bq"].reshape(1, 1, n_heads, head_dim)
        k = k + p["bk"].reshape(1, 1, n_kv, head_dim)
        v = v + p["bv"].reshape(1, 1, n_kv, head_dim)
    if use_rope:
        posv = jnp.full((b, 1), pos, jnp.int32)
        q = rope(q, posv, theta)
        k = rope(k, posv, theta)
    slot = pos % t if window else pos
    if quant:
        k8, ks = _quantize_kv(k)
        v8, vs = _quantize_kv(v)
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k8, slot, 1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v8, slot, 1)
        k_scale = jax.lax.dynamic_update_slice_in_dim(k_scale, ks, slot, 1)
        v_scale = jax.lax.dynamic_update_slice_in_dim(v_scale, vs, slot, 1)
        kf = (cache_k.astype(x.dtype)
              * k_scale[..., None].astype(x.dtype))
        vf = (cache_v.astype(x.dtype)
              * v_scale[..., None].astype(x.dtype))
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, 1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, 1)
        kf, vf = cache_k, cache_v
    scores = _gqa_scores(q, kf, head_dim ** -0.5)       # (B,Hkv,G,1,T)
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    idx = jnp.arange(t)
    valid = (idx <= slot) | (jnp.full_like(idx, bool(window))
                             .astype(bool) & (pos >= t))
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = _gqa_out(probs, vf, b, 1, n_heads, head_dim)
    if quant:
        return o @ p["wo"], cache_k, cache_v, k_scale, v_scale
    return o @ p["wo"], cache_k, cache_v
