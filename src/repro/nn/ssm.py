"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD: within a chunk the recurrence is computed as a masked
attention-like quadratic form (MXU); across chunks a short ``lax.scan``
carries the (n_heads, headdim, d_state) states.  Single-token decode is the
O(1) recurrence.  n_groups = 1 (B/C shared across heads, the released-model
default).

Layer structure (released mamba2): in_proj -> [z | x | B | C | dt],
causal depthwise conv on (x,B,C), SSD, gated RMSNorm(z), out_proj.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from .layers import lecun, rmsnorm


def ssd_params(key, d_model: int, d_state: int, d_conv: int,
               expand: int, headdim: int, dtype) -> dict:
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    d_proj = 2 * d_inner + 2 * d_state + n_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": lecun(k1, (d_model, d_proj), dtype),
        "conv_w": (jax.random.normal(k2, (d_conv, d_inner + 2 * d_state),
                                     jnp.float32) * 0.1).astype(dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), dtype),
        "out_proj": lecun(k4, (d_inner, d_model), dtype),
    }


def _split_proj(proj, d_inner, d_state, n_heads):
    z = proj[..., :d_inner]
    x = proj[..., d_inner:2 * d_inner]
    B = proj[..., 2 * d_inner:2 * d_inner + d_state]
    C = proj[..., 2 * d_inner + d_state:2 * d_inner + 2 * d_state]
    dt = proj[..., 2 * d_inner + 2 * d_state:]
    return z, x, B, C, dt


def _causal_conv(x, w):
    """x (B, S, D), w (W, D) depthwise causal conv + silu."""
    wlen = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (wlen - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(wlen))
    return jax.nn.silu(out)


def ssd_apply(p, u, d_state: int, expand: int, headdim: int,
              chunk: int = 128):
    """u (B, S, D) -> (B, S, D).  Chunked SSD scan."""
    bsz, s, d_model = u.shape
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    proj = u @ p["in_proj"]
    z, x, B, C, dt = _split_proj(proj, d_inner, d_state, n_heads)
    xBC = _causal_conv(jnp.concatenate([x, B, C], -1), p["conv_w"])
    x = xBC[..., :d_inner]
    B = xBC[..., d_inner:d_inner + d_state]
    C = xBC[..., d_inner + d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"])                    # (B,S,H)
    A = -jnp.exp(p["A_log"])                                # (H,)

    h = n_heads
    xh = x.reshape(bsz, s, h, headdim).astype(jnp.float32)
    assert s % chunk == 0 or s < chunk, "seq must divide chunk"
    q = min(chunk, s)
    nc = s // q
    # head-parallel over the model axis: the (nc, q, q, H) decay tensor
    # and the chunk states shard H-fold (80 heads / 16 = 5 per device)
    xc = constrain(xh.reshape(bsz, nc, q, h, headdim),
                   "dp", None, None, "tp", None)
    Bc = B.reshape(bsz, nc, q, d_state).astype(jnp.float32)
    Cc = C.reshape(bsz, nc, q, d_state).astype(jnp.float32)
    dtc = constrain(dt.reshape(bsz, nc, q, h), "dp", None, None, "tp")
    dA = dtc * A[None, None, None, :]                       # (B,nc,q,H)
    cum = jnp.cumsum(dA, axis=2)                            # in-chunk cumsum

    # --- intra-chunk (quadratic, attention-like, MXU) ---------------------
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,nc,q,q,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    CB = jnp.einsum("bnis,bnjs->bnij", Cc, Bc)              # (B,nc,q,q)
    y_in = jnp.einsum("bnij,bnijh,bnjh,bnjhp->bnihp",
                      CB, L, dtc, xc)                       # (B,nc,q,H,P)

    # --- chunk states + inter-chunk scan -----------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,q,H)
    states = jnp.einsum("bnjs,bnjh,bnjh,bnjhp->bnhps",
                        Bc, decay_to_end, dtc, xc)          # (B,nc,H,P,S)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (B,nc,H)

    def scan_fn(carry, inp):
        st_prev = carry                                     # (B,H,P,S)
        st_c, dec = inp
        st = st_c + dec[..., None, None] * st_prev
        return st, st_prev

    init = jnp.zeros((bsz, h, headdim, d_state), jnp.float32)
    _, st_before = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    st_before = jnp.moveaxis(st_before, 0, 1)               # (B,nc,H,P,S)

    # contribution of carried-in state to each position
    y_out = jnp.einsum("bnis,bnih,bnhps->bnihp",
                       Cc, jnp.exp(cum), st_before)
    y = (y_in + y_out).reshape(bsz, s, h, headdim)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(bsz, s, d_inner).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])        # gated norm
    return y @ p["out_proj"]


def ssd_decode(p, u, state, conv_state, d_state: int, expand: int,
               headdim: int):
    """Single-token decode.  u (B, 1, D); state (B, H, P, S);
    conv_state (B, W-1, d_inner + 2*d_state).  O(1) per token."""
    bsz, _, d_model = u.shape
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    proj = u @ p["in_proj"]
    z, x, B, C, dt = _split_proj(proj[:, 0], d_inner, d_state, n_heads)
    xBC = jnp.concatenate([x, B, C], -1)                    # (B, D')
    w = p["conv_w"]
    wlen = w.shape[0]
    hist = jnp.concatenate([conv_state, xBC[:, None, :]], axis=1)
    conv_out = jax.nn.silu(jnp.sum(hist * w[None], axis=1))
    new_conv_state = hist[:, 1:]
    x = conv_out[..., :d_inner]
    B = conv_out[..., d_inner:d_inner + d_state].astype(jnp.float32)
    C = conv_out[..., d_inner + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])                           # (B,H)
    xh = x.reshape(bsz, n_heads, headdim).astype(jnp.float32)
    dBx = jnp.einsum("bh,bs,bhp->bhps", dt, B, xh)
    state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bs,bhps->bhp", C, state)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(bsz, d_inner).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    return (y @ p["out_proj"])[:, None, :], state, new_conv_state
