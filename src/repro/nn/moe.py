"""Mixture-of-Experts: top-k routing, capacity-bounded scatter dispatch.

Dispatch schemes (selectable; see DESIGN.md §5):
  * ``scatter`` (default) — tokens are scatter-added into per-expert
    capacity buffers (E, C, D) and gathered back with gate weights.
    O(T·D) data movement, no dispatch matmul.  Under GSPMD with the expert
    dim sharded over `model` (EP) the scatter lowers to the expected
    all-to-all.  This is the modern TPU MoE (MaxText-style); the classic
    GShard one-hot *einsum* dispatch costs E·C·T·D MXU flops — 100× the
    expert FFN itself at E=128 — and is therefore not used.
  * ``dense`` — every expert computes every token, mask-combined.  The
    routing oracle; used by tiny smoke configs and tests.

Aux: load-balance loss (Switch-style: E · Σ_e f_e · p_e).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from .layers import lecun, mlp_apply, mlp_params


def moe_params(key, d: int, f: int, n_experts: int, act: str, dtype,
               shared: bool = False) -> dict:
    kr, kg, ki, ko, ks = jax.random.split(key, 5)
    p = {
        "router": lecun(kr, (d, n_experts), dtype),
        "w_in": (jax.random.normal(ki, (n_experts, d, f), jnp.float32)
                 * (1.0 / d) ** 0.5).astype(dtype),
        "w_out": (jax.random.normal(ko, (n_experts, f, d), jnp.float32)
                  * (1.0 / f) ** 0.5).astype(dtype),
    }
    if act in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(kg, (n_experts, d, f), jnp.float32)
                       * (1.0 / d) ** 0.5).astype(dtype)
    if shared:
        p["shared"] = mlp_params(ks, d, f, act, dtype)
    return p


def _expert_ffn(p, x, act):
    """x (E, C, D) -> (E, C, D), per-expert gated FFN."""
    if "w_gate" in p:
        pre = jnp.einsum("ecd,edf->ecf", x, p["w_gate"])
        g = jax.nn.silu(pre) if act == "swiglu" else \
            jax.nn.gelu(pre, approximate=True)
        h = g * jnp.einsum("ecd,edf->ecf", x, p["w_in"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, p["w_in"]),
                        approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, p["w_out"])


def _route(p, xt, n_experts, top_k):
    logits = (xt @ p["router"]).astype(jnp.float32)        # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    gate_k, idx_k = jax.lax.top_k(gates, top_k)            # (T, k)
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss: fraction routed vs. mean gate, per expert
    f_e = jnp.mean(jax.nn.one_hot(idx_k[:, 0], n_experts), axis=0)
    p_e = jnp.mean(gates, axis=0)
    aux = n_experts * jnp.sum(f_e * p_e)
    return gate_k, idx_k, aux


def moe_apply(p, x, n_experts: int, top_k: int, act: str,
              capacity_factor: float = 1.25, scheme: str = "scatter",
              shard: str = "ep"):
    """x (B, S, D) -> (y (B, S, D), aux loss scalar)."""
    if scheme == "dense":
        return _moe_dense(p, x, n_experts, top_k, act)
    b, s, d = x.shape
    n_tok = b * s
    xt = x.reshape(n_tok, d)
    gate_k, idx_k, aux = _route(p, xt, n_experts, top_k)

    cap = max(int(n_tok * top_k / n_experts * capacity_factor), 4)
    # rank of each (token, k) slot within its expert queue (first-come)
    onehot = jax.nn.one_hot(idx_k.reshape(-1), n_experts, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - 1)                 # (T*k, E)
    pos = jnp.take_along_axis(pos, idx_k.reshape(-1, 1), axis=1
                              ).reshape(n_tok, top_k)      # (T, k)
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)                       # cap = drop row

    # scatter-dispatch into (E, C+1, D); the +1 row absorbs drops
    buf = jnp.zeros((n_experts, cap + 1, d), x.dtype)
    tok_rep = jnp.broadcast_to(xt[:, None, :], (n_tok, top_k, d))
    buf = buf.at[idx_k, slot].add(tok_rep, mode="drop")
    # EP: expert buffers live on their expert's shard (scatter -> a2a)
    buf = constrain(buf, "tp" if shard == "ep" else None, None, None)
    ye = _expert_ffn(p, buf[:, :cap], act)                 # (E, C, D)
    ye = jnp.pad(ye, ((0, 0), (0, 1), (0, 0)))             # drop row = 0
    out = ye[idx_k, slot]                                  # (T, k, D)
    yt = jnp.sum(out * gate_k[..., None].astype(x.dtype), axis=1)
    y = yt.reshape(b, s, d)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, act)
    return y, aux


def _moe_dense(p, x, n_experts, top_k, act):
    """Oracle: every expert computes every token; combine with gates."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    gate_k, idx_k, aux = _route(p, xt, n_experts, top_k)
    w = jnp.zeros((xt.shape[0], n_experts), jnp.float32).at[
        jnp.arange(xt.shape[0])[:, None], idx_k].set(gate_k)  # (T, E)
    ye = _expert_ffn(p, jnp.broadcast_to(xt, (n_experts,) + xt.shape), act)
    yt = jnp.einsum("te,etd->td", w.astype(xt.dtype), ye)
    y = yt.reshape(b, s, d)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, act)
    return y, aux
