"""Pure-JAX NN primitives (no flax): norms, RoPE, gated MLPs, embedding.

Params are plain nested dicts of jnp arrays; init functions are pure and
can be shape-evaluated (jax.eval_shape) so the 100B+ configs never
materialize on the dry-run host.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def he(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape, jnp.float32)
            * (2.0 / fan_in) ** 0.5).astype(dtype)


def lecun(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape, jnp.float32)
            * (1.0 / fan_in) ** 0.5).astype(dtype)


def embed_init(key, shape, dtype):
    """std = 1/sqrt(d): keeps tied-head logits O(1); embed_scale archs
    (gemma family) multiply inputs back up by sqrt(d)."""
    d = shape[-1]
    return (jax.random.normal(key, shape, jnp.float32)
            * d ** -0.5).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray | None,
            eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype)


def np_layernorm(x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Non-parametric LayerNorm (OLMo): no learned scale/bias."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(kind: str, x, p):
    if kind == "rms":
        return rmsnorm(x, p["scale"])
    if kind == "np_ln":
        return np_layernorm(x)
    if kind == "ln":
        return layernorm(x, p["scale"], p["bias"])
    raise ValueError(kind)


def norm_params(kind: str, d: int, dtype) -> dict:
    if kind == "rms":
        return {"scale": jnp.zeros((d,), dtype)}
    if kind == "np_ln":
        return {}
    if kind == "ln":
        return {"scale": jnp.ones((d,), dtype),
                "bias": jnp.zeros((d,), dtype)}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10000.0) -> jnp.ndarray:
    """x (..., S, H, Dh), positions (..., S) -> rotated x."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                          # (...,S,1,half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------

def mlp_params(key, d: int, f: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_out": lecun(k2, (f, d), dtype)}
    if act in ("swiglu", "geglu"):
        p["w_gate"] = lecun(k1, (d, f), dtype)
        p["w_in"] = lecun(k3, (d, f), dtype)
    else:
        p["w_in"] = lecun(k1, (d, f), dtype)
    return p


def mlp_apply(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "swiglu":
        g = jax.nn.silu(x @ p["w_gate"])
        return ((g * (x @ p["w_in"])) @ p["w_out"])
    if act == "geglu":
        g = jax.nn.gelu(x @ p["w_gate"], approximate=True)
        return ((g * (x @ p["w_in"])) @ p["w_out"])
    if act == "gelu":
        return jax.nn.gelu(x @ p["w_in"], approximate=True) @ p["w_out"]
    raise ValueError(act)


def mlp_flops(d: int, f: int, act: str) -> int:
    n_mat = 3 if act in ("swiglu", "geglu") else 2
    return 2 * n_mat * d * f
