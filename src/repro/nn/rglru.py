"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (diagonal, gated):
    r_t = sigmoid(W_r x_t)            (recurrence gate)
    i_t = sigmoid(W_i x_t)            (input gate)
    a_t = exp(-c · softplus(Λ) · r_t) (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training uses an associative scan (log-depth, TPU-friendly); decode is the
O(1) step.  The full Griffin recurrent block is: linear x/gate branches,
causal conv(4) on the x branch, RG-LRU, gated merge, output projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import lecun

C_FACTOR = 8.0


def rglru_params(key, d_model: int, d_rnn: int, d_conv: int, dtype) -> dict:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "w_x": lecun(k1, (d_model, d_rnn), dtype),
        "w_gate": lecun(k2, (d_model, d_rnn), dtype),
        "conv_w": (jax.random.normal(k3, (d_conv, d_rnn), jnp.float32)
                   * 0.1).astype(dtype),
        "w_r": lecun(k4, (d_rnn, d_rnn), dtype),
        "w_i": lecun(k5, (d_rnn, d_rnn), dtype),
        # Λ init so that a ∈ (0.9, 0.999) at r=1 (Griffin appendix)
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, d_rnn)) / C_FACTOR)),
        "w_out": lecun(k6, (d_rnn, d_model), dtype),
    }


def _gates(p, x):
    r = jax.nn.sigmoid((x @ p["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["w_i"]).astype(jnp.float32))
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"]) * r       # (..., D)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    return a, beta * i


def _causal_conv(x, w):
    wlen = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (wlen - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
               for i in range(wlen))


def rglru_apply(p, u):
    """u (B, S, D) -> (B, S, D).  Griffin recurrent block, parallel scan."""
    gate = jax.nn.gelu(u @ p["w_gate"], approximate=True)
    x = _causal_conv(u @ p["w_x"], p["conv_w"])
    a, bx = _gates(p, x)
    bx = bx * x.astype(jnp.float32)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    y = (h.astype(u.dtype) * gate) @ p["w_out"]
    return y


def rglru_decode(p, u, state, conv_state):
    """u (B, 1, D); state (B, D_rnn) f32; conv_state (B, W-1, D_rnn)."""
    gate = jax.nn.gelu(u[:, 0] @ p["w_gate"], approximate=True)
    xt = u[:, 0] @ p["w_x"]
    w = p["conv_w"]
    hist = jnp.concatenate([conv_state, xt[:, None, :]], axis=1)
    x = jnp.sum(hist * w[None], axis=1)
    new_conv_state = hist[:, 1:]
    a, bi = _gates(p, x)
    state = a * state + bi * x.astype(jnp.float32)
    y = (state.astype(u.dtype) * gate) @ p["w_out"]
    return y[:, None, :], state, new_conv_state
