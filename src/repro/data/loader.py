"""Deterministic, sharded, resumable data pipeline.

Design for 1000+ nodes (DESIGN.md §5):
  * batch content is a pure function of (seed, step) — no queue state, so
    a restarted or replaced node computes exactly the batches it needs
    (skip-ahead resume is O(1), no replay);
  * each host materializes only its local shard of the global batch
    (host_index/host_count slicing);
  * straggler mitigation: batches for steps [s, s+prefetch) are generated
    ahead on a size-bounded deque — a slow host never stalls the
    collective because generation is compute-only and deterministic.

State = {"seed", "step"} — two ints, checkpointed in meta.json.
"""
from __future__ import annotations

from collections import deque

import numpy as np

from .synthetic import token_batch


class TokenStream:
    def __init__(self, *, vocab: int, batch: int, seq_len: int,
                 seed: int = 0, step: int = 0, host_index: int = 0,
                 host_count: int = 1, prefetch: int = 2):
        assert batch % host_count == 0
        self.vocab, self.batch, self.seq = vocab, batch, seq_len
        self.seed = seed
        self.step = step
        self.host_index, self.host_count = host_index, host_count
        self.prefetch = prefetch
        self._q: deque = deque()

    # -- iteration ----------------------------------------------------------

    def _make(self, step: int) -> np.ndarray:
        full = token_batch(step, self.batch, self.seq + 1, self.vocab,
                           self.seed)
        per = self.batch // self.host_count
        lo = self.host_index * per
        return full[lo:lo + per]

    def next(self) -> np.ndarray:
        while len(self._q) < self.prefetch:
            self._q.append((self.step + len(self._q),
                            self._make(self.step + len(self._q))))
        s, b = self._q.popleft()
        assert s == self.step
        self.step += 1
        return b

    # -- checkpoint integration ----------------------------------------------

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_state(cls, state: dict, **kw):
        return cls(seed=state["seed"], step=state["step"], **kw)
