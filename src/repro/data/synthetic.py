"""Synthetic data generators.

Point clouds: the four public datasets (ModelNet40 / ShapeNet / S3DIS /
ScanNet) are not available offline, so we surface-sample composited
geometric primitives (spheres, boxes, cylinders, planes) at the published
point counts.  Surface sampling gives the anisotropic, locally dense
structure that drives the paper's overlap statistics (uniform-volume noise
would understate overlap).  Densities are matched per dataset scale.

Tokens: deterministic, resumable LM batch streams (see loader.py for the
sharded pipeline built on top).
"""
from __future__ import annotations

import numpy as np

DATASETS = {
    # name: (points per cloud, feature dim, n classes, scene_like)
    "modelnet40": (1024, 3, 40, False),
    "shapenet": (2048, 3, 16, False),
    "s3dis": (4096, 6, 13, True),
    "scannet": (8192, 6, 20, True),
    "s3dis_large": (65536, 6, 13, True),   # FractalCloud large-scale band
}


def _sphere(rng, n, c, r):
    v = rng.normal(size=(n, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True) + 1e-9
    return c + r * v


def _box(rng, n, c, s):
    face = rng.integers(0, 6, n)
    u = rng.uniform(-0.5, 0.5, (n, 3))
    axis, side = face % 3, (face // 3) * 1.0 - 0.5
    u[np.arange(n), axis] = side
    return c + u * s


def _cylinder(rng, n, c, r, h):
    th = rng.uniform(0, 2 * np.pi, n)
    z = rng.uniform(-h / 2, h / 2, n)
    return c + np.stack([r * np.cos(th), r * np.sin(th), z], -1)


def _plane(rng, n, c, s):
    u = rng.uniform(-0.5, 0.5, (n, 2)) * s
    return c + np.stack([u[:, 0], u[:, 1], 0.02 * rng.normal(size=n)], -1)


def make_cloud(rng: np.random.Generator, n_points: int,
               scene_like: bool = False) -> np.ndarray:
    """One synthetic cloud (n_points, 3), normalized to the unit ball /
    room extent.  Object clouds: 3-6 primitives (CAD-surface-like);
    scenes: dominated by large planar surfaces (walls/floor) plus
    furniture-scale boxes — matching the surface concentration that
    drives the published overlap statistics on S3DIS/ScanNet."""
    prims = []
    n_parts = rng.integers(3, 7) if not scene_like else rng.integers(5, 9)
    share = rng.dirichlet(np.ones(n_parts) * 2.0) * n_points
    share = np.maximum(share.astype(int), 8)
    for pi, ns in enumerate(share):
        c = rng.uniform(-0.6, 0.6, 3)
        if scene_like:
            # 60% planes (room surfaces), 40% furniture boxes/cylinders
            kind = 3 if rng.random() < 0.6 else rng.integers(0, 3)
        else:
            kind = rng.integers(0, 3)
        if kind == 0:
            prims.append(_sphere(rng, ns, c, rng.uniform(0.1, 0.4)))
        elif kind == 1:
            prims.append(_box(rng, ns, c, rng.uniform(0.1, 0.5, 3)))
        elif kind == 2:
            prims.append(_cylinder(rng, ns, c, rng.uniform(0.05, 0.3),
                                   rng.uniform(0.2, 0.8)))
        else:
            prims.append(_plane(rng, ns, c, rng.uniform(0.8, 1.8, 2)))
    pts = np.concatenate(prims)[:n_points]
    if pts.shape[0] < n_points:  # pad by resampling
        extra = pts[rng.integers(0, pts.shape[0], n_points - pts.shape[0])]
        pts = np.concatenate([pts, extra])
    pts += 0.005 * rng.normal(size=pts.shape)  # sensor noise
    pts -= pts.mean(0)
    pts /= np.abs(pts).max() + 1e-9
    return pts.astype(np.float32)


def make_dataset(name: str, n_clouds: int, seed: int = 0):
    """-> (clouds (B,N,3), feats (B,N,F), labels (B,))."""
    n_pts, f_dim, n_cls, scene = DATASETS[name]
    rng = np.random.default_rng(seed)
    clouds = np.stack([make_cloud(rng, n_pts, scene) for _ in range(n_clouds)])
    if f_dim > 3:
        feats = rng.uniform(0, 1, (n_clouds, n_pts, f_dim - 3)
                            ).astype(np.float32)
        feats = np.concatenate([clouds, feats], -1)
    else:
        feats = clouds.copy()
    labels = rng.integers(0, n_cls, n_clouds).astype(np.int32)
    return clouds, feats, labels


def token_batch(step: int, batch: int, seq_len: int, vocab: int,
                seed: int = 0) -> np.ndarray:
    """Deterministic token batch for step `step` (resumable by
    construction: content is a pure function of (seed, step))."""
    rng = np.random.default_rng(np.uint64(seed) + np.uint64(step) * 2654435761)
    return rng.integers(0, vocab, (batch, seq_len), dtype=np.int32)
