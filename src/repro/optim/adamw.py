"""AdamW (pure JAX, pytree-native) with configurable state dtype.

``state_dtype="bfloat16"`` halves optimizer HBM (needed to fit the 314B /
400B configs on 256 chips — DESIGN.md §5); update math is always f32.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "bfloat16"    # m/v storage dtype


def init_state(cfg: AdamWConfig, params):
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def apply_updates(cfg: AdamWConfig, params, grads, state,
                  lr_scale=1.0):
    """-> (new_params, new_state).  f32 math, states stored in
    cfg.state_dtype, params updated in their own dtype."""
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mh = m32 / c1
        vh = v32 / c2
        step_dir = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:   # no decay on norms/biases
            step_dir = step_dir + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step_dir).astype(p.dtype)
        return newp, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tree.unflatten([o[0] for o in out])
    new_m = tree.unflatten([o[1] for o in out])
    new_v = tree.unflatten([o[2] for o in out])
    # preserve side-channel entries (e.g. dist.compress error feedback in
    # state["ef"], already updated by the compressor hook before this)
    return new_p, {**state, "m": new_m, "v": new_v, "step": step}
