"""Adafactor (factored second moment) — the low-memory optimizer option
for the 314B/400B configs: O(n+m) state per (n,m) matrix instead of
O(n·m)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8
    eps: float = 1e-30
    clip: float = 1.0


def init_state(cfg: AdafactorConfig, params):
    def st(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"f": jax.tree.map(st, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32)}


def apply_updates(cfg: AdafactorConfig, params, grads, state,
                  lr_scale=1.0):
    step = state["step"] + 1
    beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-cfg.decay)

    def upd(p, g, s):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + cfg.eps
        if p.ndim >= 2:
            vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
            vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(-1, keepdims=True)[..., None],
                                   cfg.eps))
            u = g32 / jnp.sqrt(denom + cfg.eps)
            ns = {"vr": vr, "vc": vc}
        else:
            v = beta * s["v"] + (1 - beta) * g2
            u = g32 / jnp.sqrt(v + cfg.eps)
            ns = {"v": v}
        rms = jnp.sqrt(jnp.mean(u * u) + cfg.eps)
        u = u / jnp.maximum(1.0, rms / cfg.clip)
        newp = (p.astype(jnp.float32) - cfg.lr * lr_scale * u
                ).astype(p.dtype)
        return newp, ns

    leaves_p, tree = jax.tree.flatten(params)
    leaves_g = jax.tree.leaves(grads)
    leaves_s = tree.flatten_up_to(state["f"])
    out = [upd(p, g, s) for p, g, s in zip(leaves_p, leaves_g, leaves_s)]
    return (tree.unflatten([o[0] for o in out]),
            {"f": tree.unflatten([o[1] for o in out]), "step": step})
