"""LR schedules (pure functions of step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int = 1000, total: int = 100000,
                  floor: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(s / max(warmup, 1), 1.0)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
