"""Typed, pytree-registered containers for the engine API.

:class:`PCNParams` replaces the per-model ``{"blocks": [...], ...}`` dicts:
one frozen dataclass covering every architecture family (SA stacks, DGCNN,
PointNeXt, PointVector), registered as a JAX pytree so whole-model params
flow through ``jit`` / ``vmap`` / ``grad`` / optimizers untouched.

:class:`Batch` is the batched input container: padded (B, N, 3) clouds with
per-cloud features, PRNG keys and valid-point counts.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mlp import MLP


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class PCNParams:
    """All parameters of one PCN.

    blocks:      one MLP per building block (the FC-step point MLPs).
    head:        classifier / per-point head MLP.
    global_mlp:  final global-SA MLP (cls models; None otherwise).
    stem:        per-point input embedding (PointNeXt/PointVector; None
                 otherwise).
    extras:      per-block side branches — InvResMLP (PointNeXt) or the
                 vector-recombination MLPs (PointVector); empty otherwise.
    """
    blocks: tuple
    head: MLP
    global_mlp: MLP | None = None
    stem: MLP | None = None
    extras: tuple = ()

    def tree_flatten(self):
        return ((self.blocks, self.head, self.global_mlp, self.stem,
                 self.extras), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def from_legacy(params) -> PCNParams:
    """Convert a legacy per-model param dict to :class:`PCNParams`.

    Accepts the three historical dict layouts ({"blocks","global","head"},
    {"stem","blocks","invres","head"}, {"stem","blocks","vector","head"});
    a PCNParams passes through unchanged.
    """
    if isinstance(params, PCNParams):
        return params
    extras = params.get("invres") or params.get("vector") or ()
    return PCNParams(
        blocks=tuple(params["blocks"]),
        head=params["head"],
        global_mlp=params.get("global"),
        stem=params.get("stem"),
        extras=tuple(extras),
    )


def to_legacy(params: PCNParams, arch: str) -> dict:
    """Render :class:`PCNParams` in the legacy dict layout of ``arch``
    (for old call sites that index ``params["blocks"]`` etc.)."""
    if arch == "pointnext":
        return {"stem": params.stem, "blocks": list(params.blocks),
                "invres": list(params.extras), "head": params.head}
    if arch == "pointvector":
        return {"stem": params.stem, "blocks": list(params.blocks),
                "vector": list(params.extras), "head": params.head}
    return {"blocks": list(params.blocks), "global": params.global_mlp,
            "head": params.head}


def validate_cloud(arr, name: str = "xyz", index=None):
    """Host-side payload validation shared by :meth:`Batch.make`'s /
    :meth:`Batch.from_clouds`'s ``validate=`` path and the serving
    admission guard: reject non-finite values, coerce any floating
    dtype to float32, refuse non-floating dtypes.  Returns the cloud
    as a float32 numpy array.

    Validation is eager-only (it inspects values, which a traced array
    cannot do) — run it where the data is still host-side, *before*
    jit: a NaN that reaches a compiled kernel silently corrupts every
    reduction that touches it, with no error to catch.
    """
    tag = name if index is None else f"{name}[{index}]"
    a = np.asarray(arr)
    if not np.issubdtype(a.dtype, np.floating):
        raise ValueError(
            f"{tag} has dtype {a.dtype}, which is not a floating point "
            f"cloud payload; convert to float32 before submitting")
    if a.dtype != np.float32:
        a = a.astype(np.float32)     # f64/f16 inputs: coerce, don't trust
                                     # implicit x64 downcasts
    if not np.isfinite(a).all():
        n_bad = int(np.size(a) - np.isfinite(a).sum())
        rows = np.unique(np.argwhere(~np.isfinite(a))[:, 0])[:4]
        raise ValueError(
            f"{tag} contains {n_bad} non-finite value(s) (NaN/Inf), e.g. "
            f"in row(s) {rows.tolist()}; refuse or clean the cloud before "
            f"it reaches a compiled kernel")
    return a


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class Batch:
    """A padded batch of point clouds.

    xyz:     (B, N, 3) coordinates; clouds shorter than N are padded by
             repeating their last point (any finite padding works — it is
             fully masked; repeat-last keeps values well-conditioned).
    feats:   (B, N, F) per-point input features (xyz for plain geometry).
    keys:    (B, 2) uint32 — one PRNG key per cloud (drives random
             sampling / hub selection independently per cloud).  Typed
             keys are canonicalized to raw uint32 key data by ``make`` so
             the pytree signature (and the engine's jit cache) is stable.
    n_valid: (B,) int32 — true point count per cloud before padding.

    Ragged contract (enforced end to end by the engine): rows >= n_valid
    are padding — never sampled as centers, never returned by neighbor
    search, never cached/pooled/islandized, excluded from every
    WorkloadReport counter, and their per-point (seg) logits are zeroed.
    ``engine.apply(batch)[i]`` equals ``engine.apply_single`` on cloud
    i's unpadded prefix (rows [:n_valid[i]] for seg outputs).
    """
    xyz: jnp.ndarray
    feats: jnp.ndarray
    keys: jnp.ndarray
    n_valid: jnp.ndarray

    def tree_flatten(self):
        return ((self.xyz, self.feats, self.keys, self.n_valid), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def batch_size(self) -> int:
        return self.xyz.shape[0]

    @staticmethod
    def make(xyz, feats=None, key=None, n_valid=None, *,
             validate: bool = False) -> "Batch":
        """Wrap pre-stacked (B, N, 3)/(B, N, F) arrays.  ``key`` may be a
        single PRNG key (split per cloud) or (B, 2) per-cloud keys.

        ``validate=True`` runs the host-side payload check
        (:func:`validate_cloud`): non-finite values are rejected with
        an actionable error and floating dtypes are coerced to float32
        — eager inputs only (traced arrays cannot be value-checked)."""
        if validate:
            xyz = validate_cloud(xyz, "xyz")
            if feats is not None:
                feats = validate_cloud(feats, "feats")
        xyz = jnp.asarray(xyz)
        b, n = xyz.shape[0], xyz.shape[1]
        feats = xyz if feats is None else jnp.asarray(feats)
        if key is None:
            key = jax.random.PRNGKey(0)
        # a single key is ndim-1 raw uint32 or ndim-0 typed; anything with
        # one more axis is already per-cloud
        typed = jax.dtypes.issubdtype(key.dtype, jax.dtypes.prng_key)
        single = key.ndim == (0 if typed else 1)
        keys = jax.random.split(key, b) if single else key
        if jax.dtypes.issubdtype(keys.dtype, jax.dtypes.prng_key):
            # canonicalize typed keys -> raw uint32 so a Batch always has
            # the same pytree signature (no retrace vs raw-array callers)
            keys = jax.random.key_data(keys)
        # host numpy keys (the serving dispatcher stacks per-request key
        # data) must become jax Arrays too — the jit cache distinguishes
        # ndarray leaves from ArrayImpl leaves, which would force one
        # spurious recompile per bucket
        keys = jnp.asarray(keys)
        if n_valid is None:
            n_valid = jnp.full((b,), n, jnp.int32)
        return Batch(xyz=xyz, feats=feats, keys=keys,
                     n_valid=jnp.asarray(n_valid, jnp.int32))

    @staticmethod
    def from_clouds(clouds, feats=None, key=None, n_pad=None, *,
                    validate: bool = False) -> "Batch":
        """Stack variable-size clouds into one padded batch.

        Each cloud is padded to ``n_pad`` rows (default: the longest
        cloud) by repeating its last point; ``n_valid`` records the true
        sizes.  A cloud already at ``n_pad`` passes through untouched,
        and an *empty* (0, ·) cloud — the serving dispatcher's
        batch-fill rows for partial batches — is zero-filled and fully
        masked via ``n_valid == 0``.  Raises if ``n_pad`` is shorter
        than the longest cloud (silent truncation would break the
        ragged contract).

        ``validate=True`` runs :func:`validate_cloud` per cloud (and
        per feature array): NaN/Inf rejected with the offending cloud
        index in the message, dtypes coerced to float32 — the serving
        admission guard runs the same check per request at ``submit``.
        """
        clouds = [np.asarray(c) for c in clouds]
        if not clouds:
            raise ValueError("from_clouds needs at least one cloud")
        if validate:
            clouds = [validate_cloud(c, "clouds", i)
                      for i, c in enumerate(clouds)]
            if feats is not None:
                feats = [validate_cloud(f, "feats", i)
                         for i, f in enumerate(feats)]
        longest = max(c.shape[0] for c in clouds)
        n = longest if n_pad is None else int(n_pad)
        if n < longest:
            raise ValueError(
                f"n_pad={n} is shorter than the longest cloud "
                f"({longest} points); pick a bucket that fits")
        if n < 1:
            raise ValueError(
                "all clouds are empty; pass n_pad >= 1 to fix the "
                "padded shape")
        n_valid = np.array([c.shape[0] for c in clouds], np.int32)

        def pad(c):
            if c.shape[0] == n:
                return c
            if c.shape[0] == 0:
                return np.zeros((n,) + c.shape[1:], c.dtype)
            return np.concatenate(
                [c, np.repeat(c[-1:], n - c.shape[0], axis=0)])

        xyz = jnp.asarray(np.stack([pad(c) for c in clouds]))
        f = None if feats is None else jnp.asarray(
            np.stack([pad(np.asarray(x)) for x in feats]))
        return Batch.make(xyz, f, key, n_valid)


def as_batch(batch) -> Batch:
    """Coerce engine.apply input: a Batch passes through; a raw (B, N, 3)
    array becomes a geometry-only batch with default keys."""
    if isinstance(batch, Batch):
        return batch
    arr = jnp.asarray(batch) if not hasattr(batch, "ndim") else batch
    if arr.ndim != 3:
        raise TypeError(
            f"engine.apply expects a Batch or a (B, N, 3) array; got "
            f"shape {getattr(arr, 'shape', None)}")
    return Batch.make(arr)
