"""Batched, backend-pluggable PCN engine — the public API.

One jit-able entry point from a padded cloud batch to logits, with every
swappable stage (sampler, neighbor search, FC backend, architecture
family) resolved by name through registries — the software form of the
paper's claim that the Islandization Unit plugs into any PCN
accelerator's workflow.

    from repro import engine

    params = engine.init(key, spec)                       # typed pytree
    logits = engine.apply(params, batch, spec=spec)       # (B, ...) logits
    eng = engine.PCNEngine(spec, fc_backend="pallas")     # serving handle

Extension points: :func:`register_sampler`, :func:`register_neighbor`,
:func:`register_fc_backend` (backends: "reference" jnp oracle, "pallas"
natively batched TPU kernels — one pallas_call per FC call site for the
whole cloud stack — and "pallas_vmap", the per-cloud dispatch kept for
A/B measurement).
"""
from repro.core.registry import (FC_BACKENDS, NEIGHBORS, SAMPLERS, Registry,
                                 register_fc_backend, register_neighbor,
                                 register_sampler)

from . import params as params_mod
from .archs import ARCHS, Arch, EngineCtx, feature_propagation, get_arch
from .engine import (PCNEngine, apply, apply_single, apply_with_reports,
                     init)
from .fc import two_layer_form
from .params import (Batch, PCNParams, as_batch, from_legacy, to_legacy,
                     validate_cloud)
from .spec import BlockSpec, PCNSpec, arch_of, block_in_dim

# legacy-style alias so call sites can write `engine.params.from_legacy`
params = params_mod

__all__ = [
    "PCNEngine", "init", "apply", "apply_single", "apply_with_reports",
    "Batch", "PCNParams", "as_batch", "from_legacy", "to_legacy",
    "validate_cloud",
    "BlockSpec", "PCNSpec", "arch_of", "block_in_dim",
    "Registry", "SAMPLERS", "NEIGHBORS", "FC_BACKENDS", "ARCHS", "Arch",
    "EngineCtx", "register_sampler", "register_neighbor",
    "register_fc_backend", "get_arch", "feature_propagation",
    "two_layer_form",
]
