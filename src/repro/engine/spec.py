"""Model specifications — static, hashable descriptions of a PCN.

A :class:`PCNSpec` is pure Python data (ints/strings/tuples), so it can be
closed over by ``jax.jit`` (one compiled executable per spec) and drives
all shape decisions statically.  Moved here from ``repro.models.common``
so the engine owns the public API surface; ``models.common`` re-exports
for backward compatibility.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class BlockSpec:
    """One building block (SA or EdgeConv) of a PCN."""
    n_centers: int
    k: int
    mlp_dims: tuple            # hidden+out dims, input inferred
    radius: float = 0.2
    kind: str = "sa"           # sa | edge
    sampler: str = "fps"
    neighbor: str = "pointacc"


@dataclass(frozen=True)
class PCNSpec:
    """A whole point-cloud network."""
    name: str
    blocks: tuple              # tuple[BlockSpec]
    head_dims: tuple           # classifier / per-point head
    n_classes: int
    in_feats: int = 3          # input feature dim (xyz counts as features)
    task: str = "cls"          # cls | seg
    global_mlp: tuple = ()     # final global SA mlp (cls only)
    activation: str = "per_layer"   # per_layer | block_end (paper §VI-E)


def block_in_dim(kind: str, f_prev: int) -> int:
    return (3 + f_prev) if kind == "sa" else (2 * f_prev)


def arch_of(spec: PCNSpec) -> str:
    """Architecture family a spec belongs to (drives init/forward
    dispatch).  Unknown names fall back to the generic SA-stack family
    ("pointnet2"), which covers ad-hoc specs built in tests/examples."""
    return spec.name.split("_")[0]
