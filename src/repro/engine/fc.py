"""The "pallas" FC backend: the building block's two MXU dataflows routed
through the TPU kernels (CPU: interpret mode; TPU: Mosaic-compiled).

  dense  -> kernels/gather_mlp   fused normalize → MLP → max-pool
  reuse  -> kernels/hub_reuse    pool-MLP → one-hot reuse-gather → Δ-comp
                                 → masked max-pool

Both kernels are fixed two-layer (W1, relu, W2) pipelines — the shape of
the paper's systolic FCU.  General point-MLPs are lowered to that form
exactly:

  * ``block_end`` (all layers linear): compose every layer into ONE linear
    map, then embed it as relu(x·[W,−W]+[b,−b])·[I;−I] — exact, because
    relu(a) − relu(−a) = a.
  * ``per_layer`` with 2 layers: direct.
  * ``per_layer`` with 1 layer: the same split-sign embedding.
  * ``per_layer`` with >2 layers: the leading layers run as a jnp prologue
    (they are the cheap narrow layers); the last two — the wide ones that
    dominate FLOPs — run fused in the kernel.

Two registry entries share these lowerings:

* ``"pallas"`` — the serving backend: its ``dense_batched`` /
  ``reuse_batched`` entries run the natively batched kernels (grid
  ``(B, ⌈S/TS⌉)`` / ``(B, ⌈H/TH⌉)``, weight-resident, lane-aligned) so
  ONE pallas_call per FC call site serves the whole cloud stack.  Tile
  plans resolve per shape: an explicit ``kernel_kw`` knob (``{"ts",
  "th", "vmem_budget_mb", "lanes", "dimension_semantics"}``, threaded
  down from ``engine.apply`` / ``PCNEngine``) wins, else an autotuned
  plan from the ``repro.kernels.plans`` store (cache hit), else the
  VMEM-budget heuristic (see ``repro.launch.autotune``).
* ``"pallas_vmap"`` — the pre-batching behavior (per-cloud kernels under
  ``jax.vmap``), kept registered for A/B measurement in
  ``benchmarks/run.py``.

The pure-jnp oracle is the ``"reference"`` backend in ``core.pipeline``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mlp import MLP
from repro.core.pipeline import FCBackend, _subset_inputs
from repro.core.registry import FC_BACKENDS
from repro.kernels.gather_mlp.ops import gather_mlp, gather_mlp_batched
from repro.kernels.hub_reuse.ops import hub_reuse, hub_reuse_batched


def _split_sign(w, b):
    """Embed the linear map x·w+b as a relu pair: since
    relu(a) − relu(−a) = a, relu(x·[w,−w]+[b,−b])·[I;−I] is exact."""
    f = w.shape[1]
    eye = jnp.eye(f, dtype=w.dtype)
    w1 = jnp.concatenate([w, -w], axis=1)
    b1 = jnp.concatenate([b, -b], axis=0)
    w2 = jnp.concatenate([eye, -eye], axis=0)
    return w1, b1, w2, jnp.zeros((f,), w.dtype)


def two_layer_form(mlp: MLP):
    """Lower an arbitrary point-MLP to the kernels' fixed
    relu-sandwich form.  Returns (prologue | None, (w1, b1, w2, b2));
    the prologue (if any) is applied with jnp before the kernel call."""
    layers = mlp.layers
    if mlp.activation == "block_end":
        w, b = layers[0].w, layers[0].b
        for l in layers[1:]:
            b = b @ l.w + l.b
            w = w @ l.w
        return None, _split_sign(w, b)
    if len(layers) == 1:
        return None, _split_sign(layers[0].w, layers[0].b)
    if len(layers) == 2:
        return None, (layers[0].w, layers[0].b, layers[1].w, layers[1].b)

    def prologue(x):
        # every prologue layer is followed by relu (none of them is the
        # network's final layer)
        for l in layers[:-2]:
            x = jax.nn.relu(x @ l.w + l.b)
        return x

    return prologue, (layers[-2].w, layers[-2].b, layers[-1].w, layers[-1].b)


def _dense_weights(mlp: MLP):
    """Cloud-independent part of the gather_mlp lowering: the two-layer
    weights (plus the optional jnp prologue).  The kernel requires >= 1
    center lane; on the prologue path the raw tensor gets a zero lane
    prepended (see :func:`_dense_raw_ctr`), mirrored here by a zero row
    in W1 so the in-kernel subtract is a no-op."""
    prologue, (w1, b1, w2, b2) = two_layer_form(mlp)
    if prologue is not None:
        w1 = jnp.concatenate([jnp.zeros((1, w1.shape[1]), w1.dtype), w1],
                             axis=0)
    return prologue, (w1, b1, w2, b2)


def _dense_raw_ctr(prologue, kind, xyz, feats, nbr_idx, centers_xyz,
                   center_feats, nbr_valid):
    """Per-cloud prep of the gather_mlp data operands.  -> (raw, ctr);
    the batched dense entry vmaps exactly this (the weight lowering,
    :func:`_dense_weights`, is cloud-independent and hoisted out)."""
    ids = nbr_idx if nbr_valid is None else jnp.where(nbr_valid, nbr_idx, 0)
    if prologue is None:
        if kind == "sa":
            # kernel computes [xyz_j − c, f_j]: raw carries the gathered
            # lanes, the center is subtracted from the leading 3 in-kernel
            raw = jnp.concatenate([xyz[ids], feats[ids]], axis=-1)
            ctr = centers_xyz
        else:
            # edge input is [f_j − c, c]: write it as a subtract over all
            # 2F lanes of [f_j, 0] with the center vector [c, −c]
            fj = feats[ids]
            raw = jnp.concatenate([fj, jnp.zeros_like(fj)], axis=-1)
            cv = center_feats
            ctr = jnp.concatenate([cv, -cv], axis=-1)
    else:
        x = prologue(_subset_inputs(kind, xyz, feats, ids, centers_xyz,
                                    center_feats))
        # zero center lane (the W1 zero row is added in _dense_weights)
        raw = jnp.concatenate(
            [jnp.zeros(x.shape[:-1] + (1,), x.dtype), x], axis=-1)
        ctr = jnp.zeros((raw.shape[0], 1), raw.dtype)
    return raw, ctr


def _dense_pallas(mlp: MLP, kind, xyz, feats, nbr_idx, centers_xyz,
                  center_feats=None, nbr_valid=None):
    """Dense FC through the fused gather_mlp kernel.  -> (S, Fout).
    ``nbr_valid`` (S, K) masks ragged -1 slots inside the kernel's
    max-pool (empty subsets come back zero-filled)."""
    prologue, (w1, b1, w2, b2) = _dense_weights(mlp)
    raw, ctr = _dense_raw_ctr(prologue, kind, xyz, feats, nbr_idx,
                              centers_xyz, center_feats, nbr_valid)
    return gather_mlp(raw, ctr, w1, b1, w2, b2, mask=nbr_valid)


def _reuse_pallas(mlp: MLP, pool_in, slot, comp, live=None):
    """Reuse dataflow through the hub_reuse kernel.  -> (H, M, Fout)."""
    prologue, (w1, b1, w2, b2) = two_layer_form(mlp)
    x = pool_in if prologue is None else prologue(pool_in)
    return hub_reuse(x, slot, comp, w1, b1, w2, b2, live=live)


def _kernel_kw(kernel_kw, *names):
    kw = dict(kernel_kw or {})
    return {k: kw[k] for k in names if kw.get(k) is not None}


def _dense_pallas_batched(mlp: MLP, kind, xyz, feats, nbr_idx, centers_xyz,
                          center_feats=None, nbr_valid=None,
                          kernel_kw=None):
    """Natively batched dense FC: per-cloud gathers are vmapped (cheap
    VPU work), then ONE gather_mlp pallas_call with grid (B, ⌈S/TS⌉)
    covers the whole cloud stack.  -> (B, S, Fout)."""
    prologue, (w1, b1, w2, b2) = _dense_weights(mlp)
    raw, ctr = jax.vmap(
        lambda x, f, n, c, cf, nv: _dense_raw_ctr(
            prologue, kind, x, f, n, c, cf, nv),
        in_axes=(0, 0, 0, 0, None if center_feats is None else 0,
                 None if nbr_valid is None else 0),
    )(xyz, feats, nbr_idx, centers_xyz, center_feats, nbr_valid)
    return gather_mlp_batched(raw, ctr, w1, b1, w2, b2, mask=nbr_valid,
                              **_kernel_kw(kernel_kw, "ts",
                                           "vmem_budget_mb", "lanes",
                                           "dimension_semantics"))


def _reuse_pallas_batched(mlp: MLP, pool_in, slot, comp, live=None,
                          kernel_kw=None):
    """Natively batched reuse FC: ONE hub_reuse pallas_call with grid
    (B, ⌈H/TH⌉) covers the whole cloud stack.  -> (B, H, M, Fout)."""
    prologue, (w1, b1, w2, b2) = two_layer_form(mlp)
    x = pool_in if prologue is None else prologue(pool_in)
    return hub_reuse_batched(x, slot, comp, w1, b1, w2, b2, live=live,
                             **_kernel_kw(kernel_kw, "th",
                                          "vmem_budget_mb", "lanes",
                                          "dimension_semantics"))


FC_BACKENDS.register("pallas", FCBackend(
    name="pallas", dense=_dense_pallas, reuse=_reuse_pallas,
    dense_batched=_dense_pallas_batched,
    reuse_batched=_reuse_pallas_batched))

# the pre-batching behavior of the "pallas" entry — per-cloud kernels
# under jax.vmap — stays available for A/B measurement (benchmarks/run.py
# times it against the batched grid on identical inputs)
FC_BACKENDS.register("pallas_vmap", FCBackend(
    name="pallas_vmap", dense=_dense_pallas, reuse=_reuse_pallas))
