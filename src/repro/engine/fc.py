"""The "pallas" FC backend: the building block's two MXU dataflows routed
through the TPU kernels (CPU: interpret mode; TPU: Mosaic-compiled).

  dense  -> kernels/gather_mlp   fused normalize → MLP → max-pool
  reuse  -> kernels/hub_reuse    pool-MLP → one-hot reuse-gather → Δ-comp
                                 → masked max-pool

Both kernels are fixed two-layer (W1, relu, W2) pipelines — the shape of
the paper's systolic FCU.  General point-MLPs are lowered to that form
exactly:

  * ``block_end`` (all layers linear): compose every layer into ONE linear
    map, then embed it as relu(x·[W,−W]+[b,−b])·[I;−I] — exact, because
    relu(a) − relu(−a) = a.
  * ``per_layer`` with 2 layers: direct.
  * ``per_layer`` with 1 layer: the same split-sign embedding.
  * ``per_layer`` with >2 layers: the leading layers run as a jnp prologue
    (they are the cheap narrow layers); the last two — the wide ones that
    dominate FLOPs — run fused in the kernel.

Registered as ``"pallas"`` in ``repro.core.registry.FC_BACKENDS``; the
pure-jnp oracle is the ``"reference"`` backend in ``core.pipeline``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mlp import MLP
from repro.core.pipeline import FCBackend, _subset_inputs
from repro.core.registry import FC_BACKENDS
from repro.kernels.gather_mlp.ops import gather_mlp
from repro.kernels.hub_reuse.ops import hub_reuse


def _split_sign(w, b):
    """Embed the linear map x·w+b as a relu pair: since
    relu(a) − relu(−a) = a, relu(x·[w,−w]+[b,−b])·[I;−I] is exact."""
    f = w.shape[1]
    eye = jnp.eye(f, dtype=w.dtype)
    w1 = jnp.concatenate([w, -w], axis=1)
    b1 = jnp.concatenate([b, -b], axis=0)
    w2 = jnp.concatenate([eye, -eye], axis=0)
    return w1, b1, w2, jnp.zeros((f,), w.dtype)


def two_layer_form(mlp: MLP):
    """Lower an arbitrary point-MLP to the kernels' fixed
    relu-sandwich form.  Returns (prologue | None, (w1, b1, w2, b2));
    the prologue (if any) is applied with jnp before the kernel call."""
    layers = mlp.layers
    if mlp.activation == "block_end":
        w, b = layers[0].w, layers[0].b
        for l in layers[1:]:
            b = b @ l.w + l.b
            w = w @ l.w
        return None, _split_sign(w, b)
    if len(layers) == 1:
        return None, _split_sign(layers[0].w, layers[0].b)
    if len(layers) == 2:
        return None, (layers[0].w, layers[0].b, layers[1].w, layers[1].b)

    def prologue(x):
        # every prologue layer is followed by relu (none of them is the
        # network's final layer)
        for l in layers[:-2]:
            x = jax.nn.relu(x @ l.w + l.b)
        return x

    return prologue, (layers[-2].w, layers[-2].b, layers[-1].w, layers[-1].b)


def _with_dummy_lane(raw, w1):
    """The kernel requires >= 1 center lane; when normalization already
    happened in a prologue, prepend a zero lane (and a zero row in W1) so
    the in-kernel subtract is a no-op."""
    zeros = jnp.zeros(raw.shape[:-1] + (1,), raw.dtype)
    raw = jnp.concatenate([zeros, raw], axis=-1)
    w1 = jnp.concatenate([jnp.zeros((1, w1.shape[1]), w1.dtype), w1], axis=0)
    ctr = jnp.zeros((raw.shape[0], 1), raw.dtype)
    return raw, ctr, w1


def _dense_pallas(mlp: MLP, kind, xyz, feats, nbr_idx, centers_xyz,
                  center_feats=None, nbr_valid=None):
    """Dense FC through the fused gather_mlp kernel.  -> (S, Fout).
    ``nbr_valid`` (S, K) masks ragged -1 slots inside the kernel's
    max-pool (empty subsets come back zero-filled)."""
    prologue, (w1, b1, w2, b2) = two_layer_form(mlp)
    ids = nbr_idx if nbr_valid is None else jnp.where(nbr_valid, nbr_idx, 0)
    if prologue is None:
        if kind == "sa":
            # kernel computes [xyz_j − c, f_j]: raw carries the gathered
            # lanes, the center is subtracted from the leading 3 in-kernel
            raw = jnp.concatenate([xyz[ids], feats[ids]], axis=-1)
            ctr = centers_xyz
        else:
            # edge input is [f_j − c, c]: write it as a subtract over all
            # 2F lanes of [f_j, 0] with the center vector [c, −c]
            fj = feats[ids]
            raw = jnp.concatenate([fj, jnp.zeros_like(fj)], axis=-1)
            cv = center_feats
            ctr = jnp.concatenate([cv, -cv], axis=-1)
    else:
        x = _subset_inputs(kind, xyz, feats, ids, centers_xyz,
                           center_feats)
        raw, ctr, w1 = _with_dummy_lane(prologue(x), w1)
    return gather_mlp(raw, ctr, w1, b1, w2, b2, mask=nbr_valid)


def _reuse_pallas(mlp: MLP, pool_in, slot, comp, live=None):
    """Reuse dataflow through the hub_reuse kernel.  -> (H, M, Fout)."""
    prologue, (w1, b1, w2, b2) = two_layer_form(mlp)
    x = pool_in if prologue is None else prologue(pool_in)
    return hub_reuse(x, slot, comp, w1, b1, w2, b2, live=live)


FC_BACKENDS.register("pallas", FCBackend(
    name="pallas", dense=_dense_pallas, reuse=_reuse_pallas))
