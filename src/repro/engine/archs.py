"""Architecture families: typed init + single-cloud forward per family.

Each family is registered under the leading token of ``spec.name``
("pointnet2", "dgcnn", "pointnext", "pointvector"); unknown names fall
back to the generic SA-stack family.  Every gather/MLP block routes
through ``core.pipeline.lpcn_block`` — the Islandization Unit plugs into
each architecture uniformly (the paper's "seamlessly integrated" claim) —
and the FC backend, sampler and neighbor method are all registry-resolved.

Forwards operate on ONE cloud; ``engine.apply`` vmaps them over a padded
:class:`~repro.engine.params.Batch`.  The RNG key-split sequences mirror
the legacy ``repro.models`` code exactly, so the compatibility shims are
bit-identical to the old path.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.core.mlp import MLP, apply_mlp, init_mlp
from repro.core.pipeline import BIG as _BIG
from repro.core.pipeline import (LPCNConfig, compute_block_features_batched,
                                 lpcn_block, structure_block)
from repro.core.registry import Registry, get_fc_backend
from repro.core.workload import WorkloadReport

from .params import PCNParams
from .spec import BlockSpec, PCNSpec, arch_of, block_in_dim

ARCHS = Registry("arch")


@dataclass(frozen=True)
class Arch:
    """One architecture family: init(key, spec) -> PCNParams and
    forward(params, spec, xyz, feats, key, ctx, n_valid) ->
    (logits, report).  ``n_valid`` (traced count or None) marks rows
    >= n_valid of the cloud as padding; forwards must mask them out of
    sampling, pooling and per-point (seg) logits.

    ``forward_batched(params, spec, xyz, feats, keys, ctx, n_valid) ->
    logits`` (optional) is the batch-first two-stage forward the serving
    path uses: a vmapped per-cloud DS → octree → islandize → hub-schedule
    stage emits stacked (B, …) structures, then the FC stage runs through
    the backend's batched entry points — one kernel dispatch per FC call
    site for the whole cloud stack.  Families without it fall back to
    ``jax.vmap`` of ``forward``."""
    name: str
    init: callable
    forward: callable
    forward_batched: callable | None = None


@dataclass(frozen=True)
class EngineCtx:
    """Per-call static execution context (lifted out of the traced args).

    ``mesh`` is the engine-level sharding plan (None = the no-mesh fast
    path): when set, the batched forward keeps every stacked (B, …)
    tensor sharded along the mesh's data axes between stages —
    :class:`~repro.engine.params.Batch` leaves on the way in, the
    structure stacks between stage 1 and stage 2, and each block's
    feature tensor on the way out of the FC stage — so the two-stage
    forward splits across devices instead of letting GSPMD replicate at
    a stage boundary.  Params are replicated (point MLPs are tiny).
    """
    mode: str = "lpcn"
    fc_backend: str = "reference"
    isl_kw: tuple = ()            # sorted (key, value) pairs — hashable
    with_report: bool = False
    kernel_kw: tuple = ()         # sorted (key, value) pairs — hashable
    mesh: object = None           # jax.sharding.Mesh | None (hashable)

    KERNEL_KW_KEYS = frozenset({"ts", "th", "vmem_budget_mb", "lanes",
                                "dimension_semantics"})

    @staticmethod
    def make(mode="lpcn", fc_backend="reference", isl_kw=None,
             with_report=False, kernel_kw=None, mesh=None) -> "EngineCtx":
        kernel_kw = dict(kernel_kw or {})
        unknown = set(kernel_kw) - EngineCtx.KERNEL_KW_KEYS
        if unknown:
            raise ValueError(
                f"unknown kernel_kw key(s) {sorted(unknown)}; valid knobs: "
                f"{sorted(EngineCtx.KERNEL_KW_KEYS)} (a typo here would "
                f"silently fall back to the VMEM-budget heuristic)")
        sem = kernel_kw.get("dimension_semantics")
        if sem is not None:
            # JSON/CLI callers pass a list; the ctx must stay hashable and
            # the values must be real Mosaic semantics (K005 territory)
            sem = tuple(sem)
            if len(sem) != 2 or not set(sem) <= {"parallel", "arbitrary"}:
                raise ValueError(
                    f"dimension_semantics must be a pair drawn from "
                    f"('parallel', 'arbitrary'); got {sem!r}")
            kernel_kw["dimension_semantics"] = sem
        if mesh is not None and "data" not in mesh.axis_names:
            raise ValueError(
                f"engine meshes shard the batch along a 'data' axis; got "
                f"axes {tuple(mesh.axis_names)} (build one with "
                f"repro.launch.mesh.data_mesh / make_mesh)")
        return EngineCtx(mode=mode, fc_backend=fc_backend,
                         isl_kw=tuple(sorted((isl_kw or {}).items())),
                         with_report=with_report,
                         kernel_kw=tuple(sorted(kernel_kw.items())),
                         mesh=mesh)


def _maybe_shard(tree, ctx: EngineCtx):
    """Constrain stacked (B, …) leaves along the data axes of
    ``ctx.mesh`` (identity on the no-mesh fast path — repro.dist is not
    even imported)."""
    if ctx.mesh is None:
        return tree
    from repro.dist.sharding import shard_leading
    return shard_leading(tree, ctx.mesh)


def get_arch(spec: PCNSpec) -> Arch:
    name = arch_of(spec)
    return ARCHS.get(name if name in ARCHS else "pointnet2")


def block_cfg(b: BlockSpec, ctx: EngineCtx) -> LPCNConfig:
    return LPCNConfig(n_centers=b.n_centers, k=b.k, sampler=b.sampler,
                      neighbor=b.neighbor, radius=b.radius, mode=ctx.mode,
                      block_kind=b.kind, fc_backend=ctx.fc_backend,
                      **dict(ctx.isl_kw))


def _total(reports):
    if not reports:
        return None
    if len(reports) == 1:
        return reports[0]
    return WorkloadReport.sum_counters(reports)


def feature_propagation(xyz_dst, xyz_src, f_src, k: int = 3,
                        src_n_valid=None):
    """PointNet++ FP layer: inverse-distance 3-NN interpolation of source
    center features onto destination points (segmentation upsampling).
    ``src_n_valid`` masks padding source rows out of the 3-NN (their
    distance is pinned to +inf, so their weight is exactly zero)."""
    d = jnp.sum((xyz_dst[:, None, :] - xyz_src[None, :, :]) ** 2, -1)
    if src_n_valid is not None:
        src_ok = jnp.arange(xyz_src.shape[0])[None, :] < src_n_valid
        d = jnp.where(src_ok, d, jnp.inf)
    neg, idx = jax.lax.top_k(-d, k)
    w = 1.0 / jnp.maximum(-neg, 1e-8)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-12)
    return (f_src[idx] * w[..., None]).sum(axis=1)


def _mask_rows(x, n_valid, fill=0.0):
    """Zero (or ``fill``) rows >= n_valid of a per-point array."""
    if n_valid is None:
        return x
    ok = jnp.arange(x.shape[0]) < n_valid
    return jnp.where(ok[:, None], x, fill)


def _mask_rows_b(x, n_valid, fill=0.0):
    """Batched :func:`_mask_rows`: zero rows >= n_valid[i] of x (B, N, F)."""
    if n_valid is None:
        return x
    ok = jnp.arange(x.shape[1])[None, :] < n_valid[:, None]
    return jnp.where(ok[..., None], x, fill)


def _structure_stack(spec: PCNSpec, ctx: EngineCtx, xyz, key, n_valid):
    """Stage 1 on ONE cloud: the geometric chain of the whole SA block
    stack (DS → octree → islandize → hub-schedule per block — coordinates
    and RNG only, no features).  The key-split sequence mirrors
    :func:`_run_blocks` exactly, so the batched forward is numerically
    identical to vmapping the fused per-cloud path.

    Returns (structures, nv_levels): one :class:`BlockStructure` per
    block and the per-level n_valid chain (downsampling samplers emit
    fully-valid center sets -> None below them; "all" keeps the count)."""
    structs = []
    cur_xyz, cur_nv = xyz, n_valid
    nv_levels = [n_valid]
    for b in spec.blocks:
        key, sub = jax.random.split(key)
        st = structure_block(block_cfg(b, ctx), cur_xyz, sub,
                             n_valid=cur_nv)
        structs.append(st)
        cur_xyz = st.center_xyz
        cur_nv = cur_nv if b.sampler == "all" else None
        nv_levels.append(cur_nv)
    return tuple(structs), tuple(nv_levels)


def _structure_stack_b(spec: PCNSpec, ctx: EngineCtx, xyz, keys, n_valid):
    """Vmapped :func:`_structure_stack`: emits stacked (B, …) structures
    for the batched FC stage."""
    return jax.vmap(
        lambda x, k, nv: _structure_stack(spec, ctx, x, k, nv)
    )(xyz, keys, n_valid)


def _compute_stack_b(params: PCNParams, spec: PCNSpec, ctx: EngineCtx,
                     xyz, feats, structs):
    """Batched stage 2 over an SA block stack: features flow through the
    backend's batched FC entry points block by block (each block's
    output re-constrained to the mesh data axes when ``ctx.mesh`` is
    set).  Returns (xyz_levels, final features)."""
    backend = get_fc_backend(ctx.fc_backend)
    kernel_kw = dict(ctx.kernel_kw)
    cur_xyz, cur_f = xyz, feats
    xyz_levels = [xyz]
    for b, mlp, st in zip(spec.blocks, params.blocks, structs):
        cur_f = compute_block_features_batched(
            block_cfg(b, ctx), mlp, cur_xyz, cur_f, st, backend=backend,
            kernel_kw=kernel_kw, mesh=ctx.mesh)
        cur_xyz = st.center_xyz
        xyz_levels.append(cur_xyz)
    return xyz_levels, cur_f


def _fp_b(xyz_dst, xyz_src, f_src, src_n_valid=None, k: int = 3):
    """Vmapped :func:`feature_propagation` (seg decoder level)."""
    return jax.vmap(
        lambda d, s, f, nv: feature_propagation(d, s, f, k=k,
                                                src_n_valid=nv),
        in_axes=(0, 0, 0, None if src_n_valid is None else 0),
    )(xyz_dst, xyz_src, f_src, src_n_valid)


def _run_blocks(params: PCNParams, spec: PCNSpec, xyz, feats, key,
                ctx: EngineCtx, n_valid=None):
    """SA block stack on one cloud -> (cx, cf, reports, saved).

    ``n_valid`` masks the first block's input padding.  Downsampling
    samplers pick only valid points, so deeper blocks see fully-valid
    center sets; the "all" sampler keeps every row, so padding (and its
    count) propagates unchanged.
    """
    reports, saved = [], []
    cur_xyz, cur_f = xyz, feats
    cur_nv = n_valid
    nv_levels = [n_valid]
    for b, mlp in zip(spec.blocks, params.blocks):
        key, sub = jax.random.split(key)
        out = lpcn_block(block_cfg(b, ctx), mlp, cur_xyz, cur_f, sub,
                         with_report=ctx.with_report, n_valid=cur_nv)
        saved.append((cur_xyz, cur_f, out))
        cur_xyz, cur_f = out.center_xyz, out.features
        cur_nv = cur_nv if b.sampler == "all" else None
        nv_levels.append(cur_nv)
        if ctx.with_report and out.report is not None:
            reports.append(out.report)
    return cur_xyz, cur_f, reports, saved, nv_levels


def _global_pool(params: PCNParams, center_xyz, center_f, n_valid=None):
    """Final global SA: one subset containing every remaining center —
    the paper's example of a no-overlap layer (processed traditionally).
    ``n_valid`` masks padding centers (possible when every block uses the
    "all" sampler) out of the centroid and the global max."""
    if params.global_mlp is None:
        return _mask_rows(center_f, n_valid, fill=-_BIG).max(axis=0)
    if n_valid is None:
        centroid = center_xyz.mean(axis=0)
    else:
        ok = (jnp.arange(center_xyz.shape[0]) < n_valid)[:, None]
        centroid = jnp.where(ok, center_xyz, 0.0).sum(axis=0) \
            / jnp.maximum(n_valid, 1)
    x = jnp.concatenate([center_xyz - centroid, center_f], axis=-1)
    return _mask_rows(apply_mlp(params.global_mlp, x), n_valid,
                      fill=-_BIG).max(axis=0)


# ---- generic SA stack (PointNet++ and ad-hoc specs) -------------------------

def _init_pointnet2(key, spec: PCNSpec) -> PCNParams:
    blocks = []
    f = spec.in_feats
    for b in spec.blocks:
        key, sub = jax.random.split(key)
        dims = [block_in_dim(b.kind, f), *b.mlp_dims]
        blocks.append(init_mlp(sub, dims, spec.activation))
        f = b.mlp_dims[-1]
    global_mlp = None
    if spec.task == "cls":
        key, sub = jax.random.split(key)
        if spec.global_mlp:
            global_mlp = init_mlp(sub, [3 + f, *spec.global_mlp],
                                  spec.activation)
            f = spec.global_mlp[-1]
    key, sub = jax.random.split(key)
    head = init_mlp(sub, [f, *spec.head_dims, spec.n_classes], "per_layer")
    return PCNParams(blocks=tuple(blocks), head=head, global_mlp=global_mlp)


def _fwd_pointnet2(params: PCNParams, spec: PCNSpec, xyz, feats, key,
                   ctx: EngineCtx, n_valid=None):
    cx, cf, reports, saved, nv_levels = _run_blocks(params, spec, xyz,
                                                    feats, key, ctx, n_valid)
    if spec.task == "cls":
        g = _global_pool(params, cx, cf, n_valid=nv_levels[-1])
        return apply_mlp(params.head, g), _total(reports)
    # segmentation: FP decoder back up the saved pyramid
    f = cf
    xyz_levels = [s[0] for s in saved] + [cx]
    for lvl in range(len(saved) - 1, -1, -1):
        f = feature_propagation(xyz_levels[lvl], xyz_levels[lvl + 1], f,
                                src_n_valid=nv_levels[lvl + 1])
    # per-point logits of padding rows are zeroed (ragged contract)
    return _mask_rows(apply_mlp(params.head, f), n_valid), _total(reports)


def _fwd_pointnet2_batched(params: PCNParams, spec: PCNSpec, xyz, feats,
                           keys, ctx: EngineCtx, n_valid=None):
    """Two-stage batched forward: vmapped geometry stack, then batched FC
    + head.  Numerically identical to vmapping :func:`_fwd_pointnet2`."""
    structs, nv_levels = _maybe_shard(
        _structure_stack_b(spec, ctx, xyz, keys, n_valid), ctx)
    xyz_levels, cf = _compute_stack_b(params, spec, ctx, xyz, feats,
                                      structs)
    if spec.task == "cls":
        nv = nv_levels[-1]
        g = jax.vmap(
            lambda c, f, v: _global_pool(params, c, f, n_valid=v),
            in_axes=(0, 0, None if nv is None else 0),
        )(xyz_levels[-1], cf, nv)
        return apply_mlp(params.head, g)
    f = cf
    for lvl in range(len(spec.blocks) - 1, -1, -1):
        f = _fp_b(xyz_levels[lvl], xyz_levels[lvl + 1], f,
                  nv_levels[lvl + 1])
    return _mask_rows_b(apply_mlp(params.head, f), n_valid)


ARCHS.register("pointnet2", Arch("pointnet2", _init_pointnet2,
                                 _fwd_pointnet2,
                                 _fwd_pointnet2_batched))


# ---- DGCNN (EdgeConv; every point a center) ---------------------------------

def _init_dgcnn(key, spec: PCNSpec) -> PCNParams:
    # head input is the concat of every EdgeConv output (cls) or that plus
    # a broadcast global vector (seg) — rebuild the head accordingly
    p = _init_pointnet2(key, spec)
    cat_dim = sum(b.mlp_dims[-1] for b in spec.blocks)
    head_in = cat_dim if spec.task == "cls" else 2 * cat_dim
    key, sub = jax.random.split(key)
    head = init_mlp(sub, [head_in, *spec.head_dims, spec.n_classes],
                    "per_layer")
    return PCNParams(blocks=p.blocks, head=head, global_mlp=None)


def _fwd_dgcnn(params: PCNParams, spec: PCNSpec, xyz, feats, key,
               ctx: EngineCtx, n_valid=None):
    """EdgeConv stack; every layer keeps all N points (no downsampling).
    Padding rows stay in every layer (static shapes) but are excluded
    from neighbor sets, islands and the global max-pool."""
    reports, per_layer = [], []
    f = feats
    for b, mlp in zip(spec.blocks, params.blocks):
        key, sub = jax.random.split(key)
        out = lpcn_block(block_cfg(b, ctx), mlp, xyz, f, sub,
                         with_report=ctx.with_report, n_valid=n_valid)
        f = out.features
        per_layer.append(f)
        if ctx.with_report and out.report is not None:
            reports.append(out.report)
    cat = jnp.concatenate(per_layer, axis=-1)
    gmax = _mask_rows(cat, n_valid, fill=-_BIG).max(axis=0)
    if spec.task == "cls":
        return apply_mlp(params.head, gmax), _total(reports)
    per_point = jnp.concatenate(
        [cat, jnp.broadcast_to(gmax[None], cat.shape[:1] + gmax.shape)],
        axis=-1)
    return _mask_rows(apply_mlp(params.head, per_point), n_valid), \
        _total(reports)


def _structure_dgcnn(spec: PCNSpec, ctx: EngineCtx, xyz, key, n_valid):
    """Stage 1 on ONE cloud for the EdgeConv stack: every block structures
    the SAME cloud (no downsampling); key splits mirror
    :func:`_fwd_dgcnn`."""
    structs = []
    for b in spec.blocks:
        key, sub = jax.random.split(key)
        structs.append(structure_block(block_cfg(b, ctx), xyz, sub,
                                       n_valid=n_valid))
    return tuple(structs)


def _fwd_dgcnn_batched(params: PCNParams, spec: PCNSpec, xyz, feats, keys,
                       ctx: EngineCtx, n_valid=None):
    """Two-stage batched EdgeConv forward (see :func:`_fwd_dgcnn`)."""
    structs = _maybe_shard(jax.vmap(
        lambda x, k, nv: _structure_dgcnn(spec, ctx, x, k, nv)
    )(xyz, keys, n_valid), ctx)
    backend = get_fc_backend(ctx.fc_backend)
    kernel_kw = dict(ctx.kernel_kw)
    f, per_layer = feats, []
    for b, mlp, st in zip(spec.blocks, params.blocks, structs):
        f = compute_block_features_batched(block_cfg(b, ctx), mlp, xyz, f,
                                           st, backend=backend,
                                           kernel_kw=kernel_kw,
                                           mesh=ctx.mesh)
        per_layer.append(f)
    cat = jnp.concatenate(per_layer, axis=-1)
    gmax = _mask_rows_b(cat, n_valid, fill=-_BIG).max(axis=1)
    if spec.task == "cls":
        return apply_mlp(params.head, gmax)
    per_point = jnp.concatenate(
        [cat, jnp.broadcast_to(gmax[:, None],
                               cat.shape[:2] + gmax.shape[-1:])], axis=-1)
    return _mask_rows_b(apply_mlp(params.head, per_point), n_valid)


ARCHS.register("dgcnn", Arch("dgcnn", _init_dgcnn, _fwd_dgcnn,
                             _fwd_dgcnn_batched))


# ---- PointNeXt (stem + SA stages with InvResMLP residuals) ------------------

def _init_pointnext(key, spec: PCNSpec, stem_dim: int = 32) -> PCNParams:
    key, sub = jax.random.split(key)
    stem = init_mlp(sub, [spec.in_feats, stem_dim], "per_layer")
    blocks, extras = [], []
    f = stem_dim
    for b in spec.blocks:
        key, s1, s2 = jax.random.split(key, 3)
        blocks.append(init_mlp(s1, [3 + f, *b.mlp_dims], spec.activation))
        f = b.mlp_dims[-1]
        # InvResMLP: pointwise expansion x4 + projection, residual
        extras.append(init_mlp(s2, [f, 4 * f, f], "per_layer"))
    key, sub = jax.random.split(key)
    head = init_mlp(sub, [f, *spec.head_dims, spec.n_classes], "per_layer")
    return PCNParams(blocks=tuple(blocks), head=head, stem=stem,
                     extras=tuple(extras))


def _fwd_stem_stack(params, spec, xyz, feats, key, ctx, combine,
                    n_valid=None):
    """Shared stem + SA stack + FP decoder used by PointNeXt/PointVector;
    ``combine(extra_mlp, block_features)`` is the per-stage residual."""
    reports = []
    f = apply_mlp(params.stem, feats)
    cur_xyz = xyz
    cur_nv = n_valid
    xyz_levels = [xyz]
    nv_levels = [n_valid]
    for b, mlp, extra in zip(spec.blocks, params.blocks, params.extras):
        key, sub = jax.random.split(key)
        out = lpcn_block(block_cfg(b, ctx), mlp, cur_xyz, f, sub,
                         with_report=ctx.with_report, n_valid=cur_nv)
        f = combine(extra, out.features)
        cur_xyz = out.center_xyz
        cur_nv = cur_nv if b.sampler == "all" else None
        xyz_levels.append(cur_xyz)
        nv_levels.append(cur_nv)
        if ctx.with_report and out.report is not None:
            reports.append(out.report)
    for lvl in range(len(spec.blocks) - 1, -1, -1):
        f = feature_propagation(xyz_levels[lvl], xyz_levels[lvl + 1], f,
                                src_n_valid=nv_levels[lvl + 1])
    # per-point logits of padding rows are zeroed (ragged contract)
    return _mask_rows(apply_mlp(params.head, f), n_valid), _total(reports)


def _fwd_stem_stack_batched(params, spec, xyz, feats, keys, ctx, combine,
                            n_valid=None):
    """Two-stage batched :func:`_fwd_stem_stack` (PointNeXt/PointVector):
    vmapped geometry stack, batched stem/FC/residuals, vmapped FP
    decoder."""
    structs, nv_levels = _maybe_shard(
        _structure_stack_b(spec, ctx, xyz, keys, n_valid), ctx)
    backend = get_fc_backend(ctx.fc_backend)
    kernel_kw = dict(ctx.kernel_kw)
    f = apply_mlp(params.stem, feats)
    cur_xyz = xyz
    xyz_levels = [xyz]
    for b, mlp, extra, st in zip(spec.blocks, params.blocks, params.extras,
                                 structs):
        h = compute_block_features_batched(block_cfg(b, ctx), mlp, cur_xyz,
                                           f, st, backend=backend,
                                           kernel_kw=kernel_kw,
                                           mesh=ctx.mesh)
        f = combine(extra, h)
        cur_xyz = st.center_xyz
        xyz_levels.append(cur_xyz)
    for lvl in range(len(spec.blocks) - 1, -1, -1):
        f = _fp_b(xyz_levels[lvl], xyz_levels[lvl + 1], f,
                  nv_levels[lvl + 1])
    return _mask_rows_b(apply_mlp(params.head, f), n_valid)


def _fwd_pointnext(params, spec, xyz, feats, key, ctx, n_valid=None):
    return _fwd_stem_stack(params, spec, xyz, feats, key, ctx,
                           lambda inv, h: h + apply_mlp(inv, h),
                           n_valid=n_valid)


def _fwd_pointnext_batched(params, spec, xyz, feats, keys, ctx,
                           n_valid=None):
    return _fwd_stem_stack_batched(params, spec, xyz, feats, keys, ctx,
                                   lambda inv, h: h + apply_mlp(inv, h),
                                   n_valid=n_valid)


ARCHS.register("pointnext", Arch("pointnext", _init_pointnext,
                                 _fwd_pointnext,
                                 _fwd_pointnext_batched))


# ---- PointVector (stem + SA stages with vector recombination) ---------------

def _init_pointvector(key, spec: PCNSpec, stem_dim: int = 64) -> PCNParams:
    key, sub = jax.random.split(key)
    stem = init_mlp(sub, [spec.in_feats, stem_dim], "per_layer")
    blocks, extras = [], []
    f = stem_dim
    for b in spec.blocks:
        key, s1, s2 = jax.random.split(key, 3)
        blocks.append(init_mlp(s1, [3 + f, *b.mlp_dims], spec.activation))
        f = b.mlp_dims[-1]
        # vector branch: per-center linear recombination post-pooling
        extras.append(init_mlp(s2, [f, f], "per_layer"))
    key, sub = jax.random.split(key)
    head = init_mlp(sub, [f, *spec.head_dims, spec.n_classes], "per_layer")
    return PCNParams(blocks=tuple(blocks), head=head, stem=stem,
                     extras=tuple(extras))


def _fwd_pointvector(params, spec, xyz, feats, key, ctx, n_valid=None):
    return _fwd_stem_stack(params, spec, xyz, feats, key, ctx,
                           lambda vec, h: jax.nn.relu(apply_mlp(vec, h)),
                           n_valid=n_valid)


def _fwd_pointvector_batched(params, spec, xyz, feats, keys, ctx,
                             n_valid=None):
    return _fwd_stem_stack_batched(
        params, spec, xyz, feats, keys, ctx,
        lambda vec, h: jax.nn.relu(apply_mlp(vec, h)), n_valid=n_valid)


ARCHS.register("pointvector", Arch("pointvector", _init_pointvector,
                                   _fwd_pointvector,
                                   _fwd_pointvector_batched))
