"""The engine: one jit-able entry point from a cloud batch to logits.

Functional API (module-level, used with ``jax.jit``/``partial``):

    from functools import partial
    import jax
    from repro import engine
    from repro.models.pointnet2 import POINTNET2_C

    params = engine.init(jax.random.PRNGKey(0), POINTNET2_C)
    run = jax.jit(partial(engine.apply, spec=POINTNET2_C, mode="lpcn",
                          fc_backend="pallas"))
    logits = run(params, xyz_batch)          # (B, N, 3) -> (B, 40)

``spec``/``mode``/``fc_backend`` are static (closed over), so ONE compiled
executable serves every batch of the same shape — the serving path.  The
object API wraps the same functions with a cached jit per engine:

    eng = engine.PCNEngine(POINTNET2_C, mode="lpcn", fc_backend="pallas")
    params = eng.init(jax.random.PRNGKey(0))
    logits = eng.apply(params, batch)

The batched forward runs in two stages: the geometric chain (DS →
islandize → hub-schedule) is vmapped per cloud with per-cloud PRNG keys,
then Feature Computation runs *natively batched* — with the "pallas"
backend, one pallas_call per FC call site covers the whole cloud stack
(the batch is folded into the kernel grid).  ``kernel_kw`` tunes the
kernels' tile sizes / VMEM budget; the "pallas_vmap" backend keeps the
old vmap-of-kernels dispatch for A/B measurement.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import fc as _fc                     # noqa: F401  registers "pallas"
from .archs import EngineCtx, get_arch
from .params import Batch, PCNParams, as_batch, from_legacy
from .spec import PCNSpec


def init(key: jax.Array, spec: PCNSpec) -> PCNParams:
    """Initialize typed params for ``spec`` (arch-dispatched)."""
    return get_arch(spec).init(key, spec)


def apply_single(params, xyz, feats, key, *, spec: PCNSpec,
                 mode: str = "lpcn", fc_backend: str = "reference",
                 isl_kw: dict | None = None, with_report: bool = False,
                 n_valid=None):
    """One cloud (N, 3)/(N, F) -> (logits, WorkloadReport | None).

    cls: (n_classes,) logits.  seg: (N, n_classes) per-point logits.
    Accepts legacy param dicts as well as :class:`PCNParams`.

    ``n_valid`` (traced count or None) marks rows >= n_valid as padding:
    they are never sampled, gathered or pooled, and seg logits of padding
    rows come back zeroed — the output over the first ``n_valid`` rows
    equals running the unpadded (n_valid, ·) cloud.
    """
    params = from_legacy(params)
    ctx = EngineCtx.make(mode=mode, fc_backend=fc_backend, isl_kw=isl_kw,
                         with_report=with_report)
    return get_arch(spec).forward(params, spec, xyz, feats, key, ctx,
                                  n_valid=n_valid)


def apply(params, batch, *, spec: PCNSpec, mode: str = "lpcn",
          fc_backend: str = "reference", isl_kw: dict | None = None,
          kernel_kw: dict | None = None, mesh=None):
    """Padded batch -> logits, fully jit-compiled, batch-first.

    ``batch`` is a :class:`Batch` or a raw (B, N, 3) array.  Returns
    (B, n_classes) for cls specs, (B, N, n_classes) for seg specs.

    The forward runs in two stages: a per-cloud *vmapped* DS → octree →
    islandize → hub-schedule stage emits stacked (B, …) structures, then
    the FC stage presents the whole cloud stack to the backend's batched
    entry points — with ``fc_backend="pallas"`` that is ONE pallas_call
    per FC call site (grid ``(B, ⌈S/TS⌉)`` / ``(B, ⌈H/TH⌉)``), not one
    per cloud.  ``kernel_kw`` (static; e.g. ``{"ts": 32, "th": 2,
    "vmem_budget_mb": 8.0}``) overrides the kernels' VMEM-budget tile
    heuristic; backends without batched entries (``"reference"``,
    ``"pallas_vmap"``) fall back to vmap at the same seam.

    Ragged contract: ``batch.n_valid`` masks padding end to end, so
    ``apply(batch)[i]`` (cls) / ``apply(batch)[i, :n_valid[i]]`` (seg)
    equals :func:`apply_single` on cloud i's unpadded prefix; seg rows
    >= n_valid[i] are zeros.

    ``mesh`` (static; ``jax.sharding.Mesh`` with a ``"data"`` axis, e.g.
    from :func:`repro.launch.mesh.data_mesh`) turns on the sharded
    serving path: ``PCNParams`` are replicated (point MLPs are tiny),
    every batch-first (B, …) tensor — the :class:`Batch` leaves, the
    stacked structures between the two stages, each block's features and
    the logits — is constrained along the mesh's data axes, so the
    whole forward (including the Pallas ``(B, …)`` kernel grids) splits
    across devices.  ``mesh=None`` is the explicit no-mesh fast path:
    bit-identical numerics, and ``repro.dist`` is never even imported.
    """
    params = from_legacy(params)
    b = as_batch(batch)
    # build (and thereby validate kernel_kw + mesh) unconditionally, so a
    # typo'd knob raises even for archs that fall back to the vmap path
    ctx = EngineCtx.make(mode=mode, fc_backend=fc_backend,
                         isl_kw=isl_kw, kernel_kw=kernel_kw, mesh=mesh)
    arch = get_arch(spec)

    def run(params, b):
        if arch.forward_batched is not None:
            return arch.forward_batched(params, spec, b.xyz, b.feats,
                                        b.keys, ctx, b.n_valid)

        def one(xyz, feats, key, nv):
            logits, _ = apply_single(params, xyz, feats, key, spec=spec,
                                     mode=mode, fc_backend=fc_backend,
                                     isl_kw=isl_kw, with_report=False,
                                     n_valid=nv)
            return logits

        return jax.vmap(one)(b.xyz, b.feats, b.keys, b.n_valid)

    if ctx.mesh is None:          # no-mesh fast path
        return run(params, b)
    from repro.dist.sharding import replicate, shard_leading, use_mesh
    # the engine's own constraints pass ctx.mesh explicitly; use_mesh
    # additionally exposes the mesh to registry components and custom
    # FCBackends that call dist.sharding.constrain / active_mesh, the
    # same seam the LM side traces under
    with use_mesh(ctx.mesh):
        out = run(replicate(params, ctx.mesh), shard_leading(b, ctx.mesh))
        return shard_leading(out, ctx.mesh)


def apply_with_reports(params, batch, *, spec: PCNSpec, mode: str = "lpcn",
                       fc_backend: str = "reference",
                       isl_kw: dict | None = None):
    """Like :func:`apply` but also returns the stacked per-cloud
    :class:`WorkloadReport` (counter fields have a leading (B,) axis);
    None in traditional mode.  Padding rows contribute to no counter, so
    the (B,) counters are identical with and without padding."""
    params = from_legacy(params)
    b = as_batch(batch)

    def one(xyz, feats, key, nv):
        return apply_single(params, xyz, feats, key, spec=spec, mode=mode,
                            fc_backend=fc_backend, isl_kw=isl_kw,
                            with_report=(mode != "traditional"),
                            n_valid=nv)

    return jax.vmap(one)(b.xyz, b.feats, b.keys, b.n_valid)


class PCNEngine:
    """A spec bound to an execution configuration, with a cached jit.

    The engine object is the serving handle: construct once, ``init`` (or
    load) params, then ``apply`` on padded batches — recompilation happens
    only when the batch shape changes.  Inputs are normalized through
    :func:`as_batch` / :func:`from_legacy` *before* the cached jit, so
    alternating raw (B, N, 3) arrays, :class:`Batch` objects and legacy
    param dicts of the same shapes reuses one executable.

    ``mesh`` (optional) makes this a *sharded* serving handle: the cached
    jit closes over the mesh, batches are split along its data axes and
    params replicated (see :func:`apply`).  ``mesh=None`` keeps the
    single-device fast path (no ``repro.dist`` import, identical
    numerics).
    """

    def __init__(self, spec: PCNSpec, *, mode: str = "lpcn",
                 fc_backend: str = "reference",
                 isl_kw: dict | None = None,
                 kernel_kw: dict | None = None,
                 mesh=None):
        self.spec = spec
        self.mode = mode
        self.fc_backend = fc_backend
        self.isl_kw = dict(isl_kw or {})
        self.kernel_kw = dict(kernel_kw or {})
        self.mesh = mesh
        # validate the configuration eagerly (a bad mesh / typo'd knob
        # should fail at construction, not at the first traffic batch)
        EngineCtx.make(mode=mode, fc_backend=fc_backend, isl_kw=self.isl_kw,
                       kernel_kw=self.kernel_kw, mesh=mesh)
        self._japply = jax.jit(partial(
            apply, spec=spec, mode=mode, fc_backend=fc_backend,
            isl_kw=self.isl_kw, kernel_kw=self.kernel_kw, mesh=mesh))

    def init(self, key: jax.Array) -> PCNParams:
        return init(key, self.spec)

    def apply(self, params, batch) -> jnp.ndarray:
        """Padded batch (Batch or (B, N, 3) array) -> logits."""
        return self._japply(from_legacy(params), as_batch(batch))

    @property
    def compile_count(self) -> int:
        """Number of distinct executables the cached jit has built — one
        per input *shape* ((B, N, F) bucket), since spec/mode/backend
        are static and ``n_valid`` is traced data.  The serving layer's
        compile-once-per-bucket contract is pinned against this."""
        return self._japply._cache_size()

    def bucket_callable(self, params, batch_size: int, n_points: int):
        """Compile (if not already cached) the executable for one
        (batch_size, n_points) bucket shape and return a callable
        ``batch -> logits`` bound to ``params`` — the serving layer's
        per-bucket seam.

        Compilation happens here, on a throwaway batch of the bucket's
        exact shape, so the first traffic batch of that shape hits the
        jit cache instead of absorbing the compile; calling this again
        for the same shape is a cache hit (``compile_count`` is
        unchanged).  Feature width comes from ``spec.in_feats``.
        """
        params = from_legacy(params)
        f = self.spec.in_feats
        rng = np.random.default_rng(0)
        xyz = jnp.asarray(rng.standard_normal((batch_size, n_points, 3)),
                          jnp.float32)
        feats = None if f <= 3 else jnp.concatenate(
            [xyz, jnp.zeros((batch_size, n_points, f - 3), jnp.float32)],
            -1)
        dummy = Batch.make(xyz, feats, key=jax.random.PRNGKey(0))
        self._japply(params, dummy).block_until_ready()
        japply = self._japply
        return lambda batch: japply(params, as_batch(batch))

    def apply_single(self, params, xyz, feats=None, key=None, *,
                     with_report: bool = False, n_valid=None):
        """Eager single-cloud path (keeps the legacy per-cloud contract)."""
        feats = xyz if feats is None else feats
        key = jax.random.PRNGKey(0) if key is None else key
        return apply_single(params, xyz, feats, key, spec=self.spec,
                            mode=self.mode, fc_backend=self.fc_backend,
                            isl_kw=self.isl_kw, with_report=with_report,
                            n_valid=n_valid)

    def __repr__(self):
        m = ("" if self.mesh is None
             else f", mesh={dict(self.mesh.shape)}")
        return (f"PCNEngine({self.spec.name}, mode={self.mode!r}, "
                f"fc_backend={self.fc_backend!r}{m})")
