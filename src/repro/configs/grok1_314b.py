"""grok-1-314b [moe] — 8 experts top-2, every layer [hf:xai-org/grok-1].

8 experts don't divide the 16-way model axis -> expert weights are
TP-sharded on d_ff over `model` (moe_shard="tp"), experts replicated on
that axis (DESIGN.md §5).
"""
from repro.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv=8, head_dim=128, d_ff=32768, vocab=131072,
    act="swiglu", norm="rms", moe_experts=8, moe_top_k=2, moe_every=1,
    moe_d_ff=32768, moe_shard="tp")

REDUCED = ArchConfig(
    name="grok-1-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv=2, head_dim=32, d_ff=256, vocab=512, act="swiglu",
    norm="rms", moe_experts=4, moe_top_k=2, moe_every=1, moe_d_ff=256,
    moe_shard="tp")
