"""olmo-1b [dense] — non-parametric LayerNorm [arXiv:2402.00838]."""
from repro.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b", family="dense", n_layers=16, d_model=2048,
    n_heads=16, n_kv=16, d_ff=8192, vocab=50304,
    act="swiglu", norm="np_ln", rope_theta=10000.0, tie_embed=True)

REDUCED = ArchConfig(
    name="olmo-1b-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv=4, d_ff=256, vocab=512, act="swiglu", norm="np_ln",
    tie_embed=True)
