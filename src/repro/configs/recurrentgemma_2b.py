"""recurrentgemma-2b [hybrid] — RG-LRU + local attn 1:2 [arXiv:2402.19427]."""
from repro.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv=1, head_dim=256, d_ff=7680, vocab=256000,
    act="geglu", norm="rms", tie_embed=True, embed_scale=True,
    mixer_pattern=("rglru", "rglru", "local"), local_window=2048,
    d_rnn=2560)

REDUCED = ArchConfig(
    name="recurrentgemma-2b-smoke", family="hybrid", n_layers=3,
    d_model=128, n_heads=4, n_kv=1, head_dim=32, d_ff=256, vocab=512,
    act="geglu", norm="rms", tie_embed=True, embed_scale=True,
    mixer_pattern=("rglru", "rglru", "local"), local_window=32, d_rnn=128)
