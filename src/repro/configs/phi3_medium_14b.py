"""phi3-medium-14b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219]."""
from repro.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv=10, head_dim=128, d_ff=17920, vocab=100352,
    act="swiglu", norm="rms", rope_theta=10000.0)

REDUCED = ArchConfig(
    name="phi3-medium-14b-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv=1, head_dim=32, d_ff=256, vocab=512,
    act="swiglu", norm="rms")
