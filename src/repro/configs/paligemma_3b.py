"""paligemma-3b [vlm] — SigLIP (stub) + gemma-2b decoder [arXiv:2407.07726].

The SigLIP patch embedder is STUBBED per assignment: input_specs provides
(B, 256, D) precomputed patch embeddings as the bidirectional prefix.
"""
from repro.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm", n_layers=18, d_model=2048,
    n_heads=8, n_kv=1, head_dim=256, d_ff=16384, vocab=257216,
    act="geglu", norm="rms", tie_embed=True, embed_scale=True,
    prefix_tokens=256)

REDUCED = ArchConfig(
    name="paligemma-3b-smoke", family="vlm", n_layers=2, d_model=128,
    n_heads=4, n_kv=1, head_dim=32, d_ff=256, vocab=512,
    act="geglu", norm="rms", tie_embed=True, embed_scale=True,
    prefix_tokens=16)
