"""mamba2-2.7b [ssm] — SSD, attention-free [arXiv:2405.21060]."""
from repro.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
    n_heads=1, n_kv=1, d_ff=0, vocab=50280, norm="rms", tie_embed=True,
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_headdim=64, ssd_chunk=64)

REDUCED = ArchConfig(
    name="mamba2-2.7b-smoke", family="ssm", n_layers=2, d_model=128,
    n_heads=1, n_kv=1, d_ff=0, vocab=512, norm="rms", tie_embed=True,
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_headdim=32, ssd_chunk=32)
