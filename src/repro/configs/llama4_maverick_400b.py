"""llama4-maverick-400b-a17b [moe] — 128 routed experts top-1 + shared
expert, MoE every 2nd layer (DESIGN.md §4 config-interpretation note:
all-MoE at d_ff=8192 would be ~774B; interleave-2 + shared matches the
released Maverick at ~398B total / ~17B active).
"""
from repro.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe", n_layers=48,
    d_model=5120, n_heads=40, n_kv=8, head_dim=128, d_ff=8192,
    vocab=202048, act="swiglu", norm="rms", rope_theta=500000.0,
    moe_experts=128, moe_top_k=1, moe_every=2, moe_shared=True,
    moe_d_ff=8192, moe_shard="ep")

REDUCED = ArchConfig(
    name="llama4-maverick-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv=2, head_dim=32, d_ff=256, vocab=512, act="swiglu",
    norm="rms", moe_experts=8, moe_top_k=1, moe_every=2, moe_shared=True,
    moe_d_ff=256, moe_scheme="scatter")
