"""Architecture registry + the assigned input-shape sets.

40 cells = 10 archs x 4 shapes.  ``long_500k`` needs sub-quadratic
attention: it runs for ssm/hybrid archs and is SKIPPED (with a note) for
pure full-attention archs (DESIGN.md §4).  Encoder-only archs would skip
decode shapes; none of the 10 is encoder-only.
"""
from __future__ import annotations

from dataclasses import dataclass
from importlib import import_module

_ARCH_MODULES = {
    "phi3-medium-14b": "phi3_medium_14b",
    "olmo-1b": "olmo_1b",
    "gemma-7b": "gemma_7b",
    "qwen2-72b": "qwen2_72b",
    "mamba2-2.7b": "mamba2_2p7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "paligemma-3b": "paligemma_3b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "grok-1-314b": "grok1_314b",
    "whisper-large-v3": "whisper_large_v3",
}

ARCH_IDS = list(_ARCH_MODULES)


def get_config(arch: str, reduced: bool = False):
    mod = import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.REDUCED if reduced else mod.CONFIG


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs with sub-quadratic sequence mixing (long_500k runs only for these)
SUBQUADRATIC = {"mamba2-2.7b", "recurrentgemma-2b"}


def cells():
    """All (arch, shape) cells with skip annotations."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPES.values():
            skip = (s.name == "long_500k" and a not in SUBQUADRATIC)
            out.append((a, s.name,
                        "full-attention arch: 500k KV/scores infeasible, "
                        "sub-quadratic attention required (DESIGN.md 4)"
                        if skip else None))
    return out
