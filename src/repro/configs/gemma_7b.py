"""gemma-7b [dense] — GeGLU, head_dim=256 [arXiv:2403.08295]."""
from repro.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b", family="dense", n_layers=28, d_model=3072,
    n_heads=16, n_kv=16, head_dim=256, d_ff=24576, vocab=256000,
    act="geglu", norm="rms", rope_theta=10000.0, tie_embed=True,
    embed_scale=True)

REDUCED = ArchConfig(
    name="gemma-7b-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv=4, head_dim=64, d_ff=384, vocab=512,
    act="geglu", norm="rms", tie_embed=True, embed_scale=True)
