"""qwen2-72b [dense] — GQA kv=8, QKV bias [arXiv:2407.10671]."""
from repro.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv=8, head_dim=128, d_ff=29568, vocab=152064,
    act="swiglu", norm="rms", qkv_bias=True, rope_theta=1e6)

REDUCED = ArchConfig(
    name="qwen2-72b-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=8, n_kv=2, head_dim=16, d_ff=256, vocab=512,
    act="swiglu", norm="rms", qkv_bias=True)
