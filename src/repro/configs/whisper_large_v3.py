"""whisper-large-v3 [audio] — enc-dec, conv frontend STUB [arXiv:2212.04356].

32 encoder + 32 decoder layers (the spec's "32L" is per stack, matching
the released model).  input_specs provides (B, 1500, D) precomputed frame
embeddings (mel+conv frontend stubbed per assignment).
"""
from repro.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio", n_layers=32, d_model=1280,
    n_heads=20, n_kv=20, head_dim=64, d_ff=5120, vocab=51866,
    act="gelu", norm="ln", qkv_bias=True, tie_embed=True,
    enc_layers=32, enc_seq=1500)

REDUCED = ArchConfig(
    name="whisper-large-v3-smoke", family="audio", n_layers=2,
    d_model=128, n_heads=4, n_kv=4, head_dim=32, d_ff=256, vocab=512,
    act="gelu", norm="ln", qkv_bias=True, tie_embed=True,
    enc_layers=2, enc_seq=64)
