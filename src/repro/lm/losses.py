"""Loss functions (vocab-sharding friendly)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """logits (..., V) any float dtype, labels (...) int32 -> scalar mean
    NLL over unmasked positions.  Stable: f32 max-sub logsumexp (GSPMD
    turns the vocab reductions into partial+all-reduce when logits are
    vocab-sharded)."""
    lg = logits.astype(jnp.float32)
    m = jnp.max(lg, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1))
    picked = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
