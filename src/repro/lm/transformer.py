"""Generic decoder-only LM covering 9 of the 10 assigned architectures
(dense / MoE / SSM / hybrid / VLM-prefix); whisper.py adds the enc-dec
audio arch on the same primitives.

Params are a dict:
  embed (V, D), final_norm {...}, lm_head (D, V) (absent if tied),
  layers: list of per-layer dicts {"norm1", "mixer", "norm2"?, "ffn"?}.

Execution is unrolled over the layer list (the dry-run needs per-layer HLO
for honest cost analysis — lax.scan bodies are counted once by XLA cost
analysis, verified empirically).  `remat` wraps each layer in
jax.checkpoint for training.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.nn import attention as attn
from repro.nn import layers as nnl
from repro.nn import moe as nnmoe
from repro.nn import rglru as nnr
from repro.nn import ssm as nnssm
from repro.dist.sharding import constrain
from .config import ArchConfig


def _dt(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ArchConfig, i: int) -> dict:
    dt = _dt(cfg)
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    mixer_kind = cfg.mixer_of(i)
    p = {"norm1": nnl.norm_params(cfg.norm, d, dt)}
    if mixer_kind in ("attn", "local"):
        p["mixer"] = attn.attn_params(k1, d, cfg.n_heads, cfg.n_kv,
                                      cfg.hd, cfg.qkv_bias, dt)
    elif mixer_kind == "ssd":
        p["mixer"] = nnssm.ssd_params(k1, d, cfg.ssm_state, cfg.ssm_conv,
                                      cfg.ssm_expand, cfg.ssm_headdim, dt)
    elif mixer_kind == "rglru":
        p["mixer"] = nnr.rglru_params(k1, d, cfg.d_rnn or d,
                                      cfg.ssm_conv, dt)
    else:
        raise ValueError(mixer_kind)
    ffn_kind = cfg.ffn_of(i)
    if ffn_kind != "none":
        p["norm2"] = nnl.norm_params(cfg.norm, d, dt)
        if ffn_kind == "mlp":
            p["ffn"] = nnl.mlp_params(k2, d, cfg.d_ff, cfg.act, dt)
        else:
            p["ffn"] = nnmoe.moe_params(k2, d, cfg.moe_d_ff or cfg.d_ff,
                                        cfg.moe_experts, cfg.act, dt,
                                        shared=cfg.moe_shared)
    return p


def init_params(key, cfg: ArchConfig) -> dict:
    dt = _dt(cfg)
    keys = jax.random.split(key, cfg.n_layers + 3)
    params = {
        "embed": nnl.embed_init(keys[0], (cfg.vocab, cfg.d_model), dt),
        "final_norm": nnl.norm_params(cfg.norm, cfg.d_model, dt),
        "layers": [init_layer(keys[2 + i], cfg, i)
                   for i in range(cfg.n_layers)],
    }
    if not cfg.tie_embed:
        params["lm_head"] = nnl.lecun(keys[1], (cfg.d_model, cfg.vocab), dt)
    return params


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def apply_layer(cfg: ArchConfig, i: int, p: dict, x, positions,
                prefix_len: int = 0):
    """Full-sequence (train/prefill) layer.  Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    mixer_kind = cfg.mixer_of(i)
    h = nnl.apply_norm(cfg.norm, x, p["norm1"])
    if mixer_kind == "attn":
        m = attn.causal_attention(p["mixer"], h, cfg.n_heads, cfg.n_kv,
                                  cfg.hd, positions, cfg.rope_theta,
                                  cfg.logits_softcap, prefix_len)
    elif mixer_kind == "local":
        m = attn.local_attention(p["mixer"], h, cfg.n_heads, cfg.n_kv,
                                 cfg.hd, positions, cfg.rope_theta,
                                 cfg.local_window)
    elif mixer_kind == "ssd":
        m = nnssm.ssd_apply(p["mixer"], h, cfg.ssm_state, cfg.ssm_expand,
                            cfg.ssm_headdim, cfg.ssd_chunk)
    elif mixer_kind == "rglru":
        m = nnr.rglru_apply(p["mixer"], h)
    x = x + m
    if "ffn" in p:
        h = nnl.apply_norm(cfg.norm, x, p["norm2"])
        if cfg.ffn_of(i) == "moe":
            y, aux = nnmoe.moe_apply(p["ffn"], h, cfg.moe_experts,
                                     cfg.moe_top_k, cfg.act,
                                     cfg.capacity_factor, cfg.moe_scheme,
                                     cfg.moe_shard)
        else:
            y = nnl.mlp_apply(p["ffn"], h, cfg.act)
        x = x + y
    return x, aux


def forward(cfg: ArchConfig, params: dict, tokens=None, embeds=None,
            prefix_embeds=None, head_last_only: bool = False):
    """Full-sequence forward.  tokens (B, S) int32 and/or prefix_embeds
    (B, P, D) prepended (VLM).  Returns (logits (B, T, V), aux).
    ``head_last_only``: inference prefill — project only the final
    position (avoids materializing (B, S, V) logits)."""
    assert tokens is not None or embeds is not None
    if embeds is None:
        x = params["embed"][tokens]
    else:
        x = embeds
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    prefix_len = 0
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        prefix_len = prefix_embeds.shape[1]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    aux_total = jnp.float32(0.0)
    x = constrain(x, "dp", "sp" if cfg.seq_shard_blocks else None, None)
    for i, lp in enumerate(params["layers"]):
        f = functools.partial(apply_layer, cfg, i, prefix_len=prefix_len)
        if cfg.remat:
            f = jax.checkpoint(f)   # prefix_len bound statically above
        x, aux = f(lp, x, positions)
        x = constrain(x, "dp", "sp" if cfg.seq_shard_blocks else None, None)
        aux_total = aux_total + aux
    x = nnl.apply_norm(cfg.norm, x, params["final_norm"])
    if head_last_only:
        x = x[:, -1:, :]
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = x @ head
    return logits, aux_total


# ---------------------------------------------------------------------------
# decode (one token against caches)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, cache_len: int) -> list:
    """Per-layer decode caches (dtype = model dtype, f32 recurrent
    states)."""
    dt = _dt(cfg)
    caches = []
    for i in range(cfg.n_layers):
        kind = cfg.mixer_of(i)
        if kind in ("attn", "local"):
            w = min(cfg.local_window, cache_len) if kind == "local" \
                else cache_len
            shape = (batch, w, cfg.n_kv, cfg.hd)
            if cfg.kv_quant:
                caches.append({
                    "k": jnp.zeros(shape, jnp.int8),
                    "v": jnp.zeros(shape, jnp.int8),
                    "ks": jnp.zeros(shape[:3], jnp.float32),
                    "vs": jnp.zeros(shape[:3], jnp.float32)})
            else:
                caches.append({"k": jnp.zeros(shape, dt),
                               "v": jnp.zeros(shape, dt)})
        elif kind == "ssd":
            caches.append({
                "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim,
                                    cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((batch, cfg.ssm_conv - 1,
                                   cfg.d_inner + 2 * cfg.ssm_state), dt)})
        elif kind == "rglru":
            dr = cfg.d_rnn or cfg.d_model
            caches.append({
                "state": jnp.zeros((batch, dr), jnp.float32),
                "conv": jnp.zeros((batch, cfg.ssm_conv - 1, dr), dt)})
    return caches


def decode_step(cfg: ArchConfig, params: dict, token, caches: list, pos):
    """token (B,) int32; pos scalar int32 (current position).  Returns
    (logits (B, V), new caches)."""
    x = params["embed"][token][:, None, :]              # (B, 1, D)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    new_caches = []
    for i, (lp, c) in enumerate(zip(params["layers"], caches)):
        kind = cfg.mixer_of(i)
        h = nnl.apply_norm(cfg.norm, x, lp["norm1"])
        if kind in ("attn", "local"):
            window = cfg.local_window if kind == "local" else 0
            if cfg.kv_quant:
                m, nk, nv, nks, nvs = attn.decode_attention(
                    lp["mixer"], h, c["k"], c["v"], pos, cfg.n_heads,
                    cfg.n_kv, cfg.hd, cfg.rope_theta, window=window,
                    softcap=cfg.logits_softcap, k_scale=c["ks"],
                    v_scale=c["vs"])
                new_caches.append({"k": nk, "v": nv, "ks": nks,
                                   "vs": nvs})
            else:
                m, nk, nv = attn.decode_attention(
                    lp["mixer"], h, c["k"], c["v"], pos, cfg.n_heads,
                    cfg.n_kv, cfg.hd, cfg.rope_theta, window=window,
                    softcap=cfg.logits_softcap)
                new_caches.append({"k": nk, "v": nv})
        elif kind == "ssd":
            m, st, cv = nnssm.ssd_decode(lp["mixer"], h, c["state"],
                                         c["conv"], cfg.ssm_state,
                                         cfg.ssm_expand, cfg.ssm_headdim)
            new_caches.append({"state": st, "conv": cv})
        else:  # rglru
            m, st, cv = nnr.rglru_decode(lp["mixer"], h, c["state"],
                                         c["conv"])
            new_caches.append({"state": st, "conv": cv})
        x = x + m
        if "ffn" in lp:
            h = nnl.apply_norm(cfg.norm, x, lp["norm2"])
            if cfg.ffn_of(i) == "moe":
                y, _ = nnmoe.moe_apply(lp["ffn"], h, cfg.moe_experts,
                                       cfg.moe_top_k, cfg.act,
                                       cfg.capacity_factor, cfg.moe_scheme,
                                       cfg.moe_shard)
            else:
                y = nnl.mlp_apply(lp["ffn"], h, cfg.act)
            x = x + y
    x = nnl.apply_norm(cfg.norm, x, params["final_norm"])
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return (x @ head)[:, 0, :], new_caches
