"""Step builders: jit-able train_step / prefill_step / decode_step with
gradient accumulation, AdamW, LR schedule, optional gradient compression.

The returned functions are pure; launch/{train,dryrun}.py bind them to the
mesh via in_shardings/out_shardings.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.optim import adamw
from repro.optim.schedules import warmup_cosine
from . import model_zoo as zoo
from .config import ArchConfig


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    microbatches: int = 1, accum_dtype=jnp.float32,
                    compressor=None, param_shardings=None):
    """-> train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics).

    Gradient accumulation over ``microbatches`` slices of the leading batch
    dim (lax.scan — one microbatch's activations live at a time).
    ``param_shardings``: optional tree of NamedShardings pinning the grad
    accumulator layout (without it GSPMD may replicate the scan carry —
    observed: 15 GB/device temp on a 1.2 B model).
    ``compressor``: optional dist.compress codec applied to accumulated
    grads (error feedback kept in opt_state["ef"] if enabled).
    """
    def loss_of(params, mb):
        loss, aux = zoo.loss_fn(cfg, params, mb)
        return loss, aux

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def pin(tree):
        if param_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            param_shardings)

    def train_step(params, opt_state, batch, step):
        if microbatches == 1:
            (loss, aux), grads = grad_fn(params, batch)
        else:
            def slice_mb(i, t):
                return jax.tree.map(
                    lambda x: x.reshape((microbatches,
                                         x.shape[0] // microbatches)
                                        + x.shape[1:])[i], t)

            def body(carry, i):
                acc, loss_acc, aux_acc = carry
                (l, a), g = grad_fn(params, slice_mb(i, batch))
                acc = pin(jax.tree.map(
                    lambda s, gg: s + gg.astype(accum_dtype), acc, g))
                return (acc, loss_acc + l, aux_acc + a), None

            zeros = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params))
            (gsum, lsum, asum), _ = jax.lax.scan(
                body, (zeros, jnp.float32(0), jnp.float32(0)),
                jnp.arange(microbatches))
            grads = jax.tree.map(
                lambda g: (g / microbatches), gsum)
            loss = lsum / microbatches
            aux = asum / microbatches

        if compressor is not None:
            grads, opt_state = compressor(grads, opt_state)

        lr_scale = warmup_cosine(step)
        params, new_opt = adamw.apply_updates(
            opt_cfg, params, grads, opt_state, lr_scale)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "aux": aux, "grad_norm": gnorm,
                   "lr_scale": lr_scale}
        return params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig):
    """-> prefill_step(params, batch) -> last-token logits (B, V)."""
    def prefill_step(params, batch):
        return zoo.prefill_fn(cfg, params, batch)
    return prefill_step


def make_decode_step(cfg: ArchConfig):
    """-> decode_step(params, token, cache, pos) -> (next_token, logits,
    cache).  Greedy sampling (argmax) — the serving driver adds
    temperature."""
    def decode_step(params, token, cache, pos):
        logits, cache = zoo.decode_fn(cfg, params, token, cache, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, cache
    return decode_step
