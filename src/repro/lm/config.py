"""Architecture config schema for the 10 assigned LM architectures."""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    act: str = "swiglu"              # swiglu | geglu | gelu
    norm: str = "rms"                # rms | np_ln | ln
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embed: bool = False
    embed_scale: bool = False        # gemma-style sqrt(d) embedding scale
    logits_softcap: float = 0.0
    # moe
    moe_experts: int = 0
    moe_top_k: int = 1
    moe_every: int = 1               # layer i is MoE iff (i % every == every-1)
    moe_shared: bool = False
    moe_d_ff: int = 0                # expert FFN width (0 -> d_ff)
    moe_scheme: str = "scatter"      # scatter | dense
    capacity_factor: float = 1.25
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssd_chunk: int = 64
    # hybrid (recurrentgemma): mixer pattern, cycled over layers
    mixer_pattern: tuple = ()        # e.g. ("rglru","rglru","local")
    local_window: int = 2048
    d_rnn: int = 0                   # rglru width (0 -> d_model)
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_seq: int = 0                 # stubbed frontend positions
    # vlm (paligemma)
    prefix_tokens: int = 0           # stubbed image-patch positions
    # numerics / execution
    dtype: str = "bfloat16"
    remat: bool = True
    kv_quant: bool = False           # int8 decode KV cache (§Perf lever)
    # sharding knobs (see dist/sharding.py)
    moe_shard: str = "ep"            # ep | tp  (grok: 8 experts < 16 -> tp)
    seq_shard_blocks: bool = True    # Megatron-SP between blocks
    shard_profile: str = "tp"        # tp | flat_dp (pure-FSDP, no TP)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def mixer_of(self, i: int) -> str:
        if self.family == "ssm":
            return "ssd"
        if self.mixer_pattern:
            return self.mixer_pattern[i % len(self.mixer_pattern)]
        return "attn"

    def ffn_of(self, i: int) -> str:
        if self.family == "ssm":
            return "none"
        if self.moe_experts and (i % self.moe_every == self.moe_every - 1):
            return "moe"
        return "mlp"

    # ---- parameter counting (for roofline MODEL_FLOPS) -------------------
    def param_counts(self) -> dict:
        d, hd = self.d_model, self.hd
        attn = d * hd * (self.n_heads + 2 * self.n_kv) + self.n_heads * hd * d
        n_mats = 3 if self.act in ("swiglu", "geglu") else 2
        mlp = n_mats * d * self.d_ff
        moe_ff = self.moe_d_ff or self.d_ff
        moe = (self.moe_experts * n_mats * d * moe_ff
               + d * self.moe_experts
               + (n_mats * d * moe_ff if self.moe_shared else 0))
        ssd = 0
        if self.family == "ssm":
            di = self.d_inner
            ssd = d * (2 * di + 2 * self.ssm_state + self.ssm_heads) + di * d
        rglru = 0
        if "rglru" in (self.mixer_pattern or ()):
            dr = self.d_rnn or self.d_model
            rglru = 2 * d * dr + 2 * dr * dr + dr * d
        emb = self.vocab * d * (1 if self.tie_embed else 2)
        total = emb
        active = emb
        for i in range(self.n_layers):
            mix = {"attn": attn, "local": attn, "ssd": ssd,
                   "rglru": rglru}[self.mixer_of(i)]
            total += mix
            active += mix
            f = self.ffn_of(i)
            if f == "mlp":
                total += mlp
                active += mlp
            elif f == "moe":
                total += moe
                active += (self.moe_top_k * n_mats * d * moe_ff
                           + d * self.moe_experts
                           + (n_mats * d * moe_ff if self.moe_shared else 0))
        if self.enc_layers:  # whisper encoder (+ its own attn/mlp)
            enc = self.enc_layers * (attn + 2 * d * self.d_ff)
            total += enc
            active += enc
        return {"total": total, "active": active}
