"""Unified entry points across the 4 model families.

    init(key, cfg)                         -> params
    loss_fn(cfg, params, batch)            -> (loss, aux)
    decode_fn(cfg, params, tok, cache, pos)-> (logits, cache)
    make_cache(cfg, params, batch, len)    -> cache
    input_specs(cfg, shape, ...)           -> ShapeDtypeStruct batch

Batches are dicts:  dense/moe/ssm/hybrid: {tokens (B,S+1)};
vlm: {patches (B,P,D), tokens (B,S+1)};  audio: {frames (B,T,D),
tokens (B,S+1)}.  Labels are tokens shifted by one.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import transformer as tfm
from . import whisper as whi
from .config import ArchConfig
from .losses import cross_entropy

AUX_WEIGHT = 0.01


def init(key, cfg: ArchConfig):
    if cfg.family == "audio":
        return whi.init_params(key, cfg)
    return tfm.init_params(key, cfg)


def loss_fn(cfg: ArchConfig, params, batch):
    toks = batch["tokens"]
    inp, lab = toks[:, :-1], toks[:, 1:]
    if cfg.family == "audio":
        logits, aux = whi.forward(cfg, params, batch["frames"], inp)
        return cross_entropy(logits, lab), aux
    if cfg.family == "vlm":
        logits, aux = tfm.forward(cfg, params, tokens=inp,
                                  prefix_embeds=batch["patches"])
        txt_logits = logits[:, cfg.prefix_tokens:]
        return (cross_entropy(txt_logits, lab) + AUX_WEIGHT * aux, aux)
    logits, aux = tfm.forward(cfg, params, tokens=inp)
    return cross_entropy(logits, lab) + AUX_WEIGHT * aux, aux


def prefill_fn(cfg: ArchConfig, params, batch):
    """Forward pass only (inference prefill): returns last-position
    logits.  The head projects ONLY the last position — a (B, S, V)
    logits tensor is never materialized."""
    if cfg.family == "audio":
        logits, _ = whi.forward(cfg, params, batch["frames"],
                                batch["tokens"][:, :-1],
                                head_last_only=True)
    elif cfg.family == "vlm":
        logits, _ = tfm.forward(cfg, params, tokens=batch["tokens"][:, :-1],
                                prefix_embeds=batch["patches"],
                                head_last_only=True)
    else:
        logits, _ = tfm.forward(cfg, params, tokens=batch["tokens"][:, :-1],
                                head_last_only=True)
    return logits[:, -1, :]


def make_cache(cfg: ArchConfig, params, batch_sz: int, cache_len: int,
               frames=None):
    if cfg.family == "audio":
        return whi.init_cache(cfg, params, frames, cache_len)
    return tfm.init_cache(cfg, batch_sz, cache_len)


def decode_fn(cfg: ArchConfig, params, token, cache, pos):
    if cfg.family == "audio":
        return whi.decode_step(cfg, params, token, cache, pos)
    return tfm.decode_step(cfg, params, token, cache, pos)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins for the dry-run (no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, seq_len: int, batch: int,
                kind: str = "train") -> dict:
    """Dry-run input specs for one step of the given kind."""
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    i32 = jnp.int32
    S = jax.ShapeDtypeStruct
    if kind in ("train", "prefill"):
        b = {"tokens": S((batch, seq_len + 1), i32)}
        if cfg.family == "vlm":
            b["patches"] = S((batch, cfg.prefix_tokens, cfg.d_model), dt)
        if cfg.family == "audio":
            b["frames"] = S((batch, cfg.enc_seq, cfg.d_model), dt)
        return b
    # decode: one new token against a cache of seq_len
    return {"token": S((batch,), i32)}


def cache_specs(cfg: ArchConfig, batch: int, cache_len: int):
    """ShapeDtypeStructs of the decode cache (mirrors make_cache)."""
    def spec_of(tree):
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    if cfg.family == "audio":
        dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        caches = []
        for _ in range(cfg.n_layers):
            caches.append({
                "k": jax.ShapeDtypeStruct(
                    (batch, cache_len, cfg.n_kv, cfg.hd), dt),
                "v": jax.ShapeDtypeStruct(
                    (batch, cache_len, cfg.n_kv, cfg.hd), dt),
                "xk": jax.ShapeDtypeStruct(
                    (batch, cfg.enc_seq, cfg.n_kv, cfg.hd), dt),
                "xv": jax.ShapeDtypeStruct(
                    (batch, cfg.enc_seq, cfg.n_kv, cfg.hd), dt),
            })
        return caches
    dummy = jax.eval_shape(lambda: tfm.init_cache(cfg, batch, cache_len))
    return dummy
