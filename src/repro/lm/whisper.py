"""Whisper-large-v3 (enc-dec audio arch) on the shared primitives.

The mel/conv frontend is STUBBED per the assignment: ``input_specs``
supplies precomputed frame embeddings (B, enc_seq, D).  Encoder:
bidirectional attention + GELU MLP, LayerNorm, sinusoidal positions.
Decoder: causal self-attn + cross-attn per layer, learned-style positions
(sinusoidal here), full softmax vocab 51866.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.nn import attention as attn
from repro.nn import layers as nnl
from .config import ArchConfig


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def sinusoid(s: int, d: int, dtype) -> jnp.ndarray:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def init_params(key, cfg: ArchConfig) -> dict:
    dt = _dt(cfg)
    d = cfg.d_model
    n_enc, n_dec = cfg.enc_layers, cfg.n_layers
    keys = jax.random.split(key, 2 * n_enc + 3 * n_dec + 4)
    ki = iter(range(len(keys)))

    def enc_layer():
        return {
            "norm1": nnl.norm_params("ln", d, dt),
            "mixer": attn.attn_params(keys[next(ki)], d, cfg.n_heads,
                                      cfg.n_kv, cfg.hd, True, dt),
            "norm2": nnl.norm_params("ln", d, dt),
            "ffn": nnl.mlp_params(keys[next(ki)], d, cfg.d_ff, "gelu", dt),
        }

    def dec_layer():
        return {
            "norm1": nnl.norm_params("ln", d, dt),
            "self": attn.attn_params(keys[next(ki)], d, cfg.n_heads,
                                     cfg.n_kv, cfg.hd, True, dt),
            "norm_x": nnl.norm_params("ln", d, dt),
            "cross": attn.attn_params(keys[next(ki)], d, cfg.n_heads,
                                      cfg.n_kv, cfg.hd, True, dt),
            "norm2": nnl.norm_params("ln", d, dt),
            "ffn": nnl.mlp_params(keys[next(ki)], d, cfg.d_ff, "gelu", dt),
        }

    return {
        "embed": nnl.embed_init(keys[next(ki)], (cfg.vocab, d), dt),
        "enc_layers": [enc_layer() for _ in range(n_enc)],
        "enc_norm": nnl.norm_params("ln", d, dt),
        "dec_layers": [dec_layer() for _ in range(n_dec)],
        "dec_norm": nnl.norm_params("ln", d, dt),
    }  # lm head tied to embed (whisper ties)


def encode(cfg: ArchConfig, params, frames):
    """frames (B, T, D) stubbed conv-frontend output -> encoder states."""
    x = frames + sinusoid(frames.shape[1], cfg.d_model, frames.dtype)[None]
    for lp in params["enc_layers"]:
        f = _enc_layer_fn(cfg)
        if cfg.remat:
            f = jax.checkpoint(f)
        x = f(lp, x)
    return nnl.apply_norm("ln", x, params["enc_norm"])


def _enc_layer_fn(cfg):
    def f(lp, x):
        h = nnl.apply_norm("ln", x, lp["norm1"])
        x = x + attn.bidir_attention(lp["mixer"], h, cfg.n_heads,
                                     cfg.n_kv, cfg.hd)
        h = nnl.apply_norm("ln", x, lp["norm2"])
        return x + nnl.mlp_apply(lp["ffn"], h, "gelu")
    return f


def _dec_layer_fn(cfg):
    def f(lp, x, enc, positions):
        h = nnl.apply_norm("ln", x, lp["norm1"])
        x = x + attn.causal_attention(lp["self"], h, cfg.n_heads,
                                      cfg.n_kv, cfg.hd, positions,
                                      cfg.rope_theta, use_rope=False)
        h = nnl.apply_norm("ln", x, lp["norm_x"])
        x = x + attn.cross_attention(lp["cross"], h, enc, cfg.n_heads,
                                     cfg.n_kv, cfg.hd)
        h = nnl.apply_norm("ln", x, lp["norm2"])
        return x + nnl.mlp_apply(lp["ffn"], h, "gelu")
    return f


def forward(cfg: ArchConfig, params, frames, tokens,
            head_last_only: bool = False):
    """-> (logits (B, S, V), aux=0)."""
    enc = encode(cfg, params, frames)
    x = params["embed"][tokens]
    b, s, _ = x.shape
    x = x + sinusoid(s, cfg.d_model, x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    for lp in params["dec_layers"]:
        f = _dec_layer_fn(cfg)
        if cfg.remat:
            f = jax.checkpoint(f)
        x = f(lp, x, enc, positions)
    x = nnl.apply_norm("ln", x, params["dec_norm"])
    if head_last_only:
        x = x[:, -1:, :]
    logits = x @ params["embed"].T
    return logits, jnp.float32(0.0)


# ---- decode ---------------------------------------------------------------

def init_cache(cfg: ArchConfig, params, frames, cache_len: int):
    """Prefill: run the encoder once, precompute per-layer cross K/V,
    allocate decoder self-attn caches."""
    enc = encode(cfg, params, frames)
    b = frames.shape[0]
    dt = _dt(cfg)
    caches = []
    for lp in params["dec_layers"]:
        ck, cv = attn.cross_kv(lp["cross"], enc, cfg.n_kv, cfg.hd)
        caches.append({
            "k": jnp.zeros((b, cache_len, cfg.n_kv, cfg.hd), dt),
            "v": jnp.zeros((b, cache_len, cfg.n_kv, cfg.hd), dt),
            "xk": ck, "xv": cv,
        })
    return caches


def decode_step(cfg: ArchConfig, params, token, caches, pos):
    x = params["embed"][token][:, None, :]
    s_embed = sinusoid(8192, cfg.d_model, x.dtype)
    x = x + jax.lax.dynamic_slice_in_dim(
        s_embed, jnp.asarray(pos, jnp.int32) % 8192, 1, axis=0)[None]
    new_caches = []
    for lp, c in zip(params["dec_layers"], caches):
        h = nnl.apply_norm("ln", x, lp["norm1"])
        m, nk, nv = attn.decode_attention(
            lp["self"], h, c["k"], c["v"], pos, cfg.n_heads, cfg.n_kv,
            cfg.hd, cfg.rope_theta, use_rope=False)
        x = x + m
        h = nnl.apply_norm("ln", x, lp["norm_x"])
        x = x + attn.decode_cross_attention(lp["cross"], h[:, 0][:, None],
                                            c["xk"], c["xv"], cfg.n_heads,
                                            cfg.n_kv, cfg.hd)
        h = nnl.apply_norm("ln", x, lp["norm2"])
        x = x + nnl.mlp_apply(lp["ffn"], h, "gelu")
        new_caches.append({"k": nk, "v": nv, "xk": c["xk"], "xv": c["xv"]})
    x = nnl.apply_norm("ln", x, params["dec_norm"])
    return (x @ params["embed"].T)[:, 0, :], new_caches
