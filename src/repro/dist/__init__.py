"""repro.dist — the scale-out substrate: sharding rules, pipeline
parallelism, and gradient compression.

Reconstructed (PR 5) from the API surface its consumers already relied
on: ``lm/transformer`` + ``nn/{attention,ssm,moe}`` call
:func:`sharding.constrain` / :func:`sharding.constrain_heads` at their
activation seams, ``launch/{train,serve,dryrun,memmodel}`` build
parameter / batch / cache shardings, and ``lm/steps`` accepts a
``dist.compress`` codec.  The PCN engine (``repro.engine``) reuses the
same :func:`sharding.batch_shardings` rules to split its batch-first
``(B, …)`` forward across the mesh ``"data"`` axis.

Submodules:
  sharding  — logical-axis sharding rules (dp/fsdp/tp/sp), the
              ``use_mesh`` context, param/batch/cache sharding trees.
  pipeline  — ``pipeline_apply``: GPipe-style microbatch schedule over a
              mesh axis (shard_map + ppermute).
  compress  — gradient codecs with error feedback (int8 quantization,
              top-k sparsification) for cross-replica grad traffic.
"""
from . import compress, pipeline, sharding  # noqa: F401
