"""Pipeline parallelism: a GPipe-style microbatch schedule over one mesh
axis, built on ``shard_map`` + ``ppermute``.

Each device along the pipeline axis holds ONE stage's parameters; the
n_micro microbatches stream through the stages, one hop per step, for
``n_micro + n_stage - 1`` steps (the classic fill/drain bubble).  The
result equals applying the stages sequentially to every microbatch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(mesh, axis: str, n_micro: int, fn, stage_params, x):
    """Run ``x`` through a pipeline of stages laid out along ``axis``.

    fn(params, microbatch) -> microbatch   one stage's computation
    stage_params                            pytree, leaves (n_stage, ...)
    x                                       (n_micro, mb, ...) inputs

    Returns (n_micro, mb, ...) outputs, replicated across the mesh.
    Equivalent to ``for s in range(n_stage): x = fn(params[s], x)`` per
    microbatch — verified by tests/test_distributed.py.
    """
    n_stage = mesh.shape[axis]
    leading = {leaf.shape[0] for leaf in jax.tree.leaves(stage_params)}
    if leading != {n_stage}:
        raise ValueError(
            f"stage_params leading dims {sorted(leading)} != mesh axis "
            f"{axis!r} size {n_stage}")
    if x.shape[0] != n_micro:
        raise ValueError(f"x has {x.shape[0]} microbatches, expected "
                         f"{n_micro}")
    perm = [(i, (i + 1) % n_stage) for i in range(n_stage)]

    def stage_fn(params, xs):
        # params: this stage's (1, ...) slice; xs: all microbatches
        # (replicated — only stage 0 actually ingests them)
        p = jax.tree.map(lambda a: a[0], params)
        idx = jax.lax.axis_index(axis)
        recv0 = jnp.zeros(xs.shape[1:], xs.dtype)
        outs0 = jnp.zeros_like(xs)

        def step(carry, t):
            recv, outs = carry
            mb = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            inp = jnp.where(idx == 0, mb, recv)
            y = fn(p, inp)
            # the last stage finishes microbatch t - (n_stage - 1)
            out_t = jnp.clip(t - (n_stage - 1), 0, n_micro - 1)
            take = (idx == n_stage - 1) & (t >= n_stage - 1)
            cur = jax.lax.dynamic_index_in_dim(outs, out_t, 0,
                                               keepdims=False)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(take, y, cur), out_t, 0)
            recv = jax.lax.ppermute(y, axis, perm)
            return (recv, outs), None

        (_, outs), _ = jax.lax.scan(
            step, (recv0, outs0), jnp.arange(n_micro + n_stage - 1))
        # only the last stage's buffer holds real results; psum
        # replicates them (every other stage contributes zeros)
        return jax.lax.psum(
            jnp.where(idx == n_stage - 1, outs, jnp.zeros_like(outs)),
            axis)

    return shard_map(stage_fn, mesh=mesh, in_specs=(P(axis), P()),
                     out_specs=P(), check_rep=False)(stage_params, x)
