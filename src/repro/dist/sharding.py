"""Sharding rules: logical axes, constraint helpers, sharding trees.

Model code never names mesh axes directly — it constrains activations
along *logical* axes which this module maps onto whatever mesh is
active:

  ``dp``    data parallel (batch rows)   -> every data-like mesh axis
                                            (``pod`` and ``data``)
  ``fsdp``  parameter sharding           -> ``data``
  ``tp``    tensor parallel              -> ``model``
  ``sp``    sequence parallel (between   -> ``model`` (Megatron-SP),
            blocks)                         off when ``use_mesh(sp=False)``

The mapping is held by the :func:`use_mesh` context.  Outside any
context every ``constrain`` is a no-op, so single-device code paths
(tests, the dev container) run unchanged — this is also the PCN
engine's "no mesh" fast path.

Two profiles: ``"tp"`` (the default 2-D data x model layout) and
``"flat_dp"`` (pure FSDP — ``tp``/``sp`` map to nothing; every matrix
is sharded over ``data`` only).

Divisibility: specs are filtered through :func:`fit_spec` — an axis
whose size does not divide the dimension is dropped (replicated) rather
than letting GSPMD pad.  Padding is usually fine, but padding
few-KV-head tensors onto a 16-way model axis provokes involuntary-remat
permutes; :func:`constrain_heads` is the explicit seam for that case.
"""
from __future__ import annotations

from contextlib import contextmanager
from contextvars import ContextVar

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# (mesh, {logical name -> physical axis or tuple or None}) of the
# innermost use_mesh context; None when no mesh is active.  A ContextVar
# (not a module global) so concurrent traces — two serve handles
# compiling under different meshes on different threads — each see their
# own context, like jax's own mesh context manager.
_ACTIVE: ContextVar[tuple | None] = ContextVar(
    "repro_dist_active_mesh", default=None)

_DATA_AXES = ("pod", "data")


def _mesh_sizes(mesh) -> dict:
    return dict(mesh.shape)


def _dp_axes(mesh):
    """All data-like axes present on ``mesh`` (batch rows shard over the
    product of pod x data)."""
    names = set(mesh.axis_names)
    axes = tuple(a for a in _DATA_AXES if a in names)
    return axes if len(axes) != 1 else axes[0]


def _physical(mesh, sp: bool = True, profile: str = "tp") -> dict:
    names = set(mesh.axis_names)
    model = "model" if "model" in names and profile != "flat_dp" else None
    return {
        "dp": _dp_axes(mesh) or None,
        "fsdp": "data" if "data" in names else None,
        "tp": model,
        "sp": model if sp else None,
    }


@contextmanager
def use_mesh(mesh, sp: bool = True, profile: str = "tp"):
    """Activate ``mesh`` for :func:`constrain` / :func:`constrain_heads`.

    ``sp`` gates Megatron-style sequence sharding between blocks
    (``ArchConfig.seq_shard_blocks``); ``profile`` selects the logical
    mapping (``ArchConfig.shard_profile``).  Nests and restores.
    """
    token = _ACTIVE.set((mesh, _physical(mesh, sp=sp, profile=profile)))
    try:
        yield mesh
    finally:
        _ACTIVE.reset(token)


def active_mesh():
    """The mesh of the innermost :func:`use_mesh` context (or None)."""
    active = _ACTIVE.get()
    return active[0] if active is not None else None


def fit_spec(spec: P, shape, mesh) -> P:
    """Drop spec entries whose mesh-axis product does not divide the
    dimension (replicate instead of letting GSPMD pad).  ``spec`` may be
    shorter than ``shape``; missing trailing dims are replicated."""
    sizes = _mesh_sizes(mesh)
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= sizes[a]
        out.append(entry if n and dim % n == 0 else None)
    return P(*out)


def _constrain_spec(x, spec, mesh):
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, fit_spec(spec, x.shape, mesh)))


def constrain(x, *logical):
    """Constrain ``x`` along logical axes (one name or None per dim).
    No-op outside a :func:`use_mesh` context."""
    active = _ACTIVE.get()
    if active is None:
        return x
    mesh, phys = active
    spec = P(*[phys.get(name) if name else None for name in logical])
    return _constrain_spec(x, spec, mesh)


def constrain_heads(x, n_heads: int):
    """Constrain a (B, S, H, Dh) tensor: batch over ``dp`` and heads over
    ``tp`` — but ONLY when the head count divides the model axis.  GSPMD
    pads 40 heads -> 48 fine, but padding few-KV-head tensors onto 16
    devices causes involuntary-remat permutes, so undersized head counts
    stay replicated on the head dim."""
    active = _ACTIVE.get()
    if active is None:
        return x
    mesh, phys = active
    tp = phys.get("tp")
    sizes = _mesh_sizes(mesh)
    heads = tp if tp is not None and n_heads % sizes[tp] == 0 else None
    spec = P(phys.get("dp"), None, heads, None)
    return _constrain_spec(x, spec, mesh)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# column-parallel 2-D matrices (d_in, d_out): shard d_in over fsdp,
# d_out over tp (inputs replicated within a TP group, outputs split)
_COL = {"wq", "wk", "wv", "w_in", "w_gate", "w_x", "w_r", "w_i",
        "in_proj", "router", "lm_head"}
# row-parallel 2-D matrices (d_in, d_out): the contracted dim is the
# TP-split one (wo consumes TP-split head outputs)
_ROW = {"wo", "w_out", "out_proj"}


def param_spec(path: str, leaf, moe_shard: str = "ep") -> tuple:
    """Logical partition of one parameter leaf.

    ``path`` is the ``/``-joined pytree path (e.g. ``layers/0/mixer/wq``);
    ``leaf`` only needs ``.ndim``.  3-D leaves are stacked per-expert
    weights: ``moe_shard="ep"`` puts experts on the model axis (expert
    parallelism), ``"tp"`` shards inside each expert instead (grok: 8
    experts < 16-way model axis).
    """
    ndim = leaf.ndim
    if ndim == 0:
        return ()
    if ndim == 1:
        return (None,)
    name = path.rsplit("/", 1)[-1]
    if ndim == 3:  # (E, d_in, d_out) stacked expert weights
        if name in _ROW:
            return ("tp", None, "fsdp") if moe_shard == "ep" \
                else (None, "tp", "fsdp")
        return ("tp", "fsdp", None) if moe_shard == "ep" \
            else (None, "fsdp", "tp")
    if ndim == 2:
        if name == "embed":
            return ("tp", "fsdp")        # (V, D): vocab over model
        if name == "conv_w":
            return (None, "tp")          # depthwise conv: channels split
        if name in _ROW:
            return ("tp", "fsdp")
        if name in _COL:
            return ("fsdp", "tp")
        return ("fsdp", None)
    return (None,) * ndim


def _resolve(mesh):
    """The logical->physical mapping: the active context's if this mesh
    is the active one, else the default profile for ``mesh``."""
    active = _ACTIVE.get()
    if active is not None and active[0] is mesh:
        return active[1]
    return _physical(mesh)


def _named(mesh, spec, shape):
    return NamedSharding(mesh, fit_spec(spec, shape, mesh))


def param_shardings(params, mesh, moe_shard: str = "ep"):
    """NamedSharding tree for a parameter / optimizer-state tree.
    Leaves may be arrays or ShapeDtypeStructs (dry-run)."""
    phys = _resolve(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for kpath, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kpath)
        logical = param_spec(path, leaf, moe_shard)
        spec = P(*[phys.get(name) if name else None for name in logical])
        out.append(_named(mesh, spec, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(batch, mesh):
    """NamedSharding tree for step inputs: leading (batch) dim over the
    data axes, everything else replicated.  Shared by the LM steps and
    the PCN engine's :class:`~repro.engine.params.Batch`."""
    dp = _dp_axes(mesh)

    def one(leaf):
        spec = P(dp) if leaf.ndim else P()
        return _named(mesh, spec, leaf.shape)

    return jax.tree.map(one, batch)


def cache_shardings(cache, mesh):
    """NamedSharding tree for decode caches: batch over ``dp``, the
    head/channel dim over ``tp`` where it divides (KV heads, SSD heads,
    conv/recurrent channels)."""
    phys = _resolve(mesh)
    dp, tp = phys.get("dp"), phys.get("tp")
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for kpath, leaf in flat:
        name = str(getattr(kpath[-1], "key", getattr(kpath[-1], "idx",
                                                     kpath[-1]))) \
            if kpath else ""
        nd = leaf.ndim
        if nd >= 4 and name in ("k", "v", "xk", "xv"):
            spec = P(dp, None, tp, None)       # (B, T, Hkv, Dh)
        elif nd == 3 and name in ("ks", "vs", "conv"):
            spec = P(dp, None, tp)             # (B, T, Hkv) / (B, W, C)
        elif name == "state":
            spec = P(dp, tp)                   # (B, H, ...) / (B, D)
        elif nd >= 1:
            spec = P(dp)
        else:
            spec = P()
        out.append(_named(mesh, spec, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# PCN engine helpers (batch-first (B, ...) trees)
# ---------------------------------------------------------------------------

def shard_leading(tree, mesh=None):
    """Constrain every array leaf's leading dim over the data axes —
    the engine's sharding plan for stacked (B, ...) structures between
    forward stages.  ``mesh=None`` uses the active context (no-op when
    there is none)."""
    if mesh is None:
        mesh = active_mesh()
    if mesh is None:
        return tree
    dp = _dp_axes(mesh)

    def one(x):
        if getattr(x, "ndim", 0) == 0:
            return x
        return _constrain_spec(x, P(dp), mesh)

    return jax.tree.map(one, tree)


def replicate(tree, mesh):
    """Constrain every leaf fully replicated (the engine's PCNParams
    plan: point-MLP weights are tiny; every device holds them all)."""
    sh = NamedSharding(mesh, P())
    return jax.tree.map(
        lambda x: jax.lax.with_sharding_constraint(x, sh), tree)
