"""Gradient compression codecs with error feedback.

Cross-replica gradient traffic is the all-reduce term of the dry-run's
cost model; these codecs shrink it while error feedback keeps the
long-run update unbiased: each step quantizes ``g + ef`` and carries the
quantization residual into the next step, so residuals never accumulate
(``sum(compressed) = sum(g) + ef_0 - ef_T``).

    ef = init_error_feedback(grads)
    dg, ef = compress_grads(grads, ef)          # int8 by default

``make_compressor`` adapts a codec to the ``compressor`` hook of
``lm.steps.make_train_step`` (error feedback rides in
``opt_state["ef"]``; seed it with :func:`init_error_feedback` before
jitting — see ``launch/train.py --compress``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(grads):
    """Zero residual tree (f32, the codec's accumulation dtype)."""
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quant_int8(v):
    """Symmetric per-tensor int8 quantization (what actually crosses the
    wire is the int8 payload + one f32 scale; here we round-trip)."""
    s = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30) / 127.0
    return jnp.round(v / s) * s


def _topk(frac: float):
    def q(v):
        flat = v.reshape(-1)
        k = max(int(flat.shape[0] * frac), 1)
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        return jnp.where(jnp.abs(v) >= thresh, v, 0.0)
    return q


_CODECS = {"int8": _quant_int8}


def compress_grads(grads, ef, codec: str = "int8", topk_frac: float = 0.1):
    """-> (compressed grads, new error feedback).  ``codec``: ``"int8"``
    (symmetric 8-bit quantization) or ``"topk"`` (magnitude
    sparsification keeping ``topk_frac`` of entries)."""
    q = _topk(topk_frac) if codec == "topk" else _CODECS[codec]
    acc = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, ef)
    dg = jax.tree.map(q, acc)
    new_ef = jax.tree.map(lambda a, d: a - d, acc, dg)
    dg = jax.tree.map(lambda d, g: d.astype(g.dtype), dg, grads)
    return dg, new_ef


def make_compressor(codec: str = "int8", topk_frac: float = 0.1):
    """Adapt a codec to ``make_train_step(compressor=...)``:
    compressor(grads, opt_state) -> (grads, opt_state), with the error
    feedback carried in ``opt_state["ef"]`` (must be pre-seeded with
    :func:`init_error_feedback` so the jitted state structure is
    stable)."""
    def compressor(grads, opt_state):
        if "ef" not in opt_state:
            raise ValueError(
                "opt_state has no 'ef' entry; seed it with "
                "dist.compress.init_error_feedback(params) before the "
                "first step (launch/train.py --compress does this)")
        dg, ef = compress_grads(grads, opt_state["ef"], codec=codec,
                                topk_frac=topk_frac)
        return dg, {**opt_state, "ef": ef}
    return compressor
