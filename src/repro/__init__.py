"""repro — L-PCN (octree-based islandization) + multi-arch LM framework in JAX.

Layers:
  repro.core     — the paper's contribution: octree-based islandization and
                   hub-based scheduling for point-cloud networks.
  repro.models   — PCN benchmark models (PointNet++, DGCNN, PointNeXt,
                   PointVector) and the Mesorasi/GDPCA baselines.
  repro.nn       — pure-JAX neural-net substrate (no flax).
  repro.lm       — the 10 assigned LM architectures + serving.
  repro.kernels  — Pallas TPU kernels (knn, gather_mlp, hub_reuse, flash
                   attention) with jnp oracles.
  repro.serve    — continuous-batching PCN serving: admission queue, size
                   buckets, timeout dispatch, latency percentiles.
  repro.dist     — sharding rules, pipeline parallelism, grad compression.
  repro.optim / repro.data / repro.ckpt — training substrate.
  repro.launch   — mesh, dry-run, train/serve drivers.
"""

__version__ = "1.0.0"

HW = dict(  # TPU v5e-class target (assignment constants)
    peak_bf16_flops=197e12,   # per chip
    hbm_bw=819e9,             # bytes/s per chip
    ici_bw=50e9,              # bytes/s per link
    hbm_bytes=16 * 2**30,     # 16 GiB HBM per chip
    vmem_bytes=128 * 2**20,   # ~128 MiB VMEM per chip (v5e ~128MB)
)
