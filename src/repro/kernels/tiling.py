"""Shared tile/lane helpers for the batched FC kernels.

TPU tiles are (8, 128) for f32: the MXU/VPU want the minor (lane) axis in
multiples of 128 and the second-minor (sublane) axis in multiples of 8.
The FC kernels pad their contraction/output lanes up front (zero lanes
through a matmul are exact no-ops) and slice the output back, so Mosaic
never sees a ragged lane dimension; tile sizes along the grid axes are
derived from a VMEM budget instead of being hardcoded.
"""
from __future__ import annotations

import jax.numpy as jnp

LANE = 128          # f32 minor-axis tile
SUBLANE = 8         # f32 second-minor-axis tile
F32_BYTES = 4
DEFAULT_VMEM_BUDGET_MB = 8.0   # of ~16 MB/core; leaves double-buffer room


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def pad_axis(x: jnp.ndarray, axis: int, target: int) -> jnp.ndarray:
    """Zero-pad ``axis`` of ``x`` up to length ``target`` (no-op if equal)."""
    cur = x.shape[axis]
    if cur == target:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - cur)
    return jnp.pad(x, widths)


def pad_lanes(x: jnp.ndarray, multiple: int = LANE) -> jnp.ndarray:
    """Zero-pad the last (lane) axis of ``x`` to a multiple of ``multiple``."""
    return pad_axis(x, x.ndim - 1, round_up(x.shape[-1], multiple))


def largest_tile(limit: int, fits, base: int = SUBLANE) -> int:
    """Largest power-of-two multiple of ``base`` (capped at ``limit``) for
    which ``fits(tile) -> bool`` holds.  When even the base tile busts the
    budget, halve below it (down to 1) so an explicit tight budget is
    honored instead of silently exceeded.

    ``fits`` is a VMEM-bytes predicate built from the kernel's per-step
    buffer shapes; the scan is tiny and static (runs at trace time).
    """
    limit = max(limit, 1)
    t = min(base, limit)
    if not fits(t):
        while t > 1 and not fits(t):
            t //= 2
        return max(t, 1)
    best = t
    t *= 2
    while t <= limit:
        if not fits(t):
            break
        best = t
        t *= 2
    return best
