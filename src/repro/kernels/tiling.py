"""Shared tile/lane helpers for the batched FC kernels.

TPU tiles are (8, 128) for f32: the MXU/VPU want the minor (lane) axis in
multiples of 128 and the second-minor (sublane) axis in multiples of 8.
The FC kernels pad their contraction/output lanes up front (zero lanes
through a matmul are exact no-ops) and slice the output back, so Mosaic
never sees a ragged lane dimension; tile sizes along the grid axes are
derived from a VMEM budget instead of being hardcoded.
"""
from __future__ import annotations

import jax.numpy as jnp

LANE = 128          # f32 minor-axis tile
SUBLANE = 8         # f32 second-minor-axis tile
F32_BYTES = 4
DEFAULT_VMEM_BUDGET_MB = 8.0   # of ~16 MB/core; leaves double-buffer room


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def pad_axis(x: jnp.ndarray, axis: int, target: int) -> jnp.ndarray:
    """Zero-pad ``axis`` of ``x`` up to length ``target`` (no-op if equal)."""
    cur = x.shape[axis]
    if cur == target:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - cur)
    return jnp.pad(x, widths)


def pad_lanes(x: jnp.ndarray, multiple: int = LANE) -> jnp.ndarray:
    """Zero-pad the last (lane) axis of ``x`` to a multiple of ``multiple``."""
    return pad_axis(x, x.ndim - 1, round_up(x.shape[-1], multiple))


def block_bytes(block_shape, dtype=jnp.float32) -> int:
    """Bytes of one VMEM block buffer (non-int dims — e.g. vmap-mapped
    entries — count as 1)."""
    n = 1
    for d in block_shape:
        n *= d if isinstance(d, int) else 1
    return n * jnp.dtype(dtype).itemsize


def call_footprint_bytes(streamed_bytes: int, resident_bytes: int) -> int:
    """Jaxpr-visible VMEM footprint of one pallas_call grid step: streamed
    blocks are double-buffered, resident (constant-index-map) blocks are
    not.  This is the byte model ``repro.analysis`` lints against."""
    return 2 * streamed_bytes + resident_bytes


def mlp_weight_elems(dp: int, hp: int, fp: int) -> int:
    """Elements of the lane-padded 2-layer MLP weights (W1+b1+W2+b2) —
    the resident set both FC kernels pin in VMEM."""
    return dp * hp + hp + hp * fp + fp


def gather_mlp_footprint_elems(t: int, k: int, dp: int, dc: int, hp: int,
                               fp: int) -> int:
    """Per-grid-step VMEM elements of the gather-MLP kernel at subset
    tile ``t``: double-buffered streamed blocks (raw tile + mask +
    centers), the (t·K, H/F) matmul intermediates, the output tile, and
    the resident weights.  Shared by :func:`gather_mlp_tile_plan`'s
    feasibility predicate and the ``repro.analysis`` kernel linter /
    future tile autotuner (ROADMAP item 1)."""
    streamed = 2 * t * (k * (dp + 1) + dc)       # double-buffered in
    inter = t * k * (hp + fp)                    # x@W1, h@W2
    out = t * fp
    return streamed + inter + out + mlp_weight_elems(dp, hp, fp)


def hub_reuse_footprint_elems(t: int, c: int, m: int, k: int, dp: int,
                              hp: int, fp: int) -> int:
    """Per-grid-step VMEM elements of the hub-reuse kernel at island
    tile ``t``; the one-hot gather's t² term is the binding constraint.
    Shared by :func:`hub_reuse_tile_plan` and ``repro.analysis``."""
    streamed = 2 * t * (c * dp + 2 * m * k + m * fp)
    onehot = (t * m * k) * (t * c)
    inter = t * c * (hp + fp) + t * m * k * fp
    out = t * m * fp
    return streamed + onehot + inter + out + mlp_weight_elems(dp, hp, fp)


def largest_tile(limit: int, fits, base: int = SUBLANE) -> int:
    """Largest power-of-two multiple of ``base`` (capped at ``limit``) for
    which ``fits(tile) -> bool`` holds.  When even the base tile busts the
    budget, halve below it (down to 1) so an explicit tight budget is
    honored instead of silently exceeded.

    ``fits`` is a VMEM-bytes predicate built from the kernel's per-step
    buffer shapes; the scan is tiny and static (runs at trace time).
    """
    limit = max(limit, 1)
    t = min(base, limit)
    if not fits(t):
        while t > 1 and not fits(t):
            t //= 2
        return max(t, 1)
    best = t
    t *= 2
    while t <= limit:
        if not fits(t):
            break
        best = t
        t *= 2
    return best
