"""Pure-jnp oracle for the hub_reuse kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

BIG = 3.4e38


def hub_reuse_ref(pool_in, slot, comp, w1, b1, w2, b2, live=None):
    """pool_in (H,C,D), slot (H,M,K), comp (H,M,F) -> (H,M,F).  ``live``
    (H,M,K) additionally masks non-resident cache entries (None = all)."""
    h = jax.nn.relu(
        jnp.einsum("hcd,de->hce", pool_in, w1,
                   preferred_element_type=jnp.float32) + b1)
    y = jnp.einsum("hce,ef->hcf", h, w2,
                   preferred_element_type=jnp.float32) + b2   # (H,C,F)
    c = pool_in.shape[1]
    safe = jnp.clip(slot, 0, c - 1)
    g = jnp.take_along_axis(
        y, safe.reshape(y.shape[0], -1, 1), axis=1
    ).reshape(slot.shape + (y.shape[-1],))                    # (H,M,K,F)
    g = g + comp[:, :, None, :]
    ok = slot >= 0 if live is None else (slot >= 0) & (live != 0)
    g = jnp.where(ok[..., None], g, -BIG)
    return jnp.max(g, axis=2).astype(pool_in.dtype)
