"""Jitted public wrappers for the hub_reuse kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import plans
from .hub_reuse import (hub_reuse_batched_pallas, hub_reuse_pallas,
                        hub_reuse_tile_plan)
from .ref import hub_reuse_ref


@partial(jax.jit, static_argnames=("interpret",))
def hub_reuse(pool_in, slot, comp, w1, b1, w2, b2,
              interpret: bool | None = None, live=None):
    """Pool-MLP + compensated reuse-gather + masked max-pool, one cloud.
    ``live`` (H, M, K) bool/int (None = all resident) additionally masks
    positions whose cache entry is not actually resident (ragged
    batches)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return hub_reuse_pallas(pool_in, slot, comp, w1, b1, w2, b2,
                            interpret=interpret, live=live)


@partial(jax.jit, static_argnames=("th", "vmem_budget_mb", "lanes",
                                   "dimension_semantics", "interpret"))
def hub_reuse_batched(pool_in, slot, comp, w1, b1, w2, b2,
                      th: int | None = None,
                      vmem_budget_mb: float | None = None,
                      lanes: int | None = None,
                      dimension_semantics: tuple | None = None,
                      interpret: bool | None = None, live=None):
    """Natively batched hub-reuse: (B, H, C, D) → (B, H, M, F_out) through
    ONE pallas_call with grid (B, ⌈H/TH⌉); TH islands share one pool
    matmul and one offset-one-hot reuse matmul per step, weights stay
    VMEM-resident and D/H/F lanes are padded to ``lanes`` multiples.
    ``th`` / ``vmem_budget_mb`` / ``lanes`` / ``dimension_semantics``
    are the ``kernel_kw`` knobs (all None = the autotuned plan store,
    else the VMEM-budget heuristic); ``live`` (B, H, M, K) as in
    :func:`hub_reuse`."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return hub_reuse_batched_pallas(
        pool_in, slot, comp, w1, b1, w2, b2, th=th,
        vmem_budget_mb=vmem_budget_mb, lanes=lanes,
        dimension_semantics=dimension_semantics, interpret=interpret,
        live=live)


# the tile plan resolves inside the trace: a plan-store mutation (or a
# plans.bypass() boundary) must drop traces made under the old plan
plans.register_cache_clearer(hub_reuse_batched.clear_cache)


__all__ = ["hub_reuse", "hub_reuse_batched", "hub_reuse_ref",
           "hub_reuse_tile_plan"]
