"""Jitted public wrapper for the hub_reuse kernel."""
from __future__ import annotations

from functools import partial

import jax

from .hub_reuse import hub_reuse_pallas
from .ref import hub_reuse_ref


@partial(jax.jit, static_argnames=("interpret",))
def hub_reuse(pool_in, slot, comp, w1, b1, w2, b2,
              interpret: bool | None = None, live=None):
    """Pool-MLP + compensated reuse-gather + masked max-pool.  ``live``
    (H, M, K) bool/int (None = all resident) additionally masks positions
    whose cache entry is not actually resident (ragged batches)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return hub_reuse_pallas(pool_in, slot, comp, w1, b1, w2, b2,
                            interpret=interpret, live=live)


__all__ = ["hub_reuse", "hub_reuse_ref"]
