"""Pallas TPU kernel: islandized FC — pool-MLP + compensated reuse-gather.

The Islandization Unit's datapath (paper Fig. 13/14), one island per grid
step:

  1. pool MLP: the island's Hub-Cache contents (C unique points, hub-
     relative inputs) go through the 2-layer MLP once          (MXU)
  2. reuse gather: every (subset, k) position fetches its cache slot.
     TPU adaptation: the gather is a ONE-HOT MATMUL (M·K, C) @ (C, F) —
     a systolic-friendly reuse of the MXU instead of the FPGA's BRAM
     random port                                              (MXU)
  3. delta compensation: + comp[subset] broadcast over k       (VPU)
  4. masked max-pool over K                                    (VPU)

Overflow (never-cached) positions are computed by the gather_mlp kernel
outside and merged with an elementwise max (max-pool commutes), so this
kernel touches exactly the deduplicated workload — the paper's compute
saving is structural, not simulated.

VMEM budget per island step (C=64, M=64, K=32, F=128):
  pool 64·131·4 ≈ 33 KB, one-hot 2048·64·4 ≈ 512 KB, out 64·128·4.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e38


def _reuse_gather(pool_ref, slot_ref, comp_ref, w1_ref, b1_ref, w2_ref,
                  b2_ref):
    """Shared kernel body: pool MLP + one-hot reuse-gather + Δ-comp.
    Returns (gathered (M, K, F), slot (M*K,))."""
    _, c, d = pool_ref.shape
    _, m, k = slot_ref.shape
    pool = pool_ref[...].reshape(c, d)
    h = jax.lax.dot_general(pool, w1_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = jax.nn.relu(h + b1_ref[...][None, :])
    y = jax.lax.dot_general(h, w2_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + b2_ref[...][None, :]                       # (C, F)

    slot = slot_ref[...].reshape(m * k)                # (M*K,)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (m * k, c), 1)
              == slot[:, None]).astype(jnp.float32)    # (M*K, C)
    gathered = jax.lax.dot_general(
        onehot, y, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (M*K, F) MXU
    gathered = gathered.reshape(m, k, -1)
    gathered = gathered + comp_ref[...].reshape(m, 1, -1)
    return gathered, slot


def _hub_reuse_kernel(pool_ref, slot_ref, comp_ref, w1_ref, b1_ref,
                      w2_ref, b2_ref, out_ref):
    """pool_ref (1, C, D) hub-relative inputs; slot_ref (1, M, K) int32;
    comp_ref (1, M, F); out_ref (1, M, F)."""
    _, m, k = slot_ref.shape
    gathered, slot = _reuse_gather(pool_ref, slot_ref, comp_ref, w1_ref,
                                   b1_ref, w2_ref, b2_ref)
    live = (slot >= 0).reshape(m, k, 1)
    gathered = jnp.where(live, gathered, -BIG)
    out_ref[...] = jnp.max(gathered, axis=1)[None].astype(out_ref.dtype)


def _hub_reuse_masked_kernel(pool_ref, slot_ref, comp_ref, live_ref,
                             w1_ref, b1_ref, w2_ref, b2_ref, out_ref):
    """Masked variant (ragged batches): a position is live only if its
    slot is assigned AND the extra mask says the cache entry is resident."""
    _, m, k = slot_ref.shape
    gathered, slot = _reuse_gather(pool_ref, slot_ref, comp_ref, w1_ref,
                                   b1_ref, w2_ref, b2_ref)
    live = ((slot >= 0) & (live_ref[...].reshape(m * k) != 0)
            ).reshape(m, k, 1)
    gathered = jnp.where(live, gathered, -BIG)
    out_ref[...] = jnp.max(gathered, axis=1)[None].astype(out_ref.dtype)


def hub_reuse_pallas(pool_in: jnp.ndarray, slot: jnp.ndarray,
                     comp: jnp.ndarray, w1, b1, w2, b2,
                     interpret: bool = False, live=None):
    """pool_in (H, C, D); slot (H, M, K) int32 (-1 = not cached);
    comp (H, M, F) per-subset delta compensation.  -> (H, M, F) pooled
    reuse partials (−BIG where a subset has no cached positions).
    ``live`` (H, M, K) int32 (nonzero = cache entry resident) composes
    with ``slot >= 0``."""
    hn, c, d = pool_in.shape
    _, m, k = slot.shape
    hdim = w1.shape[1]
    fout = w2.shape[1]
    weight_specs = [
        pl.BlockSpec((d, hdim), lambda i: (0, 0)),
        pl.BlockSpec((hdim,), lambda i: (0,)),
        pl.BlockSpec((hdim, fout), lambda i: (0, 0)),
        pl.BlockSpec((fout,), lambda i: (0,)),
    ]
    data_specs = [
        pl.BlockSpec((1, c, d), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, m, k), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, m, fout), lambda i: (i, 0, 0)),
    ]
    if live is None:
        kern = _hub_reuse_kernel
        in_specs = data_specs + weight_specs
        args = (pool_in, slot, comp, w1, b1, w2, b2)
    else:
        kern = _hub_reuse_masked_kernel
        in_specs = (data_specs
                    + [pl.BlockSpec((1, m, k), lambda i: (i, 0, 0))]
                    + weight_specs)
        args = (pool_in, slot, comp, live.astype(jnp.int32), w1, b1, w2, b2)
    return pl.pallas_call(
        kern,
        grid=(hn,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, m, fout), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hn, m, fout), pool_in.dtype),
        interpret=interpret,
    )(*args)
