"""Pallas TPU kernel: islandized FC — pool-MLP + compensated reuse-gather.

The Islandization Unit's datapath (paper Fig. 13/14):

  1. pool MLP: the island's Hub-Cache contents (C unique points, hub-
     relative inputs) go through the 2-layer MLP once          (MXU)
  2. reuse gather: every (subset, k) position fetches its cache slot.
     TPU adaptation: the gather is a ONE-HOT MATMUL (M·K, C) @ (C, F) —
     a systolic-friendly reuse of the MXU instead of the FPGA's BRAM
     random port                                              (MXU)
  3. delta compensation: + comp[subset] broadcast over k       (VPU)
  4. masked max-pool over K                                    (VPU)

Overflow (never-cached) positions are computed by the gather_mlp kernel
outside and merged with an elementwise max (max-pool commutes), so this
kernel touches exactly the deduplicated workload — the paper's compute
saving is structural, not simulated.  A subset with zero live positions
returns the merge identity ``-BIG``; the merge boundary in
``core.pipeline`` zero-fills any row that stayed at the sentinel.

Two entry points:

* ``hub_reuse_pallas`` — one cloud, one island per grid step (kept for
  the eager path and the vmap-of-kernels A/B).
* ``hub_reuse_batched_pallas`` — the natively batched serving kernel:
  grid ``(B, ⌈H/TH⌉)`` with a new island-tile axis ``TH``, so ONE
  pallas_call serves the whole cloud stack.  The TH islands of a step
  share one (TH·C, D')@(D', H') pool matmul and one offset-one-hot
  (TH·M·K, TH·C)@(TH·C, F') reuse matmul — both fully lane-aligned
  (D/H/F zero-padded to 128-multiples, sliced back after).  Weights ride
  constant ``lambda b, j: (0, 0)`` index maps with
  ``dimension_semantics=("parallel", "arbitrary")`` → VMEM-resident
  across the whole grid.

VMEM budget per grid step (the ``TH`` heuristic solves for this; lane-
padded D', H', F'; f32):
  streamed (double-buffered):  2·TH·(C·D' + M·K·2 + M·F') · 4 B
      pool (TH, C, D') + slot/live (TH, M, K) + comp (TH, M, F')
  one-hot + gathered:          (TH·M·K)·(TH·C) + TH·M·K·F') · 4 B
  pool MLP intermediates:      TH·C·(H' + F') · 4 B
  resident weights:            (D'·H' + H' + H'·F' + F') · 4 B
  output tile:                 TH·M·F' · 4 B
The one-hot term grows with TH², which is what caps TH (e.g. TH=4,
M=64, K=32, C=64: one-hot 8192·256·4 = 8 MB alone → TH=2 at the 8 MB
default).
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import plans
from repro.kernels.tiling import (DEFAULT_VMEM_BUDGET_MB, F32_BYTES, LANE,
                                  hub_reuse_footprint_elems, largest_tile,
                                  pad_axis, round_up)

DEFAULT_SEMANTICS = ("parallel", "arbitrary")

BIG = 3.4e38


def _reuse_gather(pool_ref, slot_ref, comp_ref, w1_ref, b1_ref, w2_ref,
                  b2_ref):
    """Shared kernel body: pool MLP + one-hot reuse-gather + Δ-comp.
    Returns (gathered (M, K, F), slot (M*K,))."""
    _, c, d = pool_ref.shape
    _, m, k = slot_ref.shape
    pool = pool_ref[...].reshape(c, d)
    h = jax.lax.dot_general(pool, w1_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = jax.nn.relu(h + b1_ref[...][None, :])
    y = jax.lax.dot_general(h, w2_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + b2_ref[...][None, :]                       # (C, F)

    slot = slot_ref[...].reshape(m * k)                # (M*K,)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (m * k, c), 1)
              == slot[:, None]).astype(jnp.float32)    # (M*K, C)
    gathered = jax.lax.dot_general(
        onehot, y, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (M*K, F) MXU
    gathered = gathered.reshape(m, k, -1)
    gathered = gathered + comp_ref[...].reshape(m, 1, -1)
    return gathered, slot


def _hub_reuse_kernel(pool_ref, slot_ref, comp_ref, w1_ref, b1_ref,
                      w2_ref, b2_ref, out_ref):
    """pool_ref (1, C, D) hub-relative inputs; slot_ref (1, M, K) int32;
    comp_ref (1, M, F); out_ref (1, M, F)."""
    _, m, k = slot_ref.shape
    gathered, slot = _reuse_gather(pool_ref, slot_ref, comp_ref, w1_ref,
                                   b1_ref, w2_ref, b2_ref)
    live = (slot >= 0).reshape(m, k, 1)
    gathered = jnp.where(live, gathered, -BIG)
    out_ref[...] = jnp.max(gathered, axis=1)[None].astype(out_ref.dtype)


def _hub_reuse_masked_kernel(pool_ref, slot_ref, comp_ref, live_ref,
                             w1_ref, b1_ref, w2_ref, b2_ref, out_ref):
    """Masked variant (ragged batches): a position is live only if its
    slot is assigned AND the extra mask says the cache entry is resident."""
    _, m, k = slot_ref.shape
    gathered, slot = _reuse_gather(pool_ref, slot_ref, comp_ref, w1_ref,
                                   b1_ref, w2_ref, b2_ref)
    live = ((slot >= 0) & (live_ref[...].reshape(m * k) != 0)
            ).reshape(m, k, 1)
    gathered = jnp.where(live, gathered, -BIG)
    out_ref[...] = jnp.max(gathered, axis=1)[None].astype(out_ref.dtype)


def hub_reuse_pallas(pool_in: jnp.ndarray, slot: jnp.ndarray,
                     comp: jnp.ndarray, w1, b1, w2, b2,
                     interpret: bool = False, live=None):
    """pool_in (H, C, D); slot (H, M, K) int32 (-1 = not cached);
    comp (H, M, F) per-subset delta compensation.  -> (H, M, F) pooled
    reuse partials (−BIG where a subset has no cached positions).
    ``live`` (H, M, K) int32 (nonzero = cache entry resident) composes
    with ``slot >= 0``."""
    hn, c, d = pool_in.shape
    _, m, k = slot.shape
    hdim = w1.shape[1]
    fout = w2.shape[1]
    weight_specs = [
        pl.BlockSpec((d, hdim), lambda i: (0, 0)),
        pl.BlockSpec((hdim,), lambda i: (0,)),
        pl.BlockSpec((hdim, fout), lambda i: (0, 0)),
        pl.BlockSpec((fout,), lambda i: (0,)),
    ]
    data_specs = [
        pl.BlockSpec((1, c, d), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, m, k), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, m, fout), lambda i: (i, 0, 0)),
    ]
    if live is None:
        kern = _hub_reuse_kernel
        in_specs = data_specs + weight_specs
        args = (pool_in, slot, comp, w1, b1, w2, b2)
    else:
        kern = _hub_reuse_masked_kernel
        in_specs = (data_specs
                    + [pl.BlockSpec((1, m, k), lambda i: (i, 0, 0))]
                    + weight_specs)
        args = (pool_in, slot, comp, live.astype(jnp.int32), w1, b1, w2, b2)
    return pl.pallas_call(
        kern,
        grid=(hn,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, m, fout), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hn, m, fout), pool_in.dtype),
        interpret=interpret,
    )(*args)


# ---- natively batched kernel: grid (B, ceil(H/TH)) --------------------------

def _tiled_reuse_gather(pool_ref, slot_ref, comp_ref, w1_ref, b1_ref,
                        w2_ref, b2_ref, *, hn: int):
    """TH islands per step.  Blocks carry a leading singleton batch axis:
    pool (1, TH, C, D), slot (1, TH, M, K), comp (1, TH, M, F).

    Returns (gathered (TH, M, K, F), slot (TH, M*K)).  The TH pool MLPs
    run as one (TH·C, D) matmul; the TH reuse gathers run as one
    offset-one-hot (TH·M·K, TH·C) matmul — island j's slots map to
    columns [j·C, (j+1)·C), unassigned slots (< 0) hit no column.

    When TH does not divide H, the last step's out-of-range islands read
    padding (NaN in interpret mode) — their pool rows are zeroed before
    the shared one-hot matmul so 0·NaN can't contaminate real islands
    (their own outputs are clipped on write anyway)."""
    _, th, c, d = pool_ref.shape
    _, _, m, k = slot_ref.shape
    pool = pool_ref[...].reshape(th * c, d)
    island_of_row = jax.lax.broadcasted_iota(jnp.int32, (th * c, 1), 0) // c
    in_range = pl.program_id(1) * th + island_of_row < hn
    pool = jnp.where(in_range, pool, 0.0)
    h = jax.lax.dot_general(pool, w1_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = jax.nn.relu(h + b1_ref[...][None, :])
    y = jax.lax.dot_general(h, w2_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + b2_ref[...][None, :]                       # (TH*C, F)

    slot = slot_ref[...].reshape(th, m * k)            # (TH, M*K)
    offset = jax.lax.broadcasted_iota(jnp.int32, (th, m * k), 0) * c
    col = jnp.where(slot >= 0, slot + offset, -1).reshape(th * m * k)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (th * m * k, th * c), 1)
              == col[:, None]).astype(jnp.float32)
    gathered = jax.lax.dot_general(
        onehot, y, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (TH*M*K, F) MXU
    gathered = gathered.reshape(th, m, k, -1)
    gathered = gathered + comp_ref[...].reshape(th, m, 1, -1)
    return gathered, slot


def _hub_reuse_batched_kernel(pool_ref, slot_ref, comp_ref, w1_ref, b1_ref,
                              w2_ref, b2_ref, out_ref, *, hn: int):
    _, th, m, k = slot_ref.shape
    gathered, slot = _tiled_reuse_gather(pool_ref, slot_ref, comp_ref,
                                         w1_ref, b1_ref, w2_ref, b2_ref,
                                         hn=hn)
    live = (slot >= 0).reshape(th, m, k, 1)
    gathered = jnp.where(live, gathered, -BIG)
    out_ref[...] = jnp.max(gathered, axis=2)[None].astype(out_ref.dtype)


def _hub_reuse_batched_masked_kernel(pool_ref, slot_ref, comp_ref, live_ref,
                                     w1_ref, b1_ref, w2_ref, b2_ref,
                                     out_ref, *, hn: int):
    _, th, m, k = slot_ref.shape
    gathered, slot = _tiled_reuse_gather(pool_ref, slot_ref, comp_ref,
                                         w1_ref, b1_ref, w2_ref, b2_ref,
                                         hn=hn)
    live = ((slot >= 0) & (live_ref[...].reshape(th, m * k) != 0)
            ).reshape(th, m, k, 1)
    gathered = jnp.where(live, gathered, -BIG)
    out_ref[...] = jnp.max(gathered, axis=2)[None].astype(out_ref.dtype)


def hub_reuse_tile_plan(hn: int, c: int, m: int, k: int, d: int, hdim: int,
                        fout: int, th: int | None = None,
                        vmem_budget_mb: float | None = None,
                        lanes: int | None = None,
                        dimension_semantics=None,
                        b: int | None = None) -> dict:
    """Resolve the batched kernel's tile plan: lane-padded dims and the
    island tile ``TH`` under the VMEM budget (the one-hot's TH² term is
    the binding constraint).

    Resolution order mirrors :func:`gather_mlp_tile_plan`: explicit
    ``th``/``lanes``/``dimension_semantics`` ("override") > a
    ``repro.kernels.plans`` store hit for this ``(b, shape)`` cell
    ("autotuned") > the VMEM heuristic at 128 lanes ("heuristic"); a
    stale store entry warns and degrades to the heuristic."""
    dims = {"b": b, "hn": hn, "c": c, "m": m, "k": k, "d": d, "h": hdim,
            "f": fout}

    def build(th, lanes, vmem_budget_mb, sem, provenance):
        lanes = LANE if lanes is None else int(lanes)
        mb = (DEFAULT_VMEM_BUDGET_MB if vmem_budget_mb is None
              else float(vmem_budget_mb))
        sem = DEFAULT_SEMANTICS if sem is None else tuple(sem)
        dp = round_up(d, lanes)
        hp = round_up(hdim, lanes)
        fp = round_up(fout, lanes)
        budget = int(mb * 2 ** 20)

        def fits(t: int) -> bool:
            return F32_BYTES * hub_reuse_footprint_elems(
                t, c, m, k, dp, hp, fp) <= budget

        if th is None:
            th = largest_tile(hn, fits, base=1)
        th = max(1, min(int(th), hn))
        return {"th": th, "lanes": lanes, "d_pad": dp, "h_pad": hp,
                "f_pad": fp, "grid_tiles": pl.cdiv(hn, th),
                "vmem_budget_mb": mb,
                "dimension_semantics": sem,
                "footprint_bytes": F32_BYTES * hub_reuse_footprint_elems(
                    th, c, m, k, dp, hp, fp),
                "provenance": provenance}

    overridden = (th is not None or lanes is not None
                  or dimension_semantics is not None)
    hit = None
    if not overridden and vmem_budget_mb is None and b is not None:
        hit = plans.lookup("hub_reuse", **dims)
    if hit is not None and hit.get("variant") == "vmap":
        # the measurement rejected the batched grid for this cell (the
        # common case: a handful of islands, where the TH² one-hot and
        # lane padding cost more than they amortize): dispatch jax.vmap
        # of the per-cloud kernel — one island per grid step, no padding
        plan = {"variant": "vmap", "th": 1, "lanes": 1,
                "d_pad": d, "h_pad": hdim, "f_pad": fout,
                "grid_tiles": hn,
                "vmem_budget_mb": DEFAULT_VMEM_BUDGET_MB,
                "dimension_semantics": DEFAULT_SEMANTICS,
                "footprint_bytes": F32_BYTES * hub_reuse_footprint_elems(
                    1, c, m, k, d, hdim, fout),
                "provenance": "autotuned"}
        plans.note_plan("hub_reuse", dims, plan)
        return plan
    if hit is not None:
        plan = build(hit["th"], hit.get("lanes"), hit.get("vmem_budget_mb"),
                     hit.get("dimension_semantics"), "autotuned")
        if plan["footprint_bytes"] > int(plan["vmem_budget_mb"] * 2 ** 20):
            warnings.warn(
                f"stale tile plan for {plans.plan_key('hub_reuse', dims)}: "
                f"footprint {plan['footprint_bytes']} B busts its "
                f"{plan['vmem_budget_mb']} MB budget; using the heuristic "
                f"(re-run python -m repro.launch.autotune)",
                RuntimeWarning, stacklevel=2)
            plan = build(None, None, None, None, "heuristic")
    else:
        plan = build(th, lanes, vmem_budget_mb, dimension_semantics,
                     "override" if overridden else "heuristic")
    plans.note_plan("hub_reuse", dims, plan)
    return plan


def hub_reuse_batched_pallas(pool_in: jnp.ndarray, slot: jnp.ndarray,
                             comp: jnp.ndarray, w1, b1, w2, b2,
                             th: int | None = None,
                             vmem_budget_mb: float | None = None,
                             lanes: int | None = None,
                             dimension_semantics=None,
                             interpret: bool = False, live=None):
    """Natively batched hub-reuse: pool_in (B, H, C, D), slot (B, H, M, K),
    comp (B, H, M, F), optional live (B, H, M, K).  -> (B, H, M, F_out) in
    ONE pallas_call with grid (B, ⌈H/TH⌉).

    Weights ride constant index maps (VMEM-resident across the grid);
    D/H/F are zero-padded to ``lanes``-multiples (sliced back on
    return); ``th`` / ``vmem_budget_mb`` / ``lanes`` /
    ``dimension_semantics`` are the ``kernel_kw`` knobs — left None,
    the plan comes from the autotuned store (on a hit) or the VMEM
    heuristic (see :func:`hub_reuse_tile_plan`)."""
    b, hn, c, d = pool_in.shape
    _, _, m, k = slot.shape
    hdim, fout = w1.shape[1], w2.shape[1]
    plan = hub_reuse_tile_plan(hn, c, m, k, d, hdim, fout, th=th,
                               vmem_budget_mb=vmem_budget_mb, lanes=lanes,
                               dimension_semantics=dimension_semantics,
                               b=b)
    if plan.get("variant") == "vmap":
        # measured winner for this cell is the per-cloud dispatch: B
        # logical per-cloud programs via the pallas batching rule
        per_cloud = functools.partial(hub_reuse_pallas, w1=w1, b1=b1,
                                      w2=w2, b2=b2, interpret=interpret)
        if live is None:
            return jax.vmap(lambda p, sl, cp: per_cloud(p, sl, cp))(
                pool_in, slot, comp)
        return jax.vmap(lambda p, sl, cp, lv: per_cloud(p, sl, cp, live=lv))(
            pool_in, slot, comp, live)
    th = plan["th"]
    dp, hp, fp = plan["d_pad"], plan["h_pad"], plan["f_pad"]

    pool_in = pad_axis(pool_in, 3, dp)
    comp = pad_axis(comp, 3, fp)
    w1 = pad_axis(pad_axis(w1, 1, hp), 0, dp)
    b1 = pad_axis(b1, 0, hp)
    w2 = pad_axis(pad_axis(w2, 1, fp), 0, hp)
    b2 = pad_axis(b2, 0, fp)

    weight_specs = [
        pl.BlockSpec((dp, hp), lambda bi, j: (0, 0)),
        pl.BlockSpec((hp,), lambda bi, j: (0,)),
        pl.BlockSpec((hp, fp), lambda bi, j: (0, 0)),
        pl.BlockSpec((fp,), lambda bi, j: (0,)),
    ]
    data_specs = [
        pl.BlockSpec((1, th, c, dp), lambda bi, j: (bi, j, 0, 0)),
        pl.BlockSpec((1, th, m, k), lambda bi, j: (bi, j, 0, 0)),
        pl.BlockSpec((1, th, m, fp), lambda bi, j: (bi, j, 0, 0)),
    ]
    if live is None:
        kern = functools.partial(_hub_reuse_batched_kernel, hn=hn)
        in_specs = data_specs + weight_specs
        args = (pool_in, slot, comp, w1, b1, w2, b2)
    else:
        kern = functools.partial(_hub_reuse_batched_masked_kernel, hn=hn)
        in_specs = (data_specs
                    + [pl.BlockSpec((1, th, m, k),
                                    lambda bi, j: (bi, j, 0, 0))]
                    + weight_specs)
        args = (pool_in, slot, comp, live.astype(jnp.int32), w1, b1, w2, b2)
    out = pl.pallas_call(
        kern,
        grid=(b, pl.cdiv(hn, th)),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, th, m, fp), lambda bi, j: (bi, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hn, m, fp), pool_in.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=tuple(plan["dimension_semantics"])),
        interpret=interpret,
    )(*args)
    return out[..., :fout]
