"""Shape-keyed tile-plan store for the batched FC kernels.

The autotuner (``repro.launch.autotune``) measures candidate tile plans
per ``(kernel, B, shape)`` cell and persists winners here
(``results/tile_plans.json`` by default).  The tile planners in
``gather_mlp``/``hub_reuse`` consult the active store at trace time, so
a cached plan silently replaces the VMEM-budget heuristic anywhere the
default ``kernel_kw`` resolution path runs — ``engine.apply`` /
``PCNEngine`` / ``FCBackend.{dense,reuse}_batched`` — and a cache miss
(or a stale/corrupt entry) falls back to the heuristic instead of
raising.

Resolution order inside the planners (see ``gather_mlp_tile_plan``):

    explicit kernel_kw override  >  store hit ("autotuned")  >  heuristic

Entry format (one per :func:`plan_key`)::

    {"ts": 64, "lanes": 8, "vmem_budget_mb": 8.0,
     "dimension_semantics": ["parallel", "arbitrary"],
     "provenance": "autotuned", ...measurement metadata...}

A cell where the batched grid *loses* to the old per-cloud dispatch
(e.g. hub cells with only a handful of islands) stores a **variant
entry** instead — ``{"variant": "vmap", "provenance": "autotuned",
...}`` — and the planners resolve it to a plan with ``"variant":
"vmap"``: the batched ops then dispatch ``jax.vmap`` of the per-cloud
kernel for that cell rather than the (B, tiles) grid.  Losing cells are
thereby pinned to the measured winner too, instead of silently running
a grid that the measurement rejected.

``lanes`` is the lane-padding multiple for the D/H/F dims.  On real TPU
hardware only 128 is Mosaic-aligned, and 128-lane candidates win the
measurement there; in interpret mode (CPU) the padding FLOPs are real
work, so smaller lane pads measure faster — which is exactly why the
knob is measured per host rather than hardcoded.  The K002 linter
accepts sub-128 lanes only when the block spans the full (padded) array
width, which these kernels always do for their lane dims.

Mutating the store (or toggling :func:`bypass`) clears the jit caches
the kernel ops registered via :func:`register_cache_clearer`: the
planners resolve at trace time, so an already-traced executable would
otherwise keep serving the plan that was active when it traced.

This module runs at trace time (``repro.kernels`` is an A003-traced
package): no wall-clock reads here — timing lives in
``repro.launch.autotune``.
"""
from __future__ import annotations

import json
import os
import threading
import warnings
from contextlib import contextmanager

VERSION = 1
DEFAULT_PATH = os.path.join("results", "tile_plans.json")
ENV_VAR = "REPRO_TILE_PLANS"

#: per-kernel tile field name (the knob the heuristic would otherwise set)
TILE_FIELD = {"gather_mlp": "ts", "hub_reuse": "th"}

_SEMANTICS = {"parallel", "arbitrary"}


def plan_key(kernel: str, dims: dict) -> str:
    """Canonical store key, e.g.
    ``"gather_mlp|b=2,d=35,dc=3,f=128,h=64,k=8,s=64"``."""
    if kernel not in TILE_FIELD:
        raise ValueError(f"unknown kernel {kernel!r}; "
                         f"expected one of {sorted(TILE_FIELD)}")
    return kernel + "|" + ",".join(
        f"{k}={int(v)}" for k, v in sorted(dims.items()))


def entry_error(kernel: str, entry) -> str | None:
    """Why ``entry`` is not a usable plan for ``kernel`` (None = valid).
    Checked on load AND on record, so a hand-edited or version-skewed
    cache degrades to the heuristic instead of crashing a trace."""
    if not isinstance(entry, dict):
        return "entry is not an object"
    tf = TILE_FIELD[kernel]
    variant = entry.get("variant")
    if variant is not None:
        # a "vmap" entry promotes the per-cloud-kernel dispatch for this
        # cell (the batched grid measured slower); it has no grid knobs,
        # only an optional per-cloud tile
        if variant != "vmap":
            return f"unknown variant {variant!r} (expected 'vmap')"
        t = entry.get(tf)
        if t is not None and (not isinstance(t, int)
                              or isinstance(t, bool) or t < 1):
            return (f"{tf!r} must be a positive int when present on a "
                    f"vmap entry, got {t!r}")
        if entry.get("provenance") != "autotuned":
            return (f"provenance {entry.get('provenance')!r} != "
                    f"'autotuned' (only measured winners belong in the "
                    f"store)")
        return None
    t = entry.get(tf)
    if not isinstance(t, int) or isinstance(t, bool) or t < 1:
        return f"{tf!r} must be a positive int, got {t!r}"
    lanes = entry.get("lanes", 128)
    if not isinstance(lanes, int) or isinstance(lanes, bool) or lanes < 1:
        return f"'lanes' must be a positive int, got {lanes!r}"
    mb = entry.get("vmem_budget_mb", None)
    if not isinstance(mb, (int, float)) or isinstance(mb, bool) or mb <= 0:
        return f"'vmem_budget_mb' must be a positive number, got {mb!r}"
    sem = entry.get("dimension_semantics")
    if sem is not None:
        if (not isinstance(sem, (list, tuple)) or len(sem) != 2
                or not set(sem) <= _SEMANTICS):
            return ("'dimension_semantics' must be a pair from "
                    f"{sorted(_SEMANTICS)}, got {sem!r}")
    if entry.get("provenance") != "autotuned":
        return (f"provenance {entry.get('provenance')!r} != 'autotuned' "
                f"(only measured winners belong in the store)")
    return None


class PlanStore:
    """A dict of :func:`plan_key` -> plan entries with JSON persistence.

    ``load`` never raises on bad files: a corrupt/mis-versioned file or
    an invalid entry warns (``RuntimeWarning``) and is dropped, so the
    planners fall back to the heuristic."""

    def __init__(self, entries: dict | None = None,
                 path: str | None = None):
        self.entries: dict = dict(entries or {})
        self.path = path

    @classmethod
    def load(cls, path: str) -> "PlanStore":
        store = cls(path=path)
        if not os.path.exists(path):
            return store
        try:
            with open(path, encoding="utf-8") as fh:
                raw = json.load(fh)
        except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
            warnings.warn(
                f"tile-plan store {path!r} is unreadable "
                f"({type(e).__name__}: {e}); falling back to the "
                f"heuristic tile planner", RuntimeWarning, stacklevel=2)
            return store
        if not isinstance(raw, dict) or raw.get("version") != VERSION:
            warnings.warn(
                f"tile-plan store {path!r} has version "
                f"{raw.get('version') if isinstance(raw, dict) else '?'} "
                f"!= {VERSION}; ignoring it (re-run "
                f"python -m repro.launch.autotune)",
                RuntimeWarning, stacklevel=2)
            return store
        for key, entry in (raw.get("plans") or {}).items():
            kernel = str(key).split("|", 1)[0]
            if kernel not in TILE_FIELD:
                warnings.warn(
                    f"tile-plan store {path!r}: dropping entry {key!r} "
                    f"(unknown kernel)", RuntimeWarning, stacklevel=2)
                continue
            err = entry_error(kernel, entry)
            if err:
                warnings.warn(
                    f"tile-plan store {path!r}: dropping entry {key!r} "
                    f"({err}); the heuristic covers this cell",
                    RuntimeWarning, stacklevel=2)
                continue
            store.entries[key] = entry
        return store

    def lookup(self, kernel: str, **dims) -> dict | None:
        entry = self.entries.get(plan_key(kernel, dims))
        return dict(entry) if entry is not None else None

    def record(self, kernel: str, dims: dict, entry: dict) -> str:
        """Insert a winner (validated — we produced it, so a bad entry
        is a bug, not a degradation) and invalidate kernel jit caches."""
        err = entry_error(kernel, entry)
        if err:
            raise ValueError(f"refusing to record invalid plan for "
                             f"{plan_key(kernel, dims)}: {err}")
        key = plan_key(kernel, dims)
        self.entries[key] = dict(entry)
        _clear_kernel_caches()
        return key

    def save(self, path: str | None = None) -> str:
        path = path or self.path or default_path()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w", encoding="utf-8") as fh:
            json.dump({"version": VERSION,
                       "plans": {k: self.entries[k]
                                 for k in sorted(self.entries)}},
                      fh, indent=1, sort_keys=True)
        self.path = path
        return path

    def __len__(self) -> int:
        return len(self.entries)


# ---- module state: the active store + bypass/capture contexts ---------------

_lock = threading.Lock()
_store: PlanStore | None = None
_configured: bool = False        # configure() called (None = in-memory)
_configured_path: str | None = None
_bypass_depth = 0
_captures: list[list] = []
_clearers: list = []


def default_path() -> str:
    return os.environ.get(ENV_VAR) or DEFAULT_PATH


def register_cache_clearer(fn) -> None:
    """Kernel ops modules register their jitted wrappers'
    ``clear_cache`` here so store mutations invalidate stale traces."""
    _clearers.append(fn)


def _clear_kernel_caches() -> None:
    for fn in _clearers:
        fn()


def configure(path: str | None) -> None:
    """Point the active store at ``path`` (None = fresh in-memory store,
    nothing read from or written to disk).  Clears kernel jit caches."""
    global _store, _configured, _configured_path
    with _lock:
        _configured = True
        _configured_path = path
        _store = PlanStore() if path is None else PlanStore.load(path)
    _clear_kernel_caches()


def refresh() -> None:
    """Re-read the configured (or default) store from disk."""
    global _store
    with _lock:
        path = _configured_path if _configured else default_path()
        _store = PlanStore() if path is None else PlanStore.load(path)
    _clear_kernel_caches()


def active_store() -> PlanStore:
    """The store the planners consult (lazily loaded from
    ``$REPRO_TILE_PLANS`` or ``results/tile_plans.json``)."""
    global _store
    with _lock:
        if _store is None:
            _store = PlanStore.load(default_path())
        return _store


def enabled() -> bool:
    return _bypass_depth == 0


@contextmanager
def bypass():
    """Disable store lookups inside the block — the planners resolve
    with the pure heuristic (explicit overrides still apply).  Clears
    kernel jit caches on entry and exit so traces made either side of
    the boundary can't serve the wrong plan."""
    global _bypass_depth
    _bypass_depth += 1
    _clear_kernel_caches()
    try:
        yield
    finally:
        _bypass_depth -= 1
        _clear_kernel_caches()


@contextmanager
def capture():
    """Record every plan the planners resolve inside the block — the
    plans *actually used*, post-fallback.  Yields a list of
    ``{"kernel", "dims", "plan"}`` dicts; benchmarks assert provenance
    from it instead of trusting the requested plan."""
    log: list = []
    _captures.append(log)
    try:
        yield log
    finally:
        _captures.remove(log)


def note_plan(kernel: str, dims: dict, plan: dict) -> None:
    """Called by the tile planners with the final resolved plan."""
    for log in _captures:
        log.append({"kernel": kernel, "dims": dict(dims),
                    "plan": dict(plan)})


def lookup(kernel: str, **dims) -> dict | None:
    """Store lookup honoring :func:`bypass`; None on miss."""
    if not enabled():
        return None
    return active_store().lookup(kernel, **dims)
