"""Pallas TPU kernel: Mamba-2 SSD intra-chunk block (the quadratic hot
spot of the SSD scan — nn/ssm.py's y_in + chunk-state computation).

Per (batch, chunk) grid step, entirely in VMEM:

    seg   = cum_i − cum_j   (per head)            (VPU)
    L     = exp(seg) · tril                        (VPU)
    CB    = C_c @ B_cᵀ                             (MXU)
    y_in  = (CB ⊙ L ⊙ dt_j) @ x_c   per head       (MXU)
    state = (B_c ⊙ decay_to_end ⊙ dt)ᵀ @ x_c       (MXU)

so the (q, q, H) decay tensor never reaches HBM — on TPU this is the
difference between the SSD being HBM-bound and MXU-bound (the jnp path
materializes B·nc·q²·H·4 bytes).  Heads are looped inside the kernel
(per-head (q, q) tiles keep VMEM small and MXU shapes aligned).

VMEM per step (q=64, H<=8 per shard, P=64, S=128):
  x (q,H,P) + B/C (q,S) + per-head (q,q) + state (H,P,S) ≈ 300 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, b_ref, c_ref, dt_ref, cum_ref,
                      y_ref, st_ref, *, n_heads: int):
    """Blocks carry leading (1, 1) grid dims:
    x (1,1,q,H,P) f32; b/c (1,1,q,S) f32; dt/cum (1,1,q,H) f32.
    Outputs: y (1,1,q,H,P) intra-chunk term; st (1,1,H,P,S) chunk state.
    """
    q = x_ref.shape[2]
    x = x_ref[0, 0]
    B = b_ref[0, 0]
    C = c_ref[0, 0]
    dt = dt_ref[0, 0]
    cum = cum_ref[0, 0]

    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (q, q)
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tril = rows >= cols

    for h in range(n_heads):  # static loop: small H per shard
        seg = cum[:, h][:, None] - cum[:, h][None, :]
        L = jnp.where(tril, jnp.exp(seg), 0.0)
        m = cb * L * dt[:, h][None, :]                   # (q, q)
        y_h = jax.lax.dot_general(m, x[:, h, :],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        y_ref[0, 0, :, h, :] = y_h
        decay_end = jnp.exp(cum[-1, h] - cum[:, h]) * dt[:, h]  # (q,)
        bw = B * decay_end[:, None]                      # (q, S)
        st_h = jax.lax.dot_general(x[:, h, :], bw,
                                   (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        st_ref[0, 0, h, :, :] = st_h                     # (P, S)


def ssd_chunk_pallas(x, B, C, dt, cum, interpret: bool = False):
    """x (bs, nc, q, H, P); B/C (bs, nc, q, S); dt/cum (bs, nc, q, H)
    -> y_in (bs, nc, q, H, P), states (bs, nc, H, P, S).  All f32."""
    bs, nc, q, h, p = x.shape
    s = B.shape[-1]
    kern = functools.partial(_ssd_chunk_kernel, n_heads=h)
    grid = (bs, nc)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, h, p), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1, q, s), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, s), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, h), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, h), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, h, p), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1, h, p, s), lambda i, j: (i, j, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bs, nc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bs, nc, h, p, s), jnp.float32),
        ],
        interpret=interpret,
    )(x, B, C, dt, cum)
