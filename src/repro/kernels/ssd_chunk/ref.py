"""Pure-jnp oracle for the SSD intra-chunk kernel (mirrors nn/ssm.py)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def ssd_chunk_ref(x, B, C, dt, cum):
    """x (bs,nc,q,H,P); B/C (bs,nc,q,S); dt/cum (bs,nc,q,H) ->
    (y_in (bs,nc,q,H,P), states (bs,nc,H,P,S))."""
    q = x.shape[2]
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    CB = jnp.einsum("bnis,bnjs->bnij", C, B)
    y_in = jnp.einsum("bnij,bnijh,bnjh,bnjhp->bnihp", CB, L, dt, x)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)
    states = jnp.einsum("bnjs,bnjh,bnjh,bnjhp->bnhps",
                        B, decay_to_end, dt, x)
    return y_in, states
