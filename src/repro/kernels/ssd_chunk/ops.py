"""Jitted public wrapper for the SSD intra-chunk kernel."""
from __future__ import annotations

from functools import partial

import jax

from .ref import ssd_chunk_ref
from .ssd_chunk import ssd_chunk_pallas


@partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x, B, C, dt, cum, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ssd_chunk_pallas(x, B, C, dt, cum, interpret=interpret)


__all__ = ["ssd_chunk", "ssd_chunk_ref"]
