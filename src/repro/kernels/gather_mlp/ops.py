"""Jitted public wrappers for the fused gather-MLP-pool kernel."""
from __future__ import annotations

from functools import partial

import jax

from .gather_mlp import (gather_mlp_batched_pallas, gather_mlp_pallas,
                         gather_mlp_tile_plan)
from .ref import gather_mlp_ref


@partial(jax.jit, static_argnames=("ts", "interpret"))
def gather_mlp(raw, centers, w1, b1, w2, b2, ts: int = 8,
               interpret: bool | None = None, mask=None):
    """Fused normalize → MLP → max-pool, one cloud.  ``mask`` (S, K)
    bool/int (None = all live) excludes ragged padding positions from the
    pool; rows with zero live positions return zeros instead of -BIG."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return gather_mlp_pallas(raw, centers, w1, b1, w2, b2, ts=ts,
                             interpret=interpret, mask=mask)


@partial(jax.jit, static_argnames=("ts", "vmem_budget_mb", "interpret"))
def gather_mlp_batched(raw, centers, w1, b1, w2, b2, ts: int | None = None,
                       vmem_budget_mb: float | None = None,
                       interpret: bool | None = None, mask=None):
    """Natively batched gather-MLP: (B, S, K, D) → (B, S, F_out) through
    ONE pallas_call with grid (B, ⌈S/TS⌉); weights stay VMEM-resident
    across the whole grid and D/H/F lanes are 128-aligned.  ``ts`` (None =
    VMEM-budget heuristic) and ``vmem_budget_mb`` are the ``kernel_kw``
    knobs; ``mask`` (B, S, K) as in :func:`gather_mlp`."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kw = {} if vmem_budget_mb is None else {"vmem_budget_mb": vmem_budget_mb}
    return gather_mlp_batched_pallas(raw, centers, w1, b1, w2, b2, ts=ts,
                                     interpret=interpret, mask=mask, **kw)


__all__ = ["gather_mlp", "gather_mlp_batched", "gather_mlp_ref",
           "gather_mlp_tile_plan"]
