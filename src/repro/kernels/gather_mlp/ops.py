"""Jitted public wrapper for the fused gather-MLP-pool kernel."""
from __future__ import annotations

from functools import partial

import jax

from .gather_mlp import gather_mlp_pallas
from .ref import gather_mlp_ref


@partial(jax.jit, static_argnames=("ts", "interpret"))
def gather_mlp(raw, centers, w1, b1, w2, b2, ts: int = 8,
               interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return gather_mlp_pallas(raw, centers, w1, b1, w2, b2, ts=ts,
                             interpret=interpret)


__all__ = ["gather_mlp", "gather_mlp_ref"]
