"""Jitted public wrappers for the fused gather-MLP-pool kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import plans
from .gather_mlp import (gather_mlp_batched_pallas, gather_mlp_pallas,
                         gather_mlp_tile_plan)
from .ref import gather_mlp_ref


@partial(jax.jit, static_argnames=("ts", "interpret"))
def gather_mlp(raw, centers, w1, b1, w2, b2, ts: int = 8,
               interpret: bool | None = None, mask=None):
    """Fused normalize → MLP → max-pool, one cloud.  ``mask`` (S, K)
    bool/int (None = all live) excludes ragged padding positions from the
    pool; rows with zero live positions return zeros instead of -BIG."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return gather_mlp_pallas(raw, centers, w1, b1, w2, b2, ts=ts,
                             interpret=interpret, mask=mask)


@partial(jax.jit, static_argnames=("ts", "vmem_budget_mb", "lanes",
                                   "dimension_semantics", "interpret"))
def gather_mlp_batched(raw, centers, w1, b1, w2, b2, ts: int | None = None,
                       vmem_budget_mb: float | None = None,
                       lanes: int | None = None,
                       dimension_semantics: tuple | None = None,
                       interpret: bool | None = None, mask=None):
    """Natively batched gather-MLP: (B, S, K, D) → (B, S, F_out) through
    ONE pallas_call with grid (B, ⌈S/TS⌉); weights stay VMEM-resident
    across the whole grid and D/H/F lanes are padded to ``lanes``
    multiples.  ``ts`` / ``vmem_budget_mb`` / ``lanes`` /
    ``dimension_semantics`` are the ``kernel_kw`` knobs (all None = the
    autotuned plan store, else the VMEM-budget heuristic); ``mask``
    (B, S, K) as in :func:`gather_mlp`."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return gather_mlp_batched_pallas(
        raw, centers, w1, b1, w2, b2, ts=ts,
        vmem_budget_mb=vmem_budget_mb, lanes=lanes,
        dimension_semantics=dimension_semantics, interpret=interpret,
        mask=mask)


# the tile plan resolves inside the trace: a plan-store mutation (or a
# plans.bypass() boundary) must drop traces made under the old plan
plans.register_cache_clearer(gather_mlp_batched.clear_cache)


__all__ = ["gather_mlp", "gather_mlp_batched", "gather_mlp_ref",
           "gather_mlp_tile_plan"]
