"""Jitted public wrapper for the fused gather-MLP-pool kernel."""
from __future__ import annotations

from functools import partial

import jax

from .gather_mlp import gather_mlp_pallas
from .ref import gather_mlp_ref


@partial(jax.jit, static_argnames=("ts", "interpret"))
def gather_mlp(raw, centers, w1, b1, w2, b2, ts: int = 8,
               interpret: bool | None = None, mask=None):
    """Fused normalize → MLP → max-pool.  ``mask`` (S, K) bool/int (None =
    all live) excludes ragged padding positions from the pool; rows with
    zero live positions return zeros instead of -BIG."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return gather_mlp_pallas(raw, centers, w1, b1, w2, b2, ts=ts,
                             interpret=interpret, mask=mask)


__all__ = ["gather_mlp", "gather_mlp_ref"]
