"""Pallas TPU kernel: fused center-normalize → MLP → max-pool (FC step).

The paper's FCU streams each gathered point subset through a 16×16 systolic
array (MLP = 98 % of FC FLOPs) and max-pools into the center.  TPU
adaptation: one fused kernel per subset tile —

    x   = [raw[..., :Dc] − center, raw[..., Dc:]]      (VPU)
    h   = relu(x @ W1 + b1) @ W2 + b2                  (MXU, f32 accum)
    out = max over K                                   (VPU)

so the (TS·K, H) intermediate never touches HBM.

Two entry points:

* ``gather_mlp_pallas`` — one cloud, grid over subset tiles (the original
  per-cloud kernel, kept for the eager path and vmap-of-kernels A/B).
* ``gather_mlp_batched_pallas`` — the natively batched serving kernel:
  grid ``(B, ⌈S/TS⌉)``, the batch folded into the grid so ONE pallas_call
  serves the whole cloud stack.  Weights use constant ``lambda b, i:
  (0, 0)`` index maps with ``dimension_semantics=("parallel",
  "arbitrary")`` so Mosaic keeps them VMEM-resident across the entire
  grid; the ``D``/``H``/``F`` lanes are zero-padded to 128-multiples
  before the call (zero lanes are exact no-ops through the matmuls) and
  the output is sliced back, so the MXU always sees aligned tiles.

VMEM budget per grid step (the ``TS`` heuristic solves for this; lane-
padded dims D'=⌈D/128⌉·128 etc., f32):
  streamed (double-buffered):  2·TS·(K·(D'+1) + Dc) · 4 B
      raw tile (TS, K, D') + mask (TS, K) + centers (TS, Dc)
  intermediates:               TS·K·(H'+F') · 4 B      (x@W1, h@W2)
  resident weights:            (D'·H' + H' + H'·F' + F') · 4 B
  output tile:                 TS·F' · 4 B
e.g. TS=64, K=32, D'=H'=F'=128: 2·64·(32·129+3)·4 ≈ 2.1 MB streamed
+ 64·32·256·4 ≈ 2.1 MB intermediates + 130 KB weights < 8 MB default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import (DEFAULT_VMEM_BUDGET_MB, F32_BYTES, LANE,
                                  gather_mlp_footprint_elems, largest_tile,
                                  pad_axis, pad_lanes, round_up)

BIG = 3.4e38


def _mlp_pool(raw, ctr, w1, b1, w2, b2, dc: int):
    """Shared kernel body: normalize → 2-layer MLP.  -> (TS, K, F)."""
    ts, k, d = raw.shape
    rel = raw[..., :dc] - ctr[:, None, :]
    x = jnp.concatenate([rel, raw[..., dc:]], axis=-1)    # (TS, K, D)
    x2 = x.reshape(ts * k, d)
    h = jax.lax.dot_general(x2, w1, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = jax.nn.relu(h + b1[None, :])
    y = jax.lax.dot_general(h, w2, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + b2[None, :]
    return y.reshape(ts, k, -1)


def _gather_mlp_kernel(raw_ref, ctr_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                       out_ref, *, dc: int):
    y = _mlp_pool(raw_ref[...], ctr_ref[...], w1_ref[...], b1_ref[...],
                  w2_ref[...], b2_ref[...], dc)
    out_ref[...] = jnp.max(y, axis=1).astype(out_ref.dtype)


def _gather_mlp_masked_kernel(raw_ref, ctr_ref, mask_ref, w1_ref, b1_ref,
                              w2_ref, b2_ref, out_ref, *, dc: int):
    """Masked variant (ragged batches): invalid (subset, k) positions go
    to -BIG before the pool; subsets with zero valid positions zero-fill
    instead of returning -BIG."""
    y = _mlp_pool(raw_ref[...], ctr_ref[...], w1_ref[...], b1_ref[...],
                  w2_ref[...], b2_ref[...], dc)
    live = mask_ref[...] != 0                             # (TS, K)
    pooled = jnp.max(jnp.where(live[..., None], y, -BIG), axis=1)
    pooled = jnp.where(live.any(axis=1)[:, None], pooled, 0.0)
    out_ref[...] = pooled.astype(out_ref.dtype)


def gather_mlp_pallas(raw: jnp.ndarray, centers: jnp.ndarray,
                      w1, b1, w2, b2, ts: int = 8,
                      interpret: bool = False, mask=None):
    """raw (S, K, D) gathered inputs; centers (S, Dc) subtracted from the
    leading Dc lanes; two-layer MLP; max over K.  -> (S, F_out).

    ``mask`` (S, K) int32 (nonzero = live) excludes padding positions
    from the pool; rows with no live position return zeros."""
    s, k, d = raw.shape
    dc = centers.shape[1]
    fout = w2.shape[1]
    hdim = w1.shape[1]
    ts = min(ts, s)
    weight_specs = [
        pl.BlockSpec((d, hdim), lambda i: (0, 0)),
        pl.BlockSpec((hdim,), lambda i: (0,)),
        pl.BlockSpec((hdim, fout), lambda i: (0, 0)),
        pl.BlockSpec((fout,), lambda i: (0,)),
    ]
    if mask is None:
        kern = functools.partial(_gather_mlp_kernel, dc=dc)
        in_specs = [
            pl.BlockSpec((ts, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((ts, dc), lambda i: (i, 0)),
            *weight_specs,
        ]
        args = (raw, centers, w1, b1, w2, b2)
    else:
        kern = functools.partial(_gather_mlp_masked_kernel, dc=dc)
        in_specs = [
            pl.BlockSpec((ts, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((ts, dc), lambda i: (i, 0)),
            pl.BlockSpec((ts, k), lambda i: (i, 0)),
            *weight_specs,
        ]
        args = (raw, centers, mask.astype(jnp.int32), w1, b1, w2, b2)
    return pl.pallas_call(
        kern,
        grid=(pl.cdiv(s, ts),),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((ts, fout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, fout), raw.dtype),
        interpret=interpret,
    )(*args)


# ---- natively batched kernel: grid (B, ceil(S/TS)) --------------------------

def _gather_mlp_batched_kernel(raw_ref, ctr_ref, w1_ref, b1_ref, w2_ref,
                               b2_ref, out_ref, *, dc: int):
    """Blocks carry a leading singleton batch axis: raw (1, TS, K, D)."""
    y = _mlp_pool(raw_ref[...][0], ctr_ref[...][0], w1_ref[...],
                  b1_ref[...], w2_ref[...], b2_ref[...], dc)
    out_ref[...] = jnp.max(y, axis=1)[None].astype(out_ref.dtype)


def _gather_mlp_batched_masked_kernel(raw_ref, ctr_ref, mask_ref, w1_ref,
                                      b1_ref, w2_ref, b2_ref, out_ref,
                                      *, dc: int):
    y = _mlp_pool(raw_ref[...][0], ctr_ref[...][0], w1_ref[...],
                  b1_ref[...], w2_ref[...], b2_ref[...], dc)
    live = mask_ref[...][0] != 0                          # (TS, K)
    pooled = jnp.max(jnp.where(live[..., None], y, -BIG), axis=1)
    pooled = jnp.where(live.any(axis=1)[:, None], pooled, 0.0)
    out_ref[...] = pooled[None].astype(out_ref.dtype)


def gather_mlp_tile_plan(s: int, k: int, d: int, dc: int, hdim: int,
                         fout: int, ts: int | None = None,
                         vmem_budget_mb: float = DEFAULT_VMEM_BUDGET_MB
                         ) -> dict:
    """Derive the batched kernel's tile plan: lane-padded dims and the
    subset tile ``TS`` that fills (but does not bust) the VMEM budget.

    ``ts`` overrides the heuristic (the ``kernel_kw`` knob)."""
    dp = round_up(d, LANE)
    hp = round_up(hdim, LANE)
    fp = round_up(fout, LANE)
    budget = int(vmem_budget_mb * 2 ** 20)

    def fits(t: int) -> bool:
        return F32_BYTES * gather_mlp_footprint_elems(
            t, k, dp, dc, hp, fp) <= budget

    provenance = "heuristic" if ts is None else "override"
    if ts is None:
        ts = largest_tile(s, fits)
    ts = max(1, min(ts, s))
    return {"ts": ts, "d_pad": dp, "h_pad": hp, "f_pad": fp,
            "grid_tiles": pl.cdiv(s, ts),
            "vmem_budget_mb": vmem_budget_mb,
            "footprint_bytes": F32_BYTES * gather_mlp_footprint_elems(
                ts, k, dp, dc, hp, fp),
            "provenance": provenance}


def gather_mlp_batched_pallas(raw: jnp.ndarray, centers: jnp.ndarray,
                              w1, b1, w2, b2, ts: int | None = None,
                              vmem_budget_mb: float = DEFAULT_VMEM_BUDGET_MB,
                              interpret: bool = False, mask=None):
    """Natively batched gather-MLP: raw (B, S, K, D), centers (B, S, Dc),
    optional mask (B, S, K).  -> (B, S, F_out) in ONE pallas_call with
    grid (B, ⌈S/TS⌉).

    Weights ride constant index maps (VMEM-resident across the grid);
    D/H/F are lane-padded to 128-multiples (sliced back on return);
    ``ts`` / ``vmem_budget_mb`` are the ``kernel_kw`` knobs."""
    b, s, k, d = raw.shape
    dc = centers.shape[2]
    hdim, fout = w1.shape[1], w2.shape[1]
    plan = gather_mlp_tile_plan(s, k, d, dc, hdim, fout, ts=ts,
                                vmem_budget_mb=vmem_budget_mb)
    ts = plan["ts"]
    dp, hp, fp = plan["d_pad"], plan["h_pad"], plan["f_pad"]

    raw = pad_lanes(raw)
    w1 = pad_axis(pad_lanes(w1), 0, dp)
    b1 = pad_lanes(b1)
    w2 = pad_axis(pad_lanes(w2), 0, hp)
    b2 = pad_lanes(b2)

    weight_specs = [
        pl.BlockSpec((dp, hp), lambda bi, i: (0, 0)),
        pl.BlockSpec((hp,), lambda bi, i: (0,)),
        pl.BlockSpec((hp, fp), lambda bi, i: (0, 0)),
        pl.BlockSpec((fp,), lambda bi, i: (0,)),
    ]
    data_specs = [
        pl.BlockSpec((1, ts, k, dp), lambda bi, i: (bi, i, 0, 0)),
        pl.BlockSpec((1, ts, dc), lambda bi, i: (bi, i, 0)),
    ]
    if mask is None:
        kern = functools.partial(_gather_mlp_batched_kernel, dc=dc)
        in_specs = data_specs + weight_specs
        args = (raw, centers, w1, b1, w2, b2)
    else:
        kern = functools.partial(_gather_mlp_batched_masked_kernel, dc=dc)
        in_specs = (data_specs
                    + [pl.BlockSpec((1, ts, k), lambda bi, i: (bi, i, 0))]
                    + weight_specs)
        args = (raw, centers, mask.astype(jnp.int32), w1, b1, w2, b2)
    out = pl.pallas_call(
        kern,
        grid=(b, pl.cdiv(s, ts)),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, ts, fp), lambda bi, i: (bi, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, fp), raw.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
    return out[..., :fout]
