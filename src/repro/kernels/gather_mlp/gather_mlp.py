"""Pallas TPU kernel: fused center-normalize → MLP → max-pool (FC step).

The paper's FCU streams each gathered point subset through a 16×16 systolic
array (MLP = 98 % of FC FLOPs) and max-pools into the center.  TPU
adaptation: one fused kernel per subset tile —

    x   = [raw[..., :Dc] − center, raw[..., Dc:]]      (VPU)
    h   = relu(x @ W1 + b1) @ W2 + b2                  (MXU, f32 accum)
    out = max over K                                   (VPU)

so the (TS·K, H) intermediate never touches HBM.  Grid over subset tiles;
weights are small enough to sit whole in VMEM (≤ 256×256 f32 = 256 KB).

VMEM budget per step (TS=8, K=32, D=131, H=128):
  raw tile 8·32·131·4 ≈ 134 KB + hidden 8·32·128·4 ≈ 131 KB + weights.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e38


def _mlp_pool(raw, ctr, w1, b1, w2, b2, dc: int):
    """Shared kernel body: normalize → 2-layer MLP.  -> (TS, K, F)."""
    ts, k, d = raw.shape
    rel = raw[..., :dc] - ctr[:, None, :]
    x = jnp.concatenate([rel, raw[..., dc:]], axis=-1)    # (TS, K, D)
    x2 = x.reshape(ts * k, d)
    h = jax.lax.dot_general(x2, w1, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = jax.nn.relu(h + b1[None, :])
    y = jax.lax.dot_general(h, w2, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + b2[None, :]
    return y.reshape(ts, k, -1)


def _gather_mlp_kernel(raw_ref, ctr_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                       out_ref, *, dc: int):
    y = _mlp_pool(raw_ref[...], ctr_ref[...], w1_ref[...], b1_ref[...],
                  w2_ref[...], b2_ref[...], dc)
    out_ref[...] = jnp.max(y, axis=1).astype(out_ref.dtype)


def _gather_mlp_masked_kernel(raw_ref, ctr_ref, mask_ref, w1_ref, b1_ref,
                              w2_ref, b2_ref, out_ref, *, dc: int):
    """Masked variant (ragged batches): invalid (subset, k) positions go
    to -BIG before the pool; subsets with zero valid positions zero-fill
    instead of returning -BIG."""
    y = _mlp_pool(raw_ref[...], ctr_ref[...], w1_ref[...], b1_ref[...],
                  w2_ref[...], b2_ref[...], dc)
    live = mask_ref[...] != 0                             # (TS, K)
    pooled = jnp.max(jnp.where(live[..., None], y, -BIG), axis=1)
    pooled = jnp.where(live.any(axis=1)[:, None], pooled, 0.0)
    out_ref[...] = pooled.astype(out_ref.dtype)


def gather_mlp_pallas(raw: jnp.ndarray, centers: jnp.ndarray,
                      w1, b1, w2, b2, ts: int = 8,
                      interpret: bool = False, mask=None):
    """raw (S, K, D) gathered inputs; centers (S, Dc) subtracted from the
    leading Dc lanes; two-layer MLP; max over K.  -> (S, F_out).

    ``mask`` (S, K) int32 (nonzero = live) excludes padding positions
    from the pool; rows with no live position return zeros."""
    s, k, d = raw.shape
    dc = centers.shape[1]
    fout = w2.shape[1]
    hdim = w1.shape[1]
    ts = min(ts, s)
    weight_specs = [
        pl.BlockSpec((d, hdim), lambda i: (0, 0)),
        pl.BlockSpec((hdim,), lambda i: (0,)),
        pl.BlockSpec((hdim, fout), lambda i: (0, 0)),
        pl.BlockSpec((fout,), lambda i: (0,)),
    ]
    if mask is None:
        kern = functools.partial(_gather_mlp_kernel, dc=dc)
        in_specs = [
            pl.BlockSpec((ts, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((ts, dc), lambda i: (i, 0)),
            *weight_specs,
        ]
        args = (raw, centers, w1, b1, w2, b2)
    else:
        kern = functools.partial(_gather_mlp_masked_kernel, dc=dc)
        in_specs = [
            pl.BlockSpec((ts, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((ts, dc), lambda i: (i, 0)),
            pl.BlockSpec((ts, k), lambda i: (i, 0)),
            *weight_specs,
        ]
        args = (raw, centers, mask.astype(jnp.int32), w1, b1, w2, b2)
    return pl.pallas_call(
        kern,
        grid=(pl.cdiv(s, ts),),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((ts, fout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, fout), raw.dtype),
        interpret=interpret,
    )(*args)
