"""Pallas TPU kernel: fused center-normalize → MLP → max-pool (FC step).

The paper's FCU streams each gathered point subset through a 16×16 systolic
array (MLP = 98 % of FC FLOPs) and max-pools into the center.  TPU
adaptation: one fused kernel per subset tile —

    x   = [raw[..., :Dc] − center, raw[..., Dc:]]      (VPU)
    h   = relu(x @ W1 + b1) @ W2 + b2                  (MXU, f32 accum)
    out = max over K                                   (VPU)

so the (TS·K, H) intermediate never touches HBM.

Two entry points:

* ``gather_mlp_pallas`` — one cloud, grid over subset tiles (the original
  per-cloud kernel, kept for the eager path and vmap-of-kernels A/B).
* ``gather_mlp_batched_pallas`` — the natively batched serving kernel:
  grid ``(B, ⌈S/TS⌉)``, the batch folded into the grid so ONE pallas_call
  serves the whole cloud stack.  Weights use constant ``lambda b, i:
  (0, 0)`` index maps with ``dimension_semantics=("parallel",
  "arbitrary")`` so Mosaic keeps them VMEM-resident across the entire
  grid; the ``D``/``H``/``F`` lanes are zero-padded to 128-multiples
  before the call (zero lanes are exact no-ops through the matmuls) and
  the output is sliced back, so the MXU always sees aligned tiles.

VMEM budget per grid step (the ``TS`` heuristic solves for this; lane-
padded dims D'=⌈D/128⌉·128 etc., f32):
  streamed (double-buffered):  2·TS·(K·(D'+1) + Dc) · 4 B
      raw tile (TS, K, D') + mask (TS, K) + centers (TS, Dc)
  intermediates:               TS·K·(H'+F') · 4 B      (x@W1, h@W2)
  resident weights:            (D'·H' + H' + H'·F' + F') · 4 B
  output tile:                 TS·F' · 4 B
e.g. TS=64, K=32, D'=H'=F'=128: 2·64·(32·129+3)·4 ≈ 2.1 MB streamed
+ 64·32·256·4 ≈ 2.1 MB intermediates + 130 KB weights < 8 MB default.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import plans
from repro.kernels.tiling import (DEFAULT_VMEM_BUDGET_MB, F32_BYTES, LANE,
                                  gather_mlp_footprint_elems, largest_tile,
                                  pad_axis, round_up)

DEFAULT_SEMANTICS = ("parallel", "arbitrary")

BIG = 3.4e38


def _mlp_pool(raw, ctr, w1, b1, w2, b2, dc: int):
    """Shared kernel body: normalize → 2-layer MLP.  -> (TS, K, F)."""
    ts, k, d = raw.shape
    rel = raw[..., :dc] - ctr[:, None, :]
    x = jnp.concatenate([rel, raw[..., dc:]], axis=-1)    # (TS, K, D)
    x2 = x.reshape(ts * k, d)
    h = jax.lax.dot_general(x2, w1, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = jax.nn.relu(h + b1[None, :])
    y = jax.lax.dot_general(h, w2, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + b2[None, :]
    return y.reshape(ts, k, -1)


def _gather_mlp_kernel(raw_ref, ctr_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                       out_ref, *, dc: int):
    y = _mlp_pool(raw_ref[...], ctr_ref[...], w1_ref[...], b1_ref[...],
                  w2_ref[...], b2_ref[...], dc)
    out_ref[...] = jnp.max(y, axis=1).astype(out_ref.dtype)


def _gather_mlp_masked_kernel(raw_ref, ctr_ref, mask_ref, w1_ref, b1_ref,
                              w2_ref, b2_ref, out_ref, *, dc: int):
    """Masked variant (ragged batches): invalid (subset, k) positions go
    to -BIG before the pool; subsets with zero valid positions zero-fill
    instead of returning -BIG."""
    y = _mlp_pool(raw_ref[...], ctr_ref[...], w1_ref[...], b1_ref[...],
                  w2_ref[...], b2_ref[...], dc)
    live = mask_ref[...] != 0                             # (TS, K)
    pooled = jnp.max(jnp.where(live[..., None], y, -BIG), axis=1)
    pooled = jnp.where(live.any(axis=1)[:, None], pooled, 0.0)
    out_ref[...] = pooled.astype(out_ref.dtype)


def gather_mlp_pallas(raw: jnp.ndarray, centers: jnp.ndarray,
                      w1, b1, w2, b2, ts: int = 8,
                      interpret: bool = False, mask=None):
    """raw (S, K, D) gathered inputs; centers (S, Dc) subtracted from the
    leading Dc lanes; two-layer MLP; max over K.  -> (S, F_out).

    ``mask`` (S, K) int32 (nonzero = live) excludes padding positions
    from the pool; rows with no live position return zeros."""
    s, k, d = raw.shape
    dc = centers.shape[1]
    fout = w2.shape[1]
    hdim = w1.shape[1]
    ts = min(ts, s)
    weight_specs = [
        pl.BlockSpec((d, hdim), lambda i: (0, 0)),
        pl.BlockSpec((hdim,), lambda i: (0,)),
        pl.BlockSpec((hdim, fout), lambda i: (0, 0)),
        pl.BlockSpec((fout,), lambda i: (0,)),
    ]
    if mask is None:
        kern = functools.partial(_gather_mlp_kernel, dc=dc)
        in_specs = [
            pl.BlockSpec((ts, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((ts, dc), lambda i: (i, 0)),
            *weight_specs,
        ]
        args = (raw, centers, w1, b1, w2, b2)
    else:
        kern = functools.partial(_gather_mlp_masked_kernel, dc=dc)
        in_specs = [
            pl.BlockSpec((ts, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((ts, dc), lambda i: (i, 0)),
            pl.BlockSpec((ts, k), lambda i: (i, 0)),
            *weight_specs,
        ]
        args = (raw, centers, mask.astype(jnp.int32), w1, b1, w2, b2)
    return pl.pallas_call(
        kern,
        grid=(pl.cdiv(s, ts),),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((ts, fout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, fout), raw.dtype),
        interpret=interpret,
    )(*args)


# ---- natively batched kernel: grid (B, ceil(S/TS)) --------------------------

def _gather_mlp_batched_kernel(raw_ref, ctr_ref, w1_ref, b1_ref, w2_ref,
                               b2_ref, out_ref, *, dc: int):
    """Blocks carry a leading singleton batch axis: raw (1, TS, K, D)."""
    y = _mlp_pool(raw_ref[...][0], ctr_ref[...][0], w1_ref[...],
                  b1_ref[...], w2_ref[...], b2_ref[...], dc)
    out_ref[...] = jnp.max(y, axis=1)[None].astype(out_ref.dtype)


def _gather_mlp_batched_masked_kernel(raw_ref, ctr_ref, mask_ref, w1_ref,
                                      b1_ref, w2_ref, b2_ref, out_ref,
                                      *, dc: int):
    y = _mlp_pool(raw_ref[...][0], ctr_ref[...][0], w1_ref[...],
                  b1_ref[...], w2_ref[...], b2_ref[...], dc)
    live = mask_ref[...][0] != 0                          # (TS, K)
    pooled = jnp.max(jnp.where(live[..., None], y, -BIG), axis=1)
    pooled = jnp.where(live.any(axis=1)[:, None], pooled, 0.0)
    out_ref[...] = pooled[None].astype(out_ref.dtype)


def gather_mlp_tile_plan(s: int, k: int, d: int, dc: int, hdim: int,
                         fout: int, ts: int | None = None,
                         vmem_budget_mb: float | None = None,
                         lanes: int | None = None,
                         dimension_semantics=None,
                         b: int | None = None) -> dict:
    """Resolve the batched kernel's tile plan: lane-padded dims and the
    subset tile ``TS`` that fills (but does not bust) the VMEM budget.

    Resolution order: explicit ``ts``/``lanes``/``dimension_semantics``
    (the ``kernel_kw`` knobs → ``provenance="override"``) > a
    ``repro.kernels.plans`` store hit for this ``(b, shape)`` cell
    (``"autotuned"``) > the VMEM heuristic at 128 lanes
    (``"heuristic"``).  A stale store entry — one whose recomputed
    footprint busts its own budget — warns and degrades to the
    heuristic instead of raising."""
    dims = {"b": b, "s": s, "k": k, "d": d, "dc": dc, "h": hdim, "f": fout}

    def build(ts, lanes, vmem_budget_mb, sem, provenance):
        lanes = LANE if lanes is None else int(lanes)
        mb = (DEFAULT_VMEM_BUDGET_MB if vmem_budget_mb is None
              else float(vmem_budget_mb))
        sem = DEFAULT_SEMANTICS if sem is None else tuple(sem)
        dp = round_up(d, lanes)
        hp = round_up(hdim, lanes)
        fp = round_up(fout, lanes)
        budget = int(mb * 2 ** 20)

        def fits(t: int) -> bool:
            return F32_BYTES * gather_mlp_footprint_elems(
                t, k, dp, dc, hp, fp) <= budget

        if ts is None:
            ts = largest_tile(s, fits)
        ts = max(1, min(int(ts), s))
        return {"ts": ts, "lanes": lanes, "d_pad": dp, "h_pad": hp,
                "f_pad": fp, "grid_tiles": pl.cdiv(s, ts),
                "vmem_budget_mb": mb,
                "dimension_semantics": sem,
                "footprint_bytes": F32_BYTES * gather_mlp_footprint_elems(
                    ts, k, dp, dc, hp, fp),
                "provenance": provenance}

    overridden = (ts is not None or lanes is not None
                  or dimension_semantics is not None)
    hit = None
    if not overridden and vmem_budget_mb is None and b is not None:
        hit = plans.lookup("gather_mlp", **dims)
    if hit is not None and hit.get("variant") == "vmap":
        # the measurement rejected the batched grid for this cell: the
        # dispatcher runs jax.vmap of the per-cloud kernel instead (no
        # lane padding, ts subsets per grid step per cloud)
        ts_v = max(1, min(int(hit.get("ts", 8)), s))
        plan = {"variant": "vmap", "ts": ts_v, "lanes": 1,
                "d_pad": d, "h_pad": hdim, "f_pad": fout,
                "grid_tiles": pl.cdiv(s, ts_v),
                "vmem_budget_mb": DEFAULT_VMEM_BUDGET_MB,
                "dimension_semantics": DEFAULT_SEMANTICS,
                "footprint_bytes": F32_BYTES * gather_mlp_footprint_elems(
                    ts_v, k, d, dc, hdim, fout),
                "provenance": "autotuned"}
        plans.note_plan("gather_mlp", dims, plan)
        return plan
    if hit is not None:
        plan = build(hit["ts"], hit.get("lanes"), hit.get("vmem_budget_mb"),
                     hit.get("dimension_semantics"), "autotuned")
        if plan["footprint_bytes"] > int(plan["vmem_budget_mb"] * 2 ** 20):
            warnings.warn(
                f"stale tile plan for {plans.plan_key('gather_mlp', dims)}: "
                f"footprint {plan['footprint_bytes']} B busts its "
                f"{plan['vmem_budget_mb']} MB budget; using the heuristic "
                f"(re-run python -m repro.launch.autotune)",
                RuntimeWarning, stacklevel=2)
            plan = build(None, None, None, None, "heuristic")
    else:
        plan = build(ts, lanes, vmem_budget_mb, dimension_semantics,
                     "override" if overridden else "heuristic")
    plans.note_plan("gather_mlp", dims, plan)
    return plan


def gather_mlp_batched_pallas(raw: jnp.ndarray, centers: jnp.ndarray,
                              w1, b1, w2, b2, ts: int | None = None,
                              vmem_budget_mb: float | None = None,
                              lanes: int | None = None,
                              dimension_semantics=None,
                              interpret: bool = False, mask=None):
    """Natively batched gather-MLP: raw (B, S, K, D), centers (B, S, Dc),
    optional mask (B, S, K).  -> (B, S, F_out) in ONE pallas_call with
    grid (B, ⌈S/TS⌉).

    Weights ride constant index maps (VMEM-resident across the grid);
    D/H/F are zero-padded to ``lanes``-multiples (sliced back on
    return); ``ts`` / ``vmem_budget_mb`` / ``lanes`` /
    ``dimension_semantics`` are the ``kernel_kw`` knobs — left None,
    the plan comes from the autotuned store (on a hit) or the VMEM
    heuristic (see :func:`gather_mlp_tile_plan`)."""
    b, s, k, d = raw.shape
    dc = centers.shape[2]
    hdim, fout = w1.shape[1], w2.shape[1]
    plan = gather_mlp_tile_plan(s, k, d, dc, hdim, fout, ts=ts,
                                vmem_budget_mb=vmem_budget_mb,
                                lanes=lanes,
                                dimension_semantics=dimension_semantics,
                                b=b)
    if plan.get("variant") == "vmap":
        # measured winner for this cell is the per-cloud dispatch: B
        # logical per-cloud programs via the pallas batching rule
        per_cloud = functools.partial(gather_mlp_pallas, w1=w1, b1=b1,
                                      w2=w2, b2=b2, ts=plan["ts"],
                                      interpret=interpret)
        if mask is None:
            return jax.vmap(lambda r, c: per_cloud(r, c))(raw, centers)
        return jax.vmap(lambda r, c, mk: per_cloud(r, c, mask=mk))(
            raw, centers, mask)
    ts = plan["ts"]
    dp, hp, fp = plan["d_pad"], plan["h_pad"], plan["f_pad"]

    raw = pad_axis(raw, 3, dp)
    w1 = pad_axis(pad_axis(w1, 1, hp), 0, dp)
    b1 = pad_axis(b1, 0, hp)
    w2 = pad_axis(pad_axis(w2, 1, fp), 0, hp)
    b2 = pad_axis(b2, 0, fp)

    weight_specs = [
        pl.BlockSpec((dp, hp), lambda bi, i: (0, 0)),
        pl.BlockSpec((hp,), lambda bi, i: (0,)),
        pl.BlockSpec((hp, fp), lambda bi, i: (0, 0)),
        pl.BlockSpec((fp,), lambda bi, i: (0,)),
    ]
    data_specs = [
        pl.BlockSpec((1, ts, k, dp), lambda bi, i: (bi, i, 0, 0)),
        pl.BlockSpec((1, ts, dc), lambda bi, i: (bi, i, 0)),
    ]
    if mask is None:
        kern = functools.partial(_gather_mlp_batched_kernel, dc=dc)
        in_specs = data_specs + weight_specs
        args = (raw, centers, w1, b1, w2, b2)
    else:
        kern = functools.partial(_gather_mlp_batched_masked_kernel, dc=dc)
        in_specs = (data_specs
                    + [pl.BlockSpec((1, ts, k), lambda bi, i: (bi, i, 0))]
                    + weight_specs)
        args = (raw, centers, mask.astype(jnp.int32), w1, b1, w2, b2)
    out = pl.pallas_call(
        kern,
        grid=(b, pl.cdiv(s, ts)),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, ts, fp), lambda bi, i: (bi, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, fp), raw.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=tuple(plan["dimension_semantics"])),
        interpret=interpret,
    )(*args)
    return out[..., :fout]
