"""Pure-jnp oracle for the gather_mlp kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

BIG = 3.4e38


def gather_mlp_ref(raw, centers, w1, b1, w2, b2, mask=None):
    """raw (S,K,D), centers (S,Dc) -> (S, F_out).  ``mask`` (S, K) marks
    live positions (None = all); empty rows zero-fill."""
    dc = centers.shape[1]
    rel = raw[..., :dc] - centers[:, None, :]
    x = jnp.concatenate([rel, raw[..., dc:]], axis=-1)
    h = jax.nn.relu(
        jnp.einsum("skd,dh->skh", x, w1,
                   preferred_element_type=jnp.float32) + b1)
    y = jnp.einsum("skh,hf->skf", h, w2,
                   preferred_element_type=jnp.float32) + b2
    if mask is None:
        return jnp.max(y, axis=1).astype(raw.dtype)
    live = mask != 0
    pooled = jnp.max(jnp.where(live[..., None], y, -BIG), axis=1)
    pooled = jnp.where(live.any(axis=1)[:, None], pooled, 0.0)
    return pooled.astype(raw.dtype)
