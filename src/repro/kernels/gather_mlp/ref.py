"""Pure-jnp oracle for the gather_mlp kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_mlp_ref(raw, centers, w1, b1, w2, b2):
    """raw (S,K,D), centers (S,Dc) -> (S, F_out)."""
    dc = centers.shape[1]
    rel = raw[..., :dc] - centers[:, None, :]
    x = jnp.concatenate([rel, raw[..., dc:]], axis=-1)
    h = jax.nn.relu(
        jnp.einsum("skd,dh->skh", x, w1,
                   preferred_element_type=jnp.float32) + b1)
    y = jnp.einsum("skh,hf->skf", h, w2,
                   preferred_element_type=jnp.float32) + b2
    return jnp.max(y, axis=1).astype(raw.dtype)
