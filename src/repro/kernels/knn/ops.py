"""Jitted public wrapper for the KNN kernel.

On CPU (this container) the kernel runs under ``interpret=True``; on TPU it
compiles through Mosaic.  ``knn()`` is the drop-in used by
``core.neighbor`` when ``use_pallas=True``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .knn import knn_pallas
from .ref import knn_ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("k", "tc", "tp", "interpret"))
def knn(centers: jnp.ndarray, points: jnp.ndarray, k: int,
        tc: int = 128, tp: int = 512, interpret: bool | None = None):
    """(S,3),(N,3) -> ((S,k) sq-dists, (S,k) int32 indices)."""
    if interpret is None:
        interpret = _interpret_default()
    return knn_pallas(centers, points, k, tc=tc, tp=tp,
                      interpret=interpret)


__all__ = ["knn", "knn_ref"]
