"""Pallas TPU kernel: brute-force K-nearest-neighbor search.

The DSU hot spot (PointACC's "ranking kernel": 16 distance calculators +
32-way bitonic sorter).  TPU adaptation: distances are an MXU problem
(|c−p|² = |c|² + |p|² − 2c·p, the cross term is a matmul), and the ranking
is K rounds of vectorized min-extraction — no bitonic network, because K
(≤64) ≪ N and VPU argmin reductions are wide.  ``lax.sort`` is avoided
entirely (unsupported in Mosaic).

Tiling: grid over center tiles of TC; the point set is streamed in tiles
of TP through VMEM.  Per tile, the candidate row is the concatenation of
the streamed distance tile (TC, TP) and the running best (TC, K); K rounds
of (argmin, record, mask) rebuild the running best — ascending by
construction, so the merge is exact.

VMEM budget per step: TC·(TP+K) dist row + points tile + outputs
≈ 128·(512+64)·4 B ≈ 300 KB — well inside v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e38  # python float: jnp scalars would be captured as consts


def _knn_kernel(centers_ref, points_ref, dists_ref, idx_ref, *, k: int,
                tp: int, n_points: int):
    """One center tile vs. all points (streamed in TP tiles).

    centers_ref: (TC, 3) f32     points_ref: (N, 3) f32 (full, VMEM)
    dists_ref:   (TC, K) f32     idx_ref:    (TC, K) i32
    """
    tc = centers_ref.shape[0]
    c = centers_ref[...]                                  # (TC, 3)
    c2 = jnp.sum(c * c, axis=-1, keepdims=True)           # (TC, 1)

    best_d = jnp.full((tc, k), BIG, jnp.float32)
    best_i = jnp.full((tc, k), -1, jnp.int32)

    n_tiles = pl.cdiv(n_points, tp)

    def tile_body(t, carry):
        best_d, best_i = carry
        p = points_ref[pl.dslice(t * tp, tp), :]          # (TP, 3)
        p2 = jnp.sum(p * p, axis=-1)[None, :]             # (1, TP)
        cross = jax.lax.dot_general(
            c, p, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (TC, TP) MXU
        d = c2 + p2 - 2.0 * cross                         # (TC, TP)
        gidx = t * tp + jax.lax.broadcasted_iota(jnp.int32, (tc, tp), 1)
        d = jnp.where(gidx < n_points, d, BIG)            # mask tail pad

        # candidate row: streamed tile ++ running best (exact k-merge by
        # K rounds of select-min)
        cand_d = jnp.concatenate([d, best_d], axis=1)     # (TC, TP+K)
        cand_i = jnp.concatenate([gidx, best_i], axis=1)

        def extract(j, carry2):
            best_d, best_i, cand_d = carry2
            am = jnp.argmin(cand_d, axis=-1)              # (TC,)
            m = jnp.take_along_axis(cand_d, am[:, None], 1)[:, 0]
            mi = jnp.take_along_axis(cand_i, am[:, None], 1)[:, 0]
            best_d = best_d.at[:, j].set(m)
            best_i = best_i.at[:, j].set(mi)
            cand_d = jnp.where(
                jax.lax.broadcasted_iota(jnp.int32, cand_d.shape, 1)
                == am[:, None], BIG, cand_d)
            return best_d, best_i, cand_d

        new_d = jnp.full((tc, k), BIG, jnp.float32)
        new_i = jnp.full((tc, k), -1, jnp.int32)
        best_d, best_i, _ = jax.lax.fori_loop(
            0, k, extract, (new_d, new_i, cand_d))
        return best_d, best_i

    best_d, best_i = jax.lax.fori_loop(0, n_tiles, tile_body,
                                       (best_d, best_i))
    dists_ref[...] = best_d
    idx_ref[...] = best_i


def knn_pallas(centers: jnp.ndarray, points: jnp.ndarray, k: int,
               tc: int = 128, tp: int = 512,
               interpret: bool = False):
    """(S,3) centers, (N,3) points -> (S,k) dists, (S,k) int32 indices.

    Indices are exact nearest-first; ties broken by lower index (matches
    ref.py's lexicographic (distance, index) order).
    """
    s = centers.shape[0]
    n = points.shape[0]
    tc = min(tc, s)
    tp = min(tp, n)
    # pad the point set to a tile multiple: pl.dslice clamps out-of-bounds
    # starts (dynamic_slice semantics), which would misalign the last tile
    n_pad = ((n + tp - 1) // tp) * tp
    points = jnp.pad(points.astype(jnp.float32),
                     ((0, n_pad - n), (0, 0)))
    grid = (pl.cdiv(s, tc),)
    kern = functools.partial(_knn_kernel, k=k, tp=tp, n_points=n)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tc, 3), lambda i: (i, 0)),
            pl.BlockSpec((n_pad, 3), lambda i: (0, 0)),  # full (padded) points in VMEM
        ],
        out_specs=[
            pl.BlockSpec((tc, k), lambda i: (i, 0)),
            pl.BlockSpec((tc, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, k), jnp.float32),
            jax.ShapeDtypeStruct((s, k), jnp.int32),
        ],
        interpret=interpret,
    )(centers.astype(jnp.float32), points.astype(jnp.float32))
