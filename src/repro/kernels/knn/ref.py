"""Pure-jnp oracle for the KNN kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def knn_ref(centers: jnp.ndarray, points: jnp.ndarray, k: int):
    """(S,3),(N,3) -> (S,k) sq-dists ascending, (S,k) int32 indices.
    Ties broken by lower point index (lexicographic (d, idx))."""
    c = centers.astype(jnp.float32)
    p = points.astype(jnp.float32)
    d = jnp.sum((c[:, None, :] - p[None, :, :]) ** 2, axis=-1)   # (S, N)
    idx = jnp.argsort(d, axis=-1, stable=True)[:, :k]
    dd = jnp.take_along_axis(d, idx, axis=-1)
    return dd, idx.astype(jnp.int32)
