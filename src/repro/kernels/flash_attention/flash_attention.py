"""Pallas TPU kernel: causal flash attention (forward), GQA-aware.

Used by the LM stack's prefill path (beyond-paper perf work, §Perf).
Standard online-softmax tiling: grid (batch·q_heads, q_tiles, kv_tiles);
running (m, l, acc) live in VMEM scratch across the kv_tile axis and the
output block is written at the last kv tile.  GQA never materializes
expanded K/V: the K/V BlockSpec index maps q-head → kv-head.

Causality is enforced by masking inside the tile; fully-masked kv tiles
are skipped via ``pl.when`` on the tile index (no wasted MXU passes past
the diagonal).

VMEM budget per step (TQ=TK=128, D=128, f32 accum):
  q/k/v tiles 3·128·128·4 ≈ 196 KB + scores 128·128·4 + acc ≈ 130 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, tq: int, tk: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip tiles strictly above the diagonal (causal)
    run = (not causal) or (ki * tk <= qi * tq + tq - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (TQ, D)
        k = k_ref[0].astype(jnp.float32)                  # (TK, D)
        v = v_ref[0].astype(jnp.float32)                  # (TK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            rows = qi * tq + jax.lax.broadcasted_iota(
                jnp.int32, (tq, tk), 0)
            cols = ki * tk + jax.lax.broadcasted_iota(
                jnp.int32, (tq, tk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_scr[...]                               # (TQ, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # (TQ, TK)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           tq: int = 128, tk: int = 128,
                           interpret: bool = False):
    """q (B, Hq, Sq, D); k/v (B, Hkv, Skv, D), Hq % Hkv == 0.
    -> (B, Hq, Sq, D), same dtype as q."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    tq = min(tq, sq)
    tk = min(tk, skv)
    scale = 1.0 / (d ** 0.5)

    q4 = q.reshape(b * hq, sq, d)
    k4 = k.reshape(b * hkv, skv, d)
    v4 = v.reshape(b * hkv, skv, d)

    def kv_map(bh, qi, ki):
        return (bh // group, ki, 0)

    kern = functools.partial(_flash_kernel, scale=scale, tq=tq, tk=tk,
                             causal=causal)
    out = pl.pallas_call(
        kern,
        grid=(b * hq, pl.cdiv(sq, tq), pl.cdiv(skv, tk)),
        in_specs=[
            pl.BlockSpec((1, tq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, tk, d), kv_map),
            pl.BlockSpec((1, tk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, tq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q4, k4, v4)
    return out.reshape(b, hq, sq, d)
