"""Jitted public wrapper for flash attention."""
from __future__ import annotations

from functools import partial

import jax

from .flash_attention import flash_attention_pallas
from .ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "tq", "tk", "interpret"))
def flash_attention(q, k, v, causal: bool = True, tq: int = 128,
                    tk: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_attention_pallas(q, k, v, causal=causal, tq=tq, tk=tk,
                                  interpret=interpret)


__all__ = ["flash_attention", "attention_ref"]
