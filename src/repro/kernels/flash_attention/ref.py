"""Pure-jnp oracle for flash attention (GQA, causal)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """q (B,Hq,Sq,D), k/v (B,Hkv,Skv,D) -> (B,Hq,Sq,D)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32))
    return o.astype(q.dtype)
