"""Deterministic fault injection for the serving layer.

A :class:`FaultPlan` maps *dispatch steps* (0-based, counted across all
buckets in firing order) to faults, and wraps the per-bucket engine
callable so chosen dispatches misbehave on purpose:

* ``fail``  — raise :class:`InjectedFault` instead of running;
* ``nan``   — run the engine, then poison the output with NaNs (the
  dispatcher must *detect* this — a backend returning garbage is a
  fault even when nothing raised);
* ``slow``  — run the engine, then stall ``arg_ms`` (latency spike;
  drives deadline/shed behavior downstream).

Plans are data, not chance: an explicit plan lists its steps
(``FaultPlan.parse("fail@1,nan@3,slow@5:80")`` — the ``--faults`` CLI
syntax), and a randomized plan is *pre-sampled* from a seed into the
same explicit form (``FaultPlan.bernoulli``), so a chaos trace replays
identically in tests, ``launch/serve.py --faults`` and CI.  The step
counter lives on the plan and is drawn under a lock; the async
dispatcher calls :meth:`FaultPlan.draw` at *fire* time (in firing
order, under its own lock) and :meth:`FaultPlan.apply` later on an
executor thread, so steps stay deterministic even when several batches
are in flight and complete out of order.  ``plan.wrap(fn)`` composes
the two for synchronous callers and may wrap many per-bucket
callables; they all advance the one shared counter, matching the
server's global dispatch order.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

KINDS = ("fail", "nan", "slow")


class InjectedFault(RuntimeError):
    """The exception a ``fail`` step raises — a distinct type so tests
    and the dispatcher's failure records can tell injected chaos from
    organic engine bugs."""

    def __init__(self, step: int):
        self.step = step
        super().__init__(f"injected engine fault at dispatch step {step}")


@dataclass(frozen=True)
class Fault:
    """One injected misbehavior: ``kind`` ∈ {fail, nan, slow}; ``arg``
    is the stall in ms for ``slow`` (unused otherwise)."""
    kind: str
    arg: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; pick from {KINDS}")


class FaultPlan:
    """A deterministic schedule of faults over dispatch steps.

    ``events`` maps step index -> :class:`Fault`.  ``wrap(fn)`` returns
    a callable with ``fn``'s signature that consults (and advances) the
    plan's shared step counter on every call.
    """

    def __init__(self, events: dict[int, Fault] | None = None, *,
                 sleep=time.sleep):
        self.events = {int(k): v for k, v in (events or {}).items()}
        bad = [k for k in self.events if k < 0]
        if bad:
            raise ValueError(f"fault steps must be >= 0, got {sorted(bad)}")
        self.sleep = sleep           # injectable for fake-clock tests
        self.step = 0                # next dispatch's index
        self.injected: list[tuple[int, str]] = []   # (step, kind) fired
        self._lock = threading.Lock()   # draw() is called at fire time,
                                        # possibly from several threads

    # -- construction --------------------------------------------------------

    @classmethod
    def parse(cls, spec: str, *, sleep=time.sleep) -> "FaultPlan":
        """Parse the CLI syntax: comma-separated ``kind@step[:arg_ms]``
        items, e.g. ``"fail@1,nan@3,slow@5:80"``."""
        events: dict[int, Fault] = {}
        for item in filter(None, (s.strip() for s in spec.split(","))):
            try:
                kind, rest = item.split("@", 1)
                step, _, arg = rest.partition(":")
                fault = Fault(kind.strip(), float(arg) if arg else 0.0)
                step = int(step)
            except (ValueError, TypeError) as e:
                raise ValueError(
                    f"bad fault item {item!r} (want kind@step[:arg_ms], "
                    f"kind in {KINDS}): {e}") from e
            if step in events:
                raise ValueError(f"duplicate fault step {step} in {spec!r}")
            events[step] = fault
        return cls(events, sleep=sleep)

    @classmethod
    def bernoulli(cls, *, seed: int, n_steps: int, p_fail: float = 0.0,
                  p_nan: float = 0.0, p_slow: float = 0.0,
                  slow_ms: float = 50.0, sleep=time.sleep) -> "FaultPlan":
        """Pre-sample a randomized plan over ``n_steps`` dispatches.
        Sampling happens here, once, from ``seed`` — the resulting plan
        is explicit and replays identically."""
        rng = np.random.default_rng(seed)
        events: dict[int, Fault] = {}
        for step in range(n_steps):
            u = rng.uniform()
            if u < p_fail:
                events[step] = Fault("fail")
            elif u < p_fail + p_nan:
                events[step] = Fault("nan")
            elif u < p_fail + p_nan + p_slow:
                events[step] = Fault("slow", slow_ms)
        return cls(events, sleep=sleep)

    # -- injection -----------------------------------------------------------

    def draw(self) -> tuple[int, Fault | None]:
        """Consume one dispatch step (thread-safe): returns the step
        index and the fault scheduled for it (``None`` for a clean
        step), advancing the shared counter and recording the
        injection.  The dispatcher draws at *fire* time, under its own
        lock, so step order matches firing order even when execution
        completes out of order on executor threads."""
        with self._lock:
            step, self.step = self.step, self.step + 1
            fault = self.events.get(step)
            if fault is not None:
                self.injected.append((step, fault.kind))
            return step, fault

    def apply(self, fn, batch, step: int, fault: Fault | None):
        """Execute ``fn(batch)`` under a previously drawn fault:
        ``fail`` raises :class:`InjectedFault` before the engine runs,
        ``nan`` poisons the output, ``slow`` stalls after it;
        ``fault=None`` is the clean path.  Split from :meth:`draw` so
        drawing (ordered, at fire time) and execution (later, on an
        executor thread) need not share a thread."""
        if fault is not None and fault.kind == "fail":
            raise InjectedFault(step)
        out = fn(batch)
        if fault is None:
            return out
        if fault.kind == "nan":
            out = np.asarray(out).copy()
            out[...] = np.nan
            return out
        self.sleep(fault.arg / 1e3)      # "slow"
        return out

    def next_fault(self) -> Fault | None:
        """Consume one step of the plan (compat shim over
        :meth:`draw`): returns the fault scheduled for the current
        dispatch, advancing the shared counter."""
        return self.draw()[1]

    def wrap(self, fn):
        """Wrap one engine callable (draw + apply at call time; the
        synchronous composition).  Every wrapped callable advances the
        plan's one shared step counter in dispatch order."""
        def faulty(batch):
            step, fault = self.draw()
            return self.apply(fn, batch, step, fault)
        return faulty

    def summary(self) -> dict:
        """Report block: what was planned and what actually fired."""
        return {
            "planned": {str(k): v.kind for k, v in
                        sorted(self.events.items())},
            "injected": [{"step": s, "kind": k} for s, k in self.injected],
            "steps_seen": self.step,
        }

    def __repr__(self):
        ev = ",".join(f"{v.kind}@{k}" for k, v in sorted(self.events.items()))
        return f"FaultPlan({ev or 'empty'}, step={self.step})"
