"""Per-bucket circuit breaker: stop hammering a backend that keeps
failing.

Classic three-state machine, one instance per bucket (failures are
per-executable — one bucket's broken tile plan must not take out the
others):

* **closed** — healthy.  Every dispatch tries the primary backend;
  ``fail_streak`` consecutive failures trip the breaker.
* **open** — the primary is presumed broken.  ``allow_primary()`` says
  no (the dispatcher goes straight to its fallback, or fails fast) so
  a persistently-broken bucket *degrades* instead of re-raising the
  same fault at every dispatch.  After ``cooldown_s`` the next
  ``allow_primary()`` transitions to half-open and grants one probe.
* **half-open** — exactly one probe dispatch is in flight on the
  primary.  Success closes the breaker; failure re-opens it (fresh
  cooldown).  While the probe is out, further ``allow_primary()``
  calls keep saying no.

The clock is injected (same pattern as the dispatcher timeout) so the
open → half-open → closed walk is deterministic under a fake clock.

With async dispatch the two sides of the protocol split across time:
``allow_primary()`` is consulted at *fire* time (under the server
lock, in firing order) and ``record_success``/``record_failure`` land
at *completion* time, when the in-flight batch resolves.  Several
batches fired before the first failure completes may all try the
primary — the breaker judges verdicts in completion order, which is
the only order that exists for an async pipeline.  The instance itself
is not locked; the dispatcher serializes access under its own lock.
"""
from __future__ import annotations

import time

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class CircuitBreaker:
    """Consecutive-failure breaker with a timed half-open probe.

    Parameters
    ----------
    fail_streak: consecutive primary failures that trip the breaker.
    cooldown_s:  how long the breaker stays open before granting a
                 half-open probe.
    clock:       injectable monotonic clock.
    """

    def __init__(self, fail_streak: int = 3, cooldown_s: float = 1.0,
                 clock=time.monotonic):
        if fail_streak < 1:
            raise ValueError(f"fail_streak must be >= 1, got {fail_streak}")
        self.fail_streak = int(fail_streak)
        self.cooldown_s = float(cooldown_s)
        self.clock = clock
        self.state = CLOSED
        self.failures = 0            # current consecutive-failure run
        self.opened_at: float | None = None
        self.open_count = 0          # times the breaker tripped (metrics)

    def allow_primary(self) -> bool:
        """May the next dispatch try the primary backend?

        Transitions open → half-open (and grants the probe) when the
        cooldown has elapsed; in half-open, the probe slot is already
        taken, so the answer is no until it reports back."""
        if self.state == CLOSED:
            return True
        if self.state == OPEN:
            if self.clock() - self.opened_at >= self.cooldown_s:
                self.state = HALF_OPEN
                return True
            return False
        return False                 # HALF_OPEN: probe already in flight

    def record_success(self):
        """Primary dispatch (or half-open probe) succeeded."""
        self.state = CLOSED
        self.failures = 0
        self.opened_at = None

    def record_failure(self):
        """Primary dispatch (or half-open probe) failed."""
        self.failures += 1
        if self.state == HALF_OPEN or self.failures >= self.fail_streak:
            if self.state != OPEN:
                self.open_count += 1
            self.state = OPEN
            self.opened_at = self.clock()

    def snapshot(self) -> dict:
        """Metrics view: current state + trip count."""
        return {"state": self.state, "failures": self.failures,
                "open_count": self.open_count}

    def __repr__(self):
        return (f"CircuitBreaker({self.state}, failures={self.failures}/"
                f"{self.fail_streak}, cooldown={self.cooldown_s}s)")
