"""Admission queue: where ragged traffic meets the bucket policy — and
the admission *guard*: where poisoned or excess traffic is refused.

``submit`` validates a request before anything else touches it —
shape/dtype policy and NaN/Inf rejection via the engine's own
:func:`~repro.engine.params.validate_cloud` (a non-finite cloud that
reaches a jit-compiled kernel corrupts its whole batch silently, so it
must be stopped here, with a structured
:class:`~repro.serve.errors.ValidationError`), then assigns it the
tightest bucket and a per-request PRNG key, stamps its arrival time
(and deadline, if any) and appends it to that bucket's FIFO lane.

Lanes are *bounded*: ``max_lane_depth`` caps how many requests a bucket
may hold, and a submit into a full lane is shed with
:class:`~repro.serve.errors.QueueFullError` (tail drop — the newest
request is refused; everything already admitted keeps its FIFO place
and its bounded queue wait).  An unbounded queue under overload is a
latency time bomb: every admitted request would wait behind the whole
backlog.

Lanes keep arrival order *within* a bucket — the dispatcher drains each
lane front-first, so no request can be overtaken by a later one of the
same bucket (the starvation bound is the dispatch timeout, not queue
discipline).

The queue is host-side only: payloads stay numpy until the dispatcher
pads a fired lane slice into a device :class:`~repro.engine.Batch`.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np

from .buckets import Bucket, BucketSet
from .errors import AdmissionError, QueueFullError, ValidationError


def key_data(key) -> np.ndarray:
    """Canonicalize a JAX PRNG key (typed or raw uint32) to host (2,)
    uint32 — the form :meth:`Batch.make` stacks per cloud."""
    import jax
    if jax.dtypes.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return np.asarray(key, np.uint32)


@dataclass
class Request:
    """One admitted cloud waiting for (or answered by) a dispatch."""
    rid: int
    xyz: np.ndarray                  # (Ni, 3) float32
    feats: np.ndarray | None         # (Ni, F) float32 or None
    key: np.ndarray                  # (2,) uint32 raw PRNG key data
    bucket: Bucket
    t_arrival: float
    t_deadline: float | None = None  # absolute clock value; None = none

    @property
    def n_points(self) -> int:
        return self.xyz.shape[0]


class AdmissionQueue:
    """Per-bucket bounded FIFO lanes behind the admission guard.

    ``max_lane_depth``: per-bucket queue bound (None = unbounded, the
    pre-backpressure behavior).  ``validate``: run the payload guard on
    every submit (on by default; the engine benchmark loops that
    synthesize their own clouds may turn it off).
    """

    def __init__(self, buckets: BucketSet, *,
                 max_lane_depth: int | None = None, validate: bool = True):
        if max_lane_depth is not None and max_lane_depth < 1:
            raise ValueError(
                f"max_lane_depth must be >= 1 (or None), got "
                f"{max_lane_depth}")
        self.buckets = buckets
        self.max_lane_depth = max_lane_depth
        self.validate = validate
        self._lanes: dict[tuple[int, int], deque[Request]] = {
            b.key: deque() for b in buckets}
        self._next_rid = 0

    def submit(self, xyz, feats, key, now: float,
               t_deadline: float | None = None) -> Request:
        """Admit one cloud; raises :class:`ValidationError` for a bad
        payload, :class:`AdmissionError` if no bucket fits,
        :class:`QueueFullError` if the bucket's lane is at its depth
        bound.  Returns the enqueued :class:`Request`."""
        from repro.engine.params import validate_cloud
        xyz = np.asarray(xyz)
        if xyz.ndim != 2 or xyz.shape[-1] != 3:
            raise ValidationError(
                f"a request is one cloud, shape (N, 3); got {xyz.shape}")
        if self.validate:
            try:
                xyz = validate_cloud(xyz, "xyz")
            except ValueError as e:
                raise ValidationError(str(e)) from e
        else:
            xyz = np.asarray(xyz, np.float32)
        if feats is not None:
            feats = np.asarray(feats)
            if feats.ndim != 2 or feats.shape[0] != xyz.shape[0]:
                raise ValidationError(
                    f"feats must be (N, F) aligned with xyz "
                    f"({xyz.shape[0]} points); got "
                    f"{getattr(feats, 'shape', None)}")
            if self.validate:
                try:
                    feats = validate_cloud(feats, "feats")
                except ValueError as e:
                    raise ValidationError(str(e)) from e
            else:
                feats = np.asarray(feats, np.float32)
        bucket = self.buckets.bucket_for(xyz.shape[0])
        lane = self._lanes[bucket.key]
        if (self.max_lane_depth is not None
                and len(lane) >= self.max_lane_depth):
            raise QueueFullError(bucket.key, len(lane))
        req = Request(
            rid=self._next_rid, xyz=xyz, feats=feats,
            key=key_data(key), bucket=bucket, t_arrival=now,
            t_deadline=t_deadline)
        self._next_rid += 1
        lane.append(req)
        return req

    def lane(self, bucket: Bucket) -> deque:
        return self._lanes[bucket.key]

    def take(self, bucket: Bucket, count: int) -> list[Request]:
        """Pop up to ``count`` requests from the lane front (FIFO)."""
        lane = self._lanes[bucket.key]
        return [lane.popleft() for _ in range(min(count, len(lane)))]

    def shed_expired(self, now: float) -> list[Request]:
        """Remove (from any lane position) every queued request whose
        deadline has passed — device compute spent on them would be
        wasted; the dispatcher records them as deadline misses.
        Surviving requests keep their FIFO order."""
        shed: list[Request] = []
        for key, lane in self._lanes.items():
            expired = {r.rid for r in lane
                       if r.t_deadline is not None and now >= r.t_deadline}
            if expired:
                shed.extend(r for r in lane if r.rid in expired)
                self._lanes[key] = deque(
                    r for r in lane if r.rid not in expired)
        return shed

    def pending(self) -> int:
        return sum(len(lane) for lane in self._lanes.values())

    def pending_rids(self) -> set[int]:
        """The rids currently queued (the dispatcher's unknown-rid
        diagnosis needs to tell pending from never-submitted)."""
        return {r.rid for lane in self._lanes.values() for r in lane}

    def oldest_wait(self, bucket: Bucket, now: float) -> float:
        """Age of the lane's front request (0.0 for an empty lane)."""
        lane = self._lanes[bucket.key]
        return (now - lane[0].t_arrival) if lane else 0.0
