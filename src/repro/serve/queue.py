"""Admission queue: where ragged traffic meets the bucket policy.

``submit`` validates a request (non-empty, fits some bucket), assigns it
the tightest bucket and a per-request PRNG key, stamps its arrival time
and appends it to that bucket's FIFO lane.  Lanes keep arrival order
*within* a bucket — the dispatcher drains each lane front-first, so no
request can be overtaken by a later one of the same bucket (the
starvation bound is the dispatch timeout, not queue discipline).

The queue is host-side only: payloads stay numpy until the dispatcher
pads a fired lane slice into a device :class:`~repro.engine.Batch`.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np

from .buckets import AdmissionError, Bucket, BucketSet


def key_data(key) -> np.ndarray:
    """Canonicalize a JAX PRNG key (typed or raw uint32) to host (2,)
    uint32 — the form :meth:`Batch.make` stacks per cloud."""
    import jax
    if jax.dtypes.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return np.asarray(key, np.uint32)


@dataclass
class Request:
    """One admitted cloud waiting for (or answered by) a dispatch."""
    rid: int
    xyz: np.ndarray                  # (Ni, 3) float32
    feats: np.ndarray | None         # (Ni, F) float32 or None
    key: np.ndarray                  # (2,) uint32 raw PRNG key data
    bucket: Bucket
    t_arrival: float

    @property
    def n_points(self) -> int:
        return self.xyz.shape[0]


class AdmissionQueue:
    """Per-bucket FIFO lanes with admission-time validation."""

    def __init__(self, buckets: BucketSet):
        self.buckets = buckets
        self._lanes: dict[tuple[int, int], deque[Request]] = {
            b.key: deque() for b in buckets}
        self._next_rid = 0

    def submit(self, xyz, feats, key, now: float) -> Request:
        """Admit one cloud; raises :class:`AdmissionError` if no bucket
        fits.  Returns the enqueued :class:`Request`."""
        xyz = np.asarray(xyz, np.float32)
        if xyz.ndim != 2 or xyz.shape[-1] != 3:
            raise AdmissionError(
                f"a request is one cloud, shape (N, 3); got {xyz.shape}")
        bucket = self.buckets.bucket_for(xyz.shape[0])
        req = Request(
            rid=self._next_rid, xyz=xyz,
            feats=None if feats is None else np.asarray(feats, np.float32),
            key=key_data(key), bucket=bucket, t_arrival=now)
        self._next_rid += 1
        self._lanes[bucket.key].append(req)
        return req

    def lane(self, bucket: Bucket) -> deque:
        return self._lanes[bucket.key]

    def take(self, bucket: Bucket, count: int) -> list[Request]:
        """Pop up to ``count`` requests from the lane front (FIFO)."""
        lane = self._lanes[bucket.key]
        return [lane.popleft() for _ in range(min(count, len(lane)))]

    def pending(self) -> int:
        return sum(len(lane) for lane in self._lanes.values())

    def oldest_wait(self, bucket: Bucket, now: float) -> float:
        """Age of the lane's front request (0.0 for an empty lane)."""
        lane = self._lanes[bucket.key]
        return (now - lane[0].t_arrival) if lane else 0.0
