"""Synthetic ragged arrival traces and their replay loop.

A trace models the traffic shape real PCN serving sees (the HgPCN
argument: system-level integration, not isolated kernels, is where
speedups die): request *arrivals* are a Poisson process (exponential
inter-arrival gaps at ``rate_hz``) and cloud *sizes* are log-normal —
a long right tail of big scans over a mass of small objects — clipped
to the served range.  Both streams are seeded and deterministic.

``replay`` pushes a trace through a :class:`PCNServer` in real time:
sleep until each arrival (in short slices, polling so timeouts keep
firing between arrivals), submit, and drain at the end.  If the engine
falls behind the arrival rate the backlog simply grows and queue-wait
percentiles show it — that is the measurement, not an error.

Replay composes with async dispatch unchanged: ``submit``/``poll``
never block on device compute (batches go in flight and the loop keeps
admitting, which is the whole point), the trailing ``drain`` *joins*
every in-flight batch, and service/queue-wait attribution stays
correct because the metrics layer stamps service at execution start →
completion, not at fire.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class TraceEvent:
    t: float                         # arrival offset from trace start (s)
    n_points: int


def synthetic_trace(*, n_requests: int, rate_hz: float, n_median: int,
                    sigma: float = 0.35, n_min: int = 16,
                    n_max: int | None = None,
                    seed: int = 0) -> list[TraceEvent]:
    """Poisson arrivals at ``rate_hz``; log-normal sizes with median
    ``n_median`` and log-std ``sigma``, clipped to [n_min, n_max]."""
    rng = np.random.default_rng(seed)
    if n_requests < 1:
        return []
    gaps = rng.exponential(1.0 / rate_hz, n_requests)
    ts = np.cumsum(gaps) - gaps[0]           # first arrival at t=0
    sizes = np.round(rng.lognormal(np.log(n_median), sigma,
                                   n_requests)).astype(int)
    sizes = np.clip(sizes, n_min,
                    n_max if n_max is not None else sizes.max())
    return [TraceEvent(float(t), int(n)) for t, n in zip(ts, sizes)]


def replay(server, events, make_request, *, sleep=time.sleep,
           deadline_s: float | None = None) -> list[int | None]:
    """Replay ``events`` through ``server`` in real time.

    ``make_request(n_points, index) -> (xyz, feats)`` synthesizes each
    cloud (feats may be None).  Returns one entry per event, in
    submission order: the rid, or ``None`` for a request the admission
    guard shed (queue full / invalid payload — already counted in the
    server's ``faults`` metrics; under chaos or overload, sheds are
    part of the measurement, not an abort).  Every admitted rid has an
    outcome after the trailing ``drain``.

    ``deadline_s`` stamps each submitted request with that TTL (on top
    of the server-level default when None).
    """
    from .errors import AdmissionError

    t0 = server.clock()
    rids: list[int | None] = []
    for i, ev in enumerate(events):
        while True:
            dt = (t0 + ev.t) - server.clock()
            if dt <= 0:
                break
            server.poll()                    # timeouts fire while we wait
            sleep(min(dt, max(server.timeout_s / 4, 1e-4)))
        xyz, feats = make_request(ev.n_points, i)
        try:
            rids.append(server.submit(xyz, feats, deadline_s=deadline_s))
        except AdmissionError:
            rids.append(None)                # shed at the door; counted
                                             # by the admission guard
        server.poll()
    server.drain()
    return rids
