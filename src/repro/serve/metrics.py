"""Per-request latency accounting for the serving layer.

Every admitted request gets three timestamps — arrival (``submit``),
dispatch (its batch fired) and done (logits materialized) — from which
the report derives the three serving latencies:

    queue_wait = t_dispatch - t_arrival     (batching/timeout cost)
    service    = t_done     - t_dispatch    (engine execution, shared by
                                             the whole batch)
    e2e        = t_done     - t_arrival     (what the user sees)

reported as p50/p95/p99/mean/max in milliseconds, alongside throughput
(requests per second over the active window) and padding waste — the
fraction of padded (B, N) slots·rows that carried no real points, the
price of quantizing ragged traffic onto pre-compiled bucket shapes.

With async dispatch, ``t_dispatch`` is stamped when execution actually
*begins* on an executor thread and ``t_done`` at completion — so
service time is attributed at completion, and time spent queued behind
a full in-flight table lands in queue-wait where it belongs.  The
``overlap`` report section quantifies the concurrency itself:
in-flight depth at fire time, busy time vs its interval union
(``overlap_pct``), and the device-idle gap the sync dispatcher pays.

The failure-handling layer reports through the same object: a
``faults`` section counts everything that did *not* go down the happy
path — admission rejections (``rejected_invalid``,
``shed_queue_full``), post-admission sheds (``deadline_miss``),
dispatch outcomes (``degraded_dispatches`` answered by the fallback
backend, ``failed_dispatches``/``failed_requests`` that surfaced a
structured :class:`~repro.serve.errors.RequestError`) and breaker
trips (``breaker_opened``) — so a chaos trace's report shows exactly
how much traffic was refused, degraded or failed, per counter.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field

import numpy as np

#: the fault counters every report carries (schema-stable: all present,
#: zero when nothing went wrong)
FAULT_COUNTERS = (
    "rejected_invalid",      # admission: ValidationError (bad payload)
    "shed_queue_full",       # admission: QueueFullError (backpressure)
    "deadline_miss",         # queued request shed past its deadline
    "degraded_dispatches",   # answered by the fallback backend
    "failed_dispatches",     # batch failed outright (primary + fallback)
    "failed_requests",       # requests riding failed batches + sheds
    "breaker_opened",        # circuit-breaker trips across buckets
)

PERCENTILES = (50, 95, 99)


def percentile_summary(xs) -> dict:
    """{"p50", "p95", "p99", "mean", "max"} of a sample (ms in, ms out);
    all-zero on an empty sample so reports stay schema-stable."""
    if len(xs) == 0:
        return {f"p{q}": 0.0 for q in PERCENTILES} | {"mean": 0.0,
                                                      "max": 0.0}
    a = np.asarray(xs, np.float64)
    out = {f"p{q}": float(np.percentile(a, q)) for q in PERCENTILES}
    out["mean"] = float(a.mean())
    out["max"] = float(a.max())
    return out


@dataclass(frozen=True)
class RequestRecord:
    """Timing of one answered request."""
    rid: int
    bucket: tuple[int, int]          # (batch, n_points)
    n_points: int                    # true (unpadded) size
    t_arrival: float
    t_dispatch: float
    t_done: float

    @property
    def queue_wait_s(self) -> float:
        return self.t_dispatch - self.t_arrival

    @property
    def service_s(self) -> float:
        return self.t_done - self.t_dispatch

    @property
    def e2e_s(self) -> float:
        return self.t_done - self.t_arrival


@dataclass(frozen=True)
class DispatchRecord:
    """One fired batch."""
    bucket: tuple[int, int]
    n_requests: int                  # real requests in the batch
    valid_points: int                # sum of true sizes
    partial: bool                    # fired by timeout below capacity
    service_s: float
    degraded: bool = False           # answered by the fallback backend
    t_start: float = 0.0             # execution began (clock value)
    t_done: float = 0.0              # outputs materialized
    depth: int = 1                   # in-flight batches incl. this one


@dataclass
class ServeMetrics:
    """Accumulates request/dispatch records plus the fault counters;
    ``report()`` renders the benchmark-JSON section."""
    requests: list = field(default_factory=list)
    dispatches: list = field(default_factory=list)
    counters: Counter = field(default_factory=Counter)

    def record_dispatch(self, bucket, reqs, t_dispatch, t_done, *,
                        degraded: bool = False, depth: int = 1):
        """``reqs``: the fired requests as (rid, n_points, t_arrival).
        ``t_dispatch`` is when execution *began* (in async mode the
        executor stamps it at the top of the walk, so service time is
        measured at completion against the true start — queue-wait
        absorbs any wait for an in-flight slot); ``depth`` is the
        in-flight depth at fire time."""
        self.dispatches.append(DispatchRecord(
            bucket=bucket.key, n_requests=len(reqs),
            valid_points=sum(n for _, n, _ in reqs),
            partial=len(reqs) < bucket.batch,
            service_s=t_done - t_dispatch, degraded=degraded,
            t_start=t_dispatch, t_done=t_done, depth=depth))
        if degraded:
            self.counters["degraded_dispatches"] += 1
        for rid, n, t_arr in reqs:
            self.requests.append(RequestRecord(
                rid=rid, bucket=bucket.key, n_points=n, t_arrival=t_arr,
                t_dispatch=t_dispatch, t_done=t_done))

    def record_rejection(self, counter: str):
        """Admission-guard refusal (``rejected_invalid`` /
        ``shed_queue_full``); the request never queued."""
        self.counters[counter] += 1

    def record_shed(self, n_requests: int = 1):
        """Queued requests shed past their deadline."""
        self.counters["deadline_miss"] += n_requests
        self.counters["failed_requests"] += n_requests

    def record_failed_dispatch(self, n_requests: int):
        """A batch whose every request surfaced a RequestError."""
        self.counters["failed_dispatches"] += 1
        self.counters["failed_requests"] += n_requests

    def record_breaker_opened(self):
        self.counters["breaker_opened"] += 1

    def report(self, **extra) -> dict:
        """The serving report: latency percentiles (ms), throughput,
        padding waste, per-bucket traffic.  ``extra`` (e.g.
        ``compile_count=...``, ``buckets=[...]``) is merged in."""
        reqs, disp = self.requests, self.dispatches
        lat = {
            name: percentile_summary([1e3 * getattr(r, f"{name}_s")
                                      for r in reqs])
            for name in ("queue_wait", "service", "e2e")
        }
        if reqs:
            t0 = min(r.t_arrival for r in reqs)
            t1 = max(r.t_done for r in reqs)
            rps = len(reqs) / max(t1 - t0, 1e-9)
        else:
            rps = 0.0
        padded = sum(d.bucket[0] * d.bucket[1] for d in disp)
        valid = sum(d.valid_points for d in disp)
        per_bucket: dict[str, dict] = {}
        for d in disp:
            k = f"{d.bucket[0]}x{d.bucket[1]}"
            pb = per_bucket.setdefault(
                k, {"dispatches": 0, "partial": 0, "requests": 0,
                    "degraded": 0})
            pb["dispatches"] += 1
            pb["partial"] += int(d.partial)
            pb["requests"] += d.n_requests
            pb["degraded"] += int(d.degraded)
        return {
            "requests": len(reqs),
            "dispatches": len(disp),
            "full_batches": sum(not d.partial for d in disp),
            "partial_batches": sum(d.partial for d in disp),
            "throughput_rps": rps,
            "latency_ms": lat,
            "padding_waste_pct":
                100.0 * (1.0 - valid / padded) if padded else 0.0,
            "per_bucket": per_bucket,
            "overlap": self._overlap_summary(),
            "faults": {k: int(self.counters.get(k, 0))
                       for k in FAULT_COUNTERS},
            **extra,
        }

    def _overlap_summary(self) -> dict:
        """How concurrent the dispatches actually were, from their
        recorded execution intervals: in-flight depth at fire time,
        total busy time vs its union (``overlap_pct`` > 0 means batches
        genuinely ran concurrently), and the idle gap — span time no
        dispatch covered (the sync dispatcher's serialization cost
        shows up here)."""
        ivs = sorted((d.t_start, d.t_done) for d in self.dispatches
                     if d.t_done > d.t_start)
        depths = [d.depth for d in self.dispatches]
        if not ivs:
            return {"inflight_depth_max": max(depths, default=0),
                    "inflight_depth_mean": 0.0, "busy_ms": 0.0,
                    "idle_gap_ms": 0.0, "overlap_pct": 0.0}
        busy = sum(e - s for s, e in ivs)
        union = 0.0
        cur_s, cur_e = ivs[0]
        for s, e in ivs[1:]:
            if s > cur_e:                # disjoint: close the run
                union += cur_e - cur_s
                cur_s, cur_e = s, e
            else:
                cur_e = max(cur_e, e)
        union += cur_e - cur_s
        span = max(e for _, e in ivs) - ivs[0][0]
        return {
            "inflight_depth_max": int(max(depths, default=0)),
            "inflight_depth_mean":
                float(np.mean(depths)) if depths else 0.0,
            "busy_ms": 1e3 * busy,
            "idle_gap_ms": 1e3 * max(span - union, 0.0),
            "overlap_pct":
                100.0 * (1.0 - union / busy) if busy > 0 else 0.0,
        }
