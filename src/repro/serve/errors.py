"""Typed error taxonomy for the serving layer.

Every way a request can fail is a distinct type, so callers branch on
``isinstance`` instead of parsing messages, and every failure carries
the structured context (rid, bucket, stage, cause) the client and the
metrics layer need:

* :class:`ServeError` — root of the taxonomy.
* :class:`AdmissionError` — refused at the door, never queued.  Its
  subclasses split the *reason* the door said no:

  - :class:`ValidationError` — the payload itself is unservable
    (non-finite coordinates, wrong shape/dtype, absurd size).  Poisoned
    clouds are refused here, before they can reach a jit-compiled
    kernel where a NaN silently corrupts a whole batch.
  - :class:`QueueFullError` — backpressure: the request is well-formed
    but its bucket's lane is at its depth bound.  Shedding at admission
    (tail drop) keeps queue-wait bounded for everything already
    admitted; the client should retry with backoff.

* :class:`RequestError` — admitted, then failed downstream (engine
  fault, poisoned output, missed deadline, open breaker with no
  fallback).  Stored as the request's *outcome*: ``take(rid)`` raises
  it, so a failed request is observable exactly once, like a response.
  With async dispatch these outcomes are produced by the *completion*
  path: an in-flight batch that fails resolves to the same reasons at
  the same counters as a synchronous one, and a deadline is judged
  against the completion clock (``reason="deadline"`` covers both a
  queue-side shed and an answer that materialized too late).
* :class:`UnknownRequestError` — ``take`` on a rid that is pending,
  never existed, or was already taken (also a :class:`KeyError`, for
  callers that predate the taxonomy).

``AdmissionError`` doubles as a ``ValueError`` so pre-taxonomy call
sites (``except ValueError``) keep working.
"""
from __future__ import annotations


class ServeError(Exception):
    """Root of the serving-layer error taxonomy."""


class AdmissionError(ServeError, ValueError):
    """A request the admission guard refused — it never queued.

    Subclasses say why: :class:`ValidationError` (bad payload),
    :class:`QueueFullError` (backpressure shed).  Plain
    ``AdmissionError`` covers the bucket-policy refusals (empty cloud,
    larger than every bucket)."""


class ValidationError(AdmissionError):
    """The payload is unservable: non-finite coordinates, wrong
    shape/dtype, or a size beyond the configured ceiling."""


class QueueFullError(AdmissionError):
    """The request's bucket lane is at its depth bound — shed on
    admission (tail drop) so already-admitted requests keep their
    bounded queue wait.  Retry with backoff."""

    def __init__(self, bucket_key, depth: int):
        self.bucket_key = tuple(bucket_key)
        self.depth = int(depth)
        super().__init__(
            f"bucket {self.bucket_key} lane is full ({self.depth} "
            f"queued); request shed — retry with backoff, raise "
            f"max_lane_depth, or add dispatch capacity")


class RequestError(ServeError):
    """An admitted request that failed after admission.

    Stored as the request's outcome: ``PCNServer.take(rid)`` raises it
    (exactly once, like a response), so failed requests never hang as
    forever-pending.

    Attributes
    ----------
    rid:     the failed request id.
    reason:  machine-readable stage tag — ``"engine"`` (the batch's
             engine execution raised), ``"poisoned_output"`` (the
             engine returned non-finite values), ``"deadline"`` (shed:
             could no longer be answered in time), ``"circuit_open"``
             (the bucket's breaker is open and no fallback is
             configured).
    bucket:  (batch, n_points) key of the bucket it was riding.
    cause:   ``repr`` of the underlying exception, if any.
    degraded_attempted: the one-shot fallback retry also ran (and
             failed) before this error was recorded.
    """

    def __init__(self, rid: int, reason: str, *, bucket=None,
                 cause: str | None = None,
                 degraded_attempted: bool = False):
        self.rid = int(rid)
        self.reason = str(reason)
        self.bucket = None if bucket is None else tuple(bucket)
        self.cause = cause
        self.degraded_attempted = bool(degraded_attempted)
        bits = [f"request {self.rid} failed ({self.reason})"]
        if self.bucket is not None:
            bits.append(f"bucket {self.bucket}")
        if self.degraded_attempted:
            bits.append("fallback retry also failed")
        if self.cause:
            bits.append(f"cause: {self.cause}")
        super().__init__("; ".join(bits))


class UnknownRequestError(ServeError, KeyError):
    """``take(rid)`` has nothing for this rid.  Carries a hint telling
    the caller which exactly-once rule they tripped: the request is
    still pending (poll/drain first), was already taken (responses pop
    on first take), or never existed."""

    def __init__(self, rid: int, hint: str):
        self.rid = rid
        self.hint = hint
        super().__init__(f"no response for rid {rid!r}: {hint}")

    def __str__(self):  # KeyError.__str__ would repr() the message
        return self.args[0]
