"""The dispatcher: continuous batching over pre-compiled size buckets,
hardened for an adverse world, with async in-flight dispatch.

:class:`PCNServer` is the serving handle.  It coalesces admitted
requests into the tightest bucket's batch shape and fires on either of
two triggers:

  * **batch-full** — a lane reaches its bucket's capacity; the batch
    fires immediately, inside ``submit`` (no poll latency on the hot
    path under load);
  * **timeout** — ``poll()`` fires any non-empty lane whose *oldest*
    request has waited ``timeout_s``, padding the short batch up to
    capacity with empty fill clouds (``n_valid == 0`` — fully masked by
    the PR-2 ragged contract), so light traffic is answered within one
    timeout instead of starving behind an unfillable batch.

Every fired batch has exactly its bucket's (B, N) shape — cloud rows
padded via :meth:`Batch.from_clouds(..., n_pad=N) <repro.engine.Batch
.from_clouds>`, missing batch rows zero-filled — so the engine compiles
**once per bucket** (shape-keyed jit cache; ``n_valid`` is traced data)
and every kernel/sharding win lands on the same executables traffic
uses.  Responses are exact: batch row i over its valid prefix equals
``engine.apply_single`` on that request's cloud and key.

Async dispatch (the overlap layer):

By default (``sync=False``) a fired batch does **not** block the firing
thread: the fire path registers an in-flight record (atomically with
the queue take and the slot check) and hands host padding + execution +
blocking readback to a bounded executor, so bucket A's host padding and
admission overlap bucket B's device compute, and up to ``max_in_flight``
batches are in flight at once.  The Mesorasi/HgPCN argument applies
end-to-end: the win is keeping the pipeline's heterogeneous stages
(admission → pad → dispatch → readback) concurrently occupied.  The
pieces:

* **Slot gating** — ``submit``/``poll`` only fire while fewer than
  ``max_in_flight`` batches are in flight; otherwise due batches stay
  queued (admission never blocks) and a completion pumps them out.
* **Completion path** — the executor task runs the *same*
  primary/fallback walk as sync mode and resolves outcomes under the
  lock: results into the response table, breaker verdicts recorded,
  deadlines enforced against the completion clock, counters updated —
  then fires any newly due lane while slots are free.
* **Coherent observation** — ``take(rid)`` *blocks* until an in-flight
  rid resolves (then returns or raises exactly as in sync mode);
  ``drain()`` fires everything queued and joins all in-flight work, so
  ``pending() == 0`` afterwards; ``pending()`` counts queued *plus*
  in-flight requests.
* **Sync A/B** — ``sync=True`` keeps the old fully-blocking behavior
  (fire resolves before returning) for benchmarking and for tests that
  assert post-submit state deterministically.

Failure handling (the hardened layer; identical semantics in both
modes — the async layer wraps the walk, it does not reimplement it):

* **Admission guard** — ``submit`` refuses poisoned payloads
  (:class:`ValidationError`: NaN/Inf, wrong shape/dtype), oversize
  clouds (:class:`AdmissionError`) and overload
  (:class:`QueueFullError` once a lane hits ``max_lane_depth``) with
  structured errors *before* anything reaches a compiled kernel.
* **Fault isolation** — an engine failure (raised exception *or*
  non-finite output, detected at completion) fails only that batch: the
  dispatcher retries the batch exactly once on the ``fallback`` backend
  (default ``"reference"``, through the same ``register_fc_backend``
  registry the engine resolves), and only if that also fails do the
  batch's requests surface a structured :class:`RequestError` via
  ``take``.  Other buckets, and other in-flight batches, are untouched.
* **Circuit breaker** — per bucket: consulted at *fire* time
  (``allow_primary``), verdicts recorded at *completion* time, so
  ``breaker_fail_streak`` consecutive primary failures trip it open,
  after which dispatches skip the primary entirely (straight to the
  fallback — degraded, not broken; with no fallback they fail fast)
  until a half-open probe after ``breaker_cooldown_s`` finds the
  primary healthy again.
* **Deadlines** — a request may carry a deadline (per-request
  ``deadline_s`` or the server default); ``poll``/``drain`` shed
  queued requests that can no longer be answered in time, and the
  completion path drops answers that arrive past their deadline
  (both surface ``RequestError(reason="deadline")`` from ``take``)
  instead of handing back answers nobody is waiting for.
* **Fault injection** — pass ``faults=``
  :class:`~repro.serve.faults.FaultPlan`: fault steps are *drawn* at
  fire time, under the lock, in firing order (deterministic even with
  several batches in flight) and *applied* around the primary engine
  call on the executor thread; the fallback path stays clean, which is
  exactly what makes injected chaos recoverable and testable.

Every non-happy path increments a counter in the metrics ``faults``
section (rejected/shed/deadline-miss/degraded/failed/breaker-opened),
so a chaos trace's report quantifies the damage.

Thread model: admission, polling and completions may come from
different threads — queue/result/breaker/counter state is
lock-protected; engine execution, host padding and readback all run
outside the lock so submissions keep landing while batches are in
flight.  Single-threaded drivers just call ``submit``/``poll``/
``drain`` in a loop.
"""
from __future__ import annotations

import functools
import threading
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass

import numpy as np

from .breaker import CircuitBreaker
from .buckets import Bucket, BucketSet
from .errors import (AdmissionError, QueueFullError, RequestError,
                     UnknownRequestError, ValidationError)
from .metrics import ServeMetrics
from .queue import AdmissionQueue, key_data


class _PoisonedOutput(RuntimeError):
    """Internal: the engine returned non-finite values for a request's
    valid rows — a fault even though nothing raised."""


@dataclass
class _InFlight:
    """One fired batch not yet resolved: everything the completion path
    needs, fixed at fire time (breaker verdict, drawn fault step) so
    the walk is deterministic regardless of completion order."""
    seq: int
    bucket: Bucket
    reqs: list
    batch: object
    try_primary: bool
    step: int | None = None          # drawn fault step (primary only)
    fault: object = None             # drawn Fault | None
    depth: int = 1                   # in-flight depth incl. this batch
    future: object = None            # executor handle (async mode)


@dataclass
class _Outcome:
    """What one executed batch produced (primary and fallback verdicts
    kept apart: the breaker judges only the primary)."""
    rows: dict | None
    primary_err: Exception | None
    fallback_err: Exception | None
    degraded: bool
    t_start: float
    t_done: float


class PCNServer:
    """Continuous-batching front end over a :class:`PCNEngine`.

    Parameters
    ----------
    engine:    a ``repro.engine.PCNEngine`` (any mode/backend/mesh —
               with a mesh, every bucket batch must divide the data
               axis, validated at construction).
    params:    the engine params served to every request.
    buckets:   a :class:`BucketSet` (or iterable of :class:`Bucket`).
    timeout_s: max queue-wait of a lane's oldest request before a
               partial batch fires.
    clock:     injectable monotonic clock (tests pass a fake one to make
               timeout/deadline/breaker policy deterministic).
    warmup:    compile every bucket at construction (one engine
               compilation per bucket; the first traffic batch then hits
               the jit cache).  ``False`` compiles lazily on each
               bucket's first dispatch.
    max_lane_depth: per-bucket queue bound; a submit into a full lane
               sheds with :class:`QueueFullError` (None = unbounded).
    deadline_s: default per-request deadline (seconds from arrival);
               ``submit(..., deadline_s=)`` overrides per request.
               None = requests never expire.
    fallback:  FC backend name for the one-shot degraded retry when a
               dispatch fails (``None`` disables: failures surface
               immediately).  The fallback engine compiles lazily, per
               bucket, on first use.
    breaker_fail_streak / breaker_cooldown_s: per-bucket circuit
               breaker: consecutive primary failures to trip, and how
               long it stays open before a half-open probe.
    faults:    optional :class:`~repro.serve.faults.FaultPlan`; fault
               steps are drawn at fire time (deterministic firing
               order) and applied around the primary engine call only
               (the fallback path is never faulted).
    validate:  run the payload guard (NaN/Inf/dtype) on every submit.
    max_in_flight: how many fired batches may be unresolved at once
               (the executor bound); due batches beyond it stay queued
               until a completion frees a slot.
    sync:      ``True`` restores fully-blocking dispatch (every fire
               resolves before returning) — the A/B baseline.
    """

    def __init__(self, engine, params, buckets, *, timeout_s: float = 0.01,
                 clock=time.monotonic, warmup: bool = True, seed: int = 0,
                 max_lane_depth: int | None = None,
                 deadline_s: float | None = None,
                 fallback: str | None = "reference",
                 breaker_fail_streak: int = 3,
                 breaker_cooldown_s: float = 1.0,
                 faults=None, validate: bool = True,
                 max_in_flight: int = 4, sync: bool = False):
        import jax
        self.engine = engine
        self.params = params
        self.buckets = (buckets if isinstance(buckets, BucketSet)
                        else BucketSet(buckets))
        if engine.mesh is not None:
            n_data = int(dict(engine.mesh.shape).get("data", 1))
            bad = [b for b in self.buckets if b.batch % max(n_data, 1)]
            if bad:
                raise ValueError(
                    f"buckets {bad} do not divide over the engine's "
                    f"{n_data}-way data mesh; use batch sizes that are "
                    f"multiples of {n_data}")
        if max_in_flight < 1:
            raise ValueError(f"max_in_flight must be >= 1, "
                             f"got {max_in_flight}")
        self.timeout_s = float(timeout_s)
        self.clock = clock
        self.deadline_s = deadline_s
        self.fallback = fallback
        self.faults = faults
        self.max_in_flight = int(max_in_flight)
        self.sync = bool(sync)
        self.queue = AdmissionQueue(self.buckets,
                                    max_lane_depth=max_lane_depth,
                                    validate=validate)
        self.metrics = ServeMetrics()
        self.breakers: dict[tuple[int, int], CircuitBreaker] = {
            b.key: CircuitBreaker(breaker_fail_streak, breaker_cooldown_s,
                                  clock=clock)
            for b in self.buckets}
        self._base_key = jax.random.PRNGKey(seed)
        self._results: dict[int, object] = {}   # ndarray | RequestError
        self._callables: dict[tuple[int, int], object] = {}
        self._fallback_engine = None
        self._fallback_callables: dict[tuple[int, int], object] = {}
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._aux_lock = threading.Lock()       # lazy compiles / executor
        self._inflight: dict[int, _InFlight] = {}
        self._inflight_rids: set[int] = set()
        self._seq = 0
        self._pool: ThreadPoolExecutor | None = None
        if warmup:
            for b in self.buckets:
                self._callable_for(b)

    # -- compilation seam ---------------------------------------------------

    def _callable_for(self, bucket: Bucket):
        """Per-bucket compiled callable (engine seam; compiles on first
        use of the bucket, cached thereafter).  Thread-safe: two
        in-flight batches racing a lazy compile build it once."""
        fn = self._callables.get(bucket.key)
        if fn is None:
            with self._aux_lock:
                fn = self._callables.get(bucket.key)
                if fn is None:
                    fn = self.engine.bucket_callable(
                        self.params, bucket.batch, bucket.n_points)
                    self._callables[bucket.key] = fn
        return fn

    def _fallback_callable_for(self, bucket: Bucket):
        """The degraded-path callable: same spec/mode/mesh, FC backend
        swapped to ``self.fallback`` through the registry seam.  Built
        and compiled lazily — healthy serving never pays for it (the
        first degraded dispatch of a bucket absorbs the compile; that
        cost lands in its service time, visibly)."""
        fn = self._fallback_callables.get(bucket.key)
        if fn is None:
            with self._aux_lock:
                fn = self._fallback_callables.get(bucket.key)
                if fn is None:
                    if self._fallback_engine is None:
                        eng = self.engine
                        self._fallback_engine = type(eng)(
                            eng.spec, mode=eng.mode,
                            fc_backend=self.fallback,
                            isl_kw=eng.isl_kw, kernel_kw=eng.kernel_kw,
                            mesh=eng.mesh)
                    fn = self._fallback_engine.bucket_callable(
                        self.params, bucket.batch, bucket.n_points)
                    self._fallback_callables[bucket.key] = fn
        return fn

    def _executor(self) -> ThreadPoolExecutor:
        ex = self._pool
        if ex is None:
            with self._aux_lock:
                ex = self._pool
                if ex is None:
                    ex = self._pool = ThreadPoolExecutor(
                        max_workers=self.max_in_flight,
                        thread_name_prefix="pcn-serve")
        return ex

    @property
    def compile_count(self) -> int:
        """Distinct *primary*-engine executables built so far (one per
        bucket; the lazy fallback engine has its own cache)."""
        return self.engine.compile_count

    # -- admission ----------------------------------------------------------

    def submit(self, xyz, feats=None, key=None, *,
               deadline_s: float | None = None) -> int:
        """Admit one cloud; returns its request id.  Fires immediately
        if this request fills its bucket's batch and an in-flight slot
        is free (never blocks on device compute in async mode; with all
        slots busy the full lane waits for a completion to pump it).

        Raises the structured admission taxonomy: :class:`ValidationError`
        (NaN/Inf, bad shape/dtype), :class:`AdmissionError` (no bucket
        fits), :class:`QueueFullError` (lane at its depth bound) — each
        counted in the metrics ``faults`` section.

        ``deadline_s`` (seconds from now; default: the server-level
        ``deadline_s``) marks when the answer stops being useful:
        ``poll``/``drain`` shed the request once it expires, and an
        in-flight answer completing past it is dropped.
        """
        import jax
        now = self.clock()
        ttl = self.deadline_s if deadline_s is None else deadline_s
        t_deadline = None if ttl is None else now + ttl
        with self._lock:
            if key is None:
                key = jax.random.fold_in(self._base_key,
                                         self.queue._next_rid)
            try:
                req = self.queue.submit(xyz, feats, key, now, t_deadline)
            except QueueFullError:
                self.metrics.record_rejection("shed_queue_full")
                raise
            except ValidationError:
                self.metrics.record_rejection("rejected_invalid")
                raise
            except AdmissionError:
                # bucket-policy refusal (empty / beyond the size ceiling)
                self.metrics.record_rejection("rejected_invalid")
                raise
            rec = None
            if (len(self.queue.lane(req.bucket)) >= req.bucket.batch
                    and self._slot_free_locked()):
                rec = self._register_locked(
                    req.bucket,
                    self.queue.take(req.bucket, req.bucket.batch))
        if rec is not None:
            self._launch(rec)
        return req.rid

    # -- dispatch -----------------------------------------------------------

    def _slot_free_locked(self) -> bool:
        """May one more batch go in flight?  (Caller holds the lock.)"""
        return self.sync or len(self._inflight) < self.max_in_flight

    def _shed_expired(self) -> list[int]:
        """Drop queued requests past their deadline; each becomes a
        ``RequestError(reason="deadline")`` outcome and a
        ``deadline_miss`` count."""
        now = self.clock()
        with self._lock:
            shed = self.queue.shed_expired(now)
            for r in shed:
                self.metrics.record_shed()
                self._results[r.rid] = RequestError(
                    r.rid, "deadline", bucket=r.bucket.key)
        return [r.rid for r in shed]

    def poll(self) -> list[int]:
        """Shed expired requests, then fire every lane that is due
        (full, or oldest request past the timeout) while in-flight
        slots are free; returns the rids this call shed or fired (fired
        rids are resolved on return in sync mode, possibly still in
        flight in async mode — ``ready``/``take`` observe them
        coherently either way)."""
        done: list[int] = self._shed_expired()
        for bucket in self.buckets:
            while True:
                now = self.clock()
                with self._lock:
                    rec = None
                    if self._slot_free_locked():
                        lane = self.queue.lane(bucket)
                        full = len(lane) >= bucket.batch
                        timed_out = (len(lane) > 0 and
                                     now - lane[0].t_arrival
                                     >= self.timeout_s)
                        if full or timed_out:
                            rec = self._register_locked(
                                bucket,
                                self.queue.take(bucket, bucket.batch))
                if rec is None:
                    break
                done += self._launch(rec)
        return done

    def drain(self) -> list[int]:
        """Shed expired requests, fire everything still queued
        regardless of timeout (waiting for in-flight slots as needed),
        then **join** all in-flight work (end of a trace / shutdown).
        Afterwards ``pending() == 0``: every admitted rid has an
        outcome."""
        done: list[int] = self._shed_expired()
        for bucket in self.buckets:
            while True:
                with self._cond:
                    while not self._slot_free_locked():
                        self._cond.wait()
                    reqs = self.queue.take(bucket, bucket.batch)
                    rec = self._register_locked(bucket, reqs) \
                        if reqs else None
                if rec is None:
                    break
                done += self._launch(rec)
        with self._cond:
            while self._inflight:
                self._cond.wait()
        return done

    def _pump(self):
        """Called after a completion freed a slot (async mode): fire
        due lanes (full, or timed out) while slots stay free, so the
        device never idles behind a full in-flight table."""
        if self.sync:
            return
        while True:
            now = self.clock()
            rec = None
            with self._lock:
                if not self._slot_free_locked():
                    return
                for bucket in self.buckets:
                    lane = self.queue.lane(bucket)
                    full = len(lane) >= bucket.batch
                    timed_out = (len(lane) > 0 and
                                 now - lane[0].t_arrival >= self.timeout_s)
                    if full or timed_out:
                        rec = self._register_locked(
                            bucket, self.queue.take(bucket, bucket.batch))
                        break
            if rec is None:
                return
            self._launch(rec)

    # -- execution ----------------------------------------------------------

    def _build_batch(self, bucket: Bucket, reqs):
        import jax
        from repro.engine import Batch

        n_fill = bucket.batch - len(reqs)
        feat_dim = self.engine.spec.in_feats
        clouds = [r.xyz for r in reqs] + [
            np.zeros((0, 3), np.float32)] * n_fill
        feats = None
        if feat_dim > 3:
            feats = [r.feats for r in reqs] + [
                np.zeros((0, feat_dim), np.float32)] * n_fill
        fill_key = key_data(jax.random.PRNGKey(0))
        keys = np.stack([r.key for r in reqs]
                        + [fill_key] * n_fill).astype(np.uint32)
        return Batch.from_clouds(clouds, feats=feats, key=keys,
                                 n_pad=bucket.n_points)

    def _run(self, fn, batch, reqs) -> dict[int, np.ndarray]:
        """Execute one callable and slice out per-request rows,
        checking every valid row is finite (a backend returning NaN is
        a fault even when nothing raised)."""
        import jax
        out = fn(batch)
        jax.block_until_ready(out)
        out = np.asarray(out)
        rows: dict[int, np.ndarray] = {}
        for i, r in enumerate(reqs):
            row = out[i]
            # seg heads return (N, n_classes); valid prefix only
            row = row[:r.n_points] if row.ndim == 2 else row
            if not np.isfinite(row).all():
                raise _PoisonedOutput(
                    f"non-finite output for rid {r.rid} "
                    f"(bucket {bucket_str(r.bucket)})")
            rows[r.rid] = row
        return rows

    def _register_locked(self, bucket: Bucket, reqs) -> _InFlight:
        """Fix the fire-time decisions and register the in-flight
        record — breaker consult and fault draw happen here, in firing
        order, atomically with the queue take and the slot check (the
        caller holds the lock), so the in-flight table never exceeds
        ``max_in_flight`` and fault steps stay deterministic."""
        try_primary = self.breakers[bucket.key].allow_primary()
        rec = _InFlight(seq=self._seq, bucket=bucket, reqs=reqs,
                        batch=None, try_primary=try_primary)
        self._seq += 1
        if try_primary and self.faults is not None:
            rec.step, rec.fault = self.faults.draw()
        self._inflight[rec.seq] = rec
        self._inflight_rids.update(r.rid for r in reqs)
        rec.depth = len(self._inflight)
        return rec

    def _launch(self, rec: _InFlight) -> list[int]:
        """Run a registered batch: inline in sync mode, on the bounded
        executor otherwise (host padding rides the executor thread too
        — that is the admission↔padding↔compute overlap).  Returns the
        fired rids."""
        if self.sync:
            self._complete(rec, self._execute(rec))
        else:
            rec.future = self._executor().submit(self._task, rec)
            rec.future.add_done_callback(
                functools.partial(self._future_guard, rec))
        return [r.rid for r in rec.reqs]

    def _execute(self, rec: _InFlight) -> _Outcome:
        """The full batch walk — host padding, engine execution and
        readback, entirely outside the lock.  Never raises: verdicts
        travel in the :class:`_Outcome` for ``_complete`` to judge."""
        bucket, reqs = rec.bucket, rec.reqs
        t_start = self.clock()          # service includes host padding
        batch = rec.batch = self._build_batch(bucket, reqs)
        rows = None
        primary_err: Exception | None = None
        fallback_err: Exception | None = None
        degraded = False
        if rec.try_primary:
            try:
                fn = self._callable_for(bucket)
                if self.faults is not None:
                    rows = self._run(
                        lambda b, _fn=fn: self.faults.apply(
                            _fn, b, rec.step, rec.fault),
                        batch, reqs)
                else:
                    rows = self._run(fn, batch, reqs)
            except Exception as e:      # noqa: BLE001 — judged by
                primary_err = e         # _complete (breaker + reason)
        if rows is None and self.fallback is not None:
            try:
                rows = self._run(self._fallback_callable_for(bucket),
                                 batch, reqs)
                degraded = True
            except Exception as e:      # noqa: BLE001 — both sides down;
                fallback_err = e        # surfaces as RequestError
        return _Outcome(rows, primary_err, fallback_err, degraded,
                        t_start, self.clock())

    def _task(self, rec: _InFlight):
        """Executor body: execute, then resolve.  ``_execute`` never
        raises; ``_future_guard`` backstops a completion-path bug."""
        self._complete(rec, self._execute(rec))

    def _complete(self, rec: _InFlight, out: _Outcome):
        """Resolve one executed batch under the lock: record the
        breaker verdict (completion-time), enforce deadlines against
        the completion clock, stash per-request outcomes, update
        counters, wake blocked ``take``/``drain`` — then pump newly
        due lanes into the freed slot."""
        bucket, reqs = rec.bucket, rec.reqs
        with self._cond:
            br = self.breakers[bucket.key]
            if rec.try_primary:
                if out.primary_err is None:
                    br.record_success()
                else:
                    opened_before = br.open_count
                    br.record_failure()
                    if br.open_count > opened_before:
                        self.metrics.record_breaker_opened()
            if out.rows is not None:
                live = []
                for r in reqs:
                    if (r.t_deadline is not None
                            and out.t_done >= r.t_deadline):
                        # answered too late to be useful: same outcome
                        # and counters as a queue-side shed
                        self.metrics.record_shed()
                        self._results[r.rid] = RequestError(
                            r.rid, "deadline", bucket=bucket.key)
                    else:
                        live.append(r)
                self.metrics.record_dispatch(
                    bucket, [(r.rid, r.n_points, r.t_arrival)
                             for r in live],
                    out.t_start, out.t_done, degraded=out.degraded,
                    depth=rec.depth)
                self._results.update(
                    {r.rid: out.rows[r.rid] for r in live})
            else:
                err = (out.primary_err if out.primary_err is not None
                       else out.fallback_err)
                if not rec.try_primary and self.fallback is None:
                    reason = "circuit_open"
                elif isinstance(err, _PoisonedOutput):
                    reason = "poisoned_output"
                else:
                    reason = "engine"
                self.metrics.record_failed_dispatch(len(reqs))
                for r in reqs:
                    self._results[r.rid] = RequestError(
                        r.rid, reason, bucket=bucket.key,
                        cause=None if err is None else repr(err),
                        degraded_attempted=(rec.try_primary
                                            and self.fallback
                                            is not None))
            del self._inflight[rec.seq]
            self._inflight_rids.difference_update(r.rid for r in reqs)
            self._cond.notify_all()
        self._pump()

    def _future_guard(self, rec: _InFlight, fut):
        """Done-callback on every in-flight future: an exception that
        escaped the completion path (a dispatcher bug — ``_execute``
        converts engine failures itself) must not strand its requests
        or vanish silently."""
        err = fut.exception()
        if err is None:
            return
        warnings.warn(f"in-flight completion crashed: {err!r}",
                      RuntimeWarning, stacklevel=2)
        with self._cond:
            if rec.seq not in self._inflight:
                return
            del self._inflight[rec.seq]
            self._inflight_rids.difference_update(
                r.rid for r in rec.reqs)
            self.metrics.record_failed_dispatch(len(rec.reqs))
            for r in rec.reqs:
                self._results[r.rid] = RequestError(
                    r.rid, "engine", bucket=rec.bucket.key,
                    cause=repr(err))
            self._cond.notify_all()

    # -- responses ----------------------------------------------------------

    def take(self, rid: int) -> np.ndarray:
        """Pop the outcome for ``rid`` (each resolved exactly once).

        **Blocks** while ``rid`` rides an in-flight batch (async mode:
        the completion path resolves it and wakes us).  Returns the
        logits for an answered request; raises its
        :class:`RequestError` for a failed/shed one (also popped —
        failures are observed exactly once, like responses); raises
        :class:`UnknownRequestError` (a ``KeyError``) with a diagnosis
        when there is nothing to pop: still queued (unfired — blocking
        would deadlock a single-threaded driver), already taken, or
        never submitted."""
        with self._cond:
            while rid in self._inflight_rids:
                self._cond.wait()
            if rid in self._results:
                out = self._results.pop(rid)
            elif rid in self.queue.pending_rids():
                raise UnknownRequestError(
                    rid, "still pending — poll()/drain() until "
                         "ready(rid) before taking")
            elif isinstance(rid, int) and 0 <= rid < self.queue._next_rid:
                raise UnknownRequestError(
                    rid, "already taken (outcomes pop on first take — "
                         "exactly-once semantics)")
            else:
                raise UnknownRequestError(
                    rid, "never submitted to this server")
        if isinstance(out, RequestError):
            raise out
        return out

    def ready(self, rid: int) -> bool:
        """An outcome (response *or* structured failure) is available.
        False while the rid is queued or in flight."""
        with self._lock:
            return rid in self._results

    def failed(self, rid: int) -> bool:
        """The available outcome is a :class:`RequestError` (peek —
        does not consume it)."""
        with self._lock:
            return isinstance(self._results.get(rid), RequestError)

    def pending(self) -> int:
        """Requests admitted but not yet resolved: queued + in flight."""
        with self._lock:
            return self.queue.pending() + len(self._inflight_rids)

    def close(self):
        """Join all in-flight work and shut the executor down
        (idempotent; a later async fire lazily rebuilds the pool)."""
        with self._cond:
            while self._inflight:
                self._cond.wait()
        with self._aux_lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    def report(self, **extra) -> dict:
        """Serving report (see :meth:`ServeMetrics.report`) annotated
        with the bucket config, dispatch mode, compile count, per-bucket
        breaker states and the fault plan (if any)."""
        return self.metrics.report(
            buckets=[list(b.key) for b in self.buckets],
            timeout_ms=1e3 * self.timeout_s,
            dispatch_mode="sync" if self.sync else "async",
            max_in_flight=self.max_in_flight,
            compile_count=self.compile_count,
            engine=repr(self.engine),
            fallback=self.fallback,
            breakers={bucket_str(k): br.snapshot()
                      for k, br in self.breakers.items()},
            fault_plan=(None if self.faults is None
                        else self.faults.summary()),
            **extra)


def bucket_str(key) -> str:
    return f"{key[0]}x{key[1]}"
