"""The dispatcher: continuous batching over pre-compiled size buckets.

:class:`PCNServer` is the serving handle.  It coalesces admitted
requests into the tightest bucket's batch shape and fires on either of
two triggers:

  * **batch-full** — a lane reaches its bucket's capacity; the batch
    fires immediately, inside ``submit`` (no poll latency on the hot
    path under load);
  * **timeout** — ``poll()`` fires any non-empty lane whose *oldest*
    request has waited ``timeout_s``, padding the short batch up to
    capacity with empty fill clouds (``n_valid == 0`` — fully masked by
    the PR-2 ragged contract), so light traffic is answered within one
    timeout instead of starving behind an unfillable batch.

Every fired batch has exactly its bucket's (B, N) shape — cloud rows
padded via :meth:`Batch.from_clouds(..., n_pad=N) <repro.engine.Batch
.from_clouds>`, missing batch rows zero-filled — so the engine compiles
**once per bucket** (shape-keyed jit cache; ``n_valid`` is traced data)
and every kernel/sharding win lands on the same executables traffic
uses.  Responses are exact: batch row i over its valid prefix equals
``engine.apply_single`` on that request's cloud and key.

Thread model: admission and polling may come from different threads
(queue state is lock-protected); engine execution runs outside the lock
so submissions keep landing while a batch is in flight.  Single-threaded
drivers just call ``submit``/``poll``/``drain`` in a loop.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from .buckets import Bucket, BucketSet
from .metrics import ServeMetrics
from .queue import AdmissionQueue, key_data


class PCNServer:
    """Continuous-batching front end over a :class:`PCNEngine`.

    Parameters
    ----------
    engine:    a ``repro.engine.PCNEngine`` (any mode/backend/mesh —
               with a mesh, every bucket batch must divide the data
               axis, validated at construction).
    params:    the engine params served to every request.
    buckets:   a :class:`BucketSet` (or iterable of :class:`Bucket`).
    timeout_s: max queue-wait of a lane's oldest request before a
               partial batch fires.
    clock:     injectable monotonic clock (tests pass a fake one to make
               timeout policy deterministic).
    warmup:    compile every bucket at construction (one engine
               compilation per bucket; the first traffic batch then hits
               the jit cache).  ``False`` compiles lazily on each
               bucket's first dispatch.
    """

    def __init__(self, engine, params, buckets, *, timeout_s: float = 0.01,
                 clock=time.monotonic, warmup: bool = True, seed: int = 0):
        import jax
        self.engine = engine
        self.params = params
        self.buckets = (buckets if isinstance(buckets, BucketSet)
                        else BucketSet(buckets))
        if engine.mesh is not None:
            n_data = int(dict(engine.mesh.shape).get("data", 1))
            bad = [b for b in self.buckets if b.batch % max(n_data, 1)]
            if bad:
                raise ValueError(
                    f"buckets {bad} do not divide over the engine's "
                    f"{n_data}-way data mesh; use batch sizes that are "
                    f"multiples of {n_data}")
        self.timeout_s = float(timeout_s)
        self.clock = clock
        self.queue = AdmissionQueue(self.buckets)
        self.metrics = ServeMetrics()
        self._base_key = jax.random.PRNGKey(seed)
        self._results: dict[int, np.ndarray] = {}
        self._callables: dict[tuple[int, int], object] = {}
        self._lock = threading.Lock()
        if warmup:
            for b in self.buckets:
                self._callable_for(b)

    # -- compilation seam ---------------------------------------------------

    def _callable_for(self, bucket: Bucket):
        """Per-bucket compiled callable (engine seam; compiles on first
        use of the bucket, cached thereafter)."""
        fn = self._callables.get(bucket.key)
        if fn is None:
            fn = self.engine.bucket_callable(self.params, bucket.batch,
                                             bucket.n_points)
            self._callables[bucket.key] = fn
        return fn

    @property
    def compile_count(self) -> int:
        """Distinct engine executables built so far (one per bucket)."""
        return self.engine.compile_count

    # -- admission ----------------------------------------------------------

    def submit(self, xyz, feats=None, key=None) -> int:
        """Admit one cloud; returns its request id.  Fires immediately
        if this request fills its bucket's batch.  Raises
        :class:`AdmissionError` for clouds no bucket fits."""
        import jax
        now = self.clock()
        with self._lock:
            if key is None:
                key = jax.random.fold_in(self._base_key,
                                         self.queue._next_rid)
            req = self.queue.submit(xyz, feats, key, now)
            fire = (len(self.queue.lane(req.bucket)) >= req.bucket.batch)
            reqs = self.queue.take(req.bucket, req.bucket.batch) \
                if fire else None
        if fire:
            self._fire(req.bucket, reqs)
        return req.rid

    # -- dispatch -----------------------------------------------------------

    def poll(self) -> list[int]:
        """Fire every lane that is due (full, or oldest request past the
        timeout); returns the rids answered by this call."""
        done: list[int] = []
        for bucket in self.buckets:
            while True:
                now = self.clock()
                with self._lock:
                    lane = self.queue.lane(bucket)
                    full = len(lane) >= bucket.batch
                    timed_out = (len(lane) > 0 and
                                 now - lane[0].t_arrival >= self.timeout_s)
                    reqs = self.queue.take(bucket, bucket.batch) \
                        if (full or timed_out) else None
                if not reqs:
                    break
                done += self._fire(bucket, reqs)
        return done

    def drain(self) -> list[int]:
        """Fire everything still queued regardless of timeout (end of a
        trace / shutdown)."""
        done: list[int] = []
        for bucket in self.buckets:
            while True:
                with self._lock:
                    reqs = self.queue.take(bucket, bucket.batch)
                if not reqs:
                    break
                done += self._fire(bucket, reqs)
        return done

    def _fire(self, bucket: Bucket, reqs) -> list[int]:
        """Pad ``reqs`` to the bucket shape, run the engine, record
        metrics and stash per-request responses."""
        import jax
        from repro.engine import Batch

        fn = self._callable_for(bucket)
        n_fill = bucket.batch - len(reqs)
        feat_dim = self.engine.spec.in_feats
        clouds = [r.xyz for r in reqs] + [
            np.zeros((0, 3), np.float32)] * n_fill
        feats = None
        if feat_dim > 3:
            feats = [r.feats for r in reqs] + [
                np.zeros((0, feat_dim), np.float32)] * n_fill
        fill_key = key_data(jax.random.PRNGKey(0))
        keys = np.stack([r.key for r in reqs]
                        + [fill_key] * n_fill).astype(np.uint32)
        batch = Batch.from_clouds(clouds, feats=feats, key=keys,
                                  n_pad=bucket.n_points)
        t_dispatch = self.clock()
        out = fn(batch)
        jax.block_until_ready(out)
        t_done = self.clock()
        out = np.asarray(out)
        with self._lock:
            self.metrics.record_dispatch(
                bucket, [(r.rid, r.n_points, r.t_arrival) for r in reqs],
                t_dispatch, t_done)
            for i, r in enumerate(reqs):
                row = out[i]
                # seg heads return (N, n_classes); valid prefix only
                self._results[r.rid] = (row[:r.n_points]
                                        if row.ndim == 2 else row)
        return [r.rid for r in reqs]

    # -- responses ----------------------------------------------------------

    def take(self, rid: int) -> np.ndarray:
        """Pop the response for ``rid`` (each answered exactly once);
        KeyError if not yet dispatched or already taken."""
        with self._lock:
            return self._results.pop(rid)

    def ready(self, rid: int) -> bool:
        with self._lock:
            return rid in self._results

    def pending(self) -> int:
        with self._lock:
            return self.queue.pending()

    def report(self, **extra) -> dict:
        """Serving report (see :meth:`ServeMetrics.report`) annotated
        with the bucket config and compile count."""
        return self.metrics.report(
            buckets=[list(b.key) for b in self.buckets],
            timeout_ms=1e3 * self.timeout_s,
            compile_count=self.compile_count,
            engine=repr(self.engine), **extra)
