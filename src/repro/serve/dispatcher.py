"""The dispatcher: continuous batching over pre-compiled size buckets,
hardened for an adverse world.

:class:`PCNServer` is the serving handle.  It coalesces admitted
requests into the tightest bucket's batch shape and fires on either of
two triggers:

  * **batch-full** — a lane reaches its bucket's capacity; the batch
    fires immediately, inside ``submit`` (no poll latency on the hot
    path under load);
  * **timeout** — ``poll()`` fires any non-empty lane whose *oldest*
    request has waited ``timeout_s``, padding the short batch up to
    capacity with empty fill clouds (``n_valid == 0`` — fully masked by
    the PR-2 ragged contract), so light traffic is answered within one
    timeout instead of starving behind an unfillable batch.

Every fired batch has exactly its bucket's (B, N) shape — cloud rows
padded via :meth:`Batch.from_clouds(..., n_pad=N) <repro.engine.Batch
.from_clouds>`, missing batch rows zero-filled — so the engine compiles
**once per bucket** (shape-keyed jit cache; ``n_valid`` is traced data)
and every kernel/sharding win lands on the same executables traffic
uses.  Responses are exact: batch row i over its valid prefix equals
``engine.apply_single`` on that request's cloud and key.

Failure handling (the hardened layer):

* **Admission guard** — ``submit`` refuses poisoned payloads
  (:class:`ValidationError`: NaN/Inf, wrong shape/dtype), oversize
  clouds (:class:`AdmissionError`) and overload
  (:class:`QueueFullError` once a lane hits ``max_lane_depth``) with
  structured errors *before* anything reaches a compiled kernel.
* **Fault isolation** — an engine failure (raised exception *or*
  non-finite output) fails only that batch: the dispatcher retries the
  batch exactly once on the ``fallback`` backend (default
  ``"reference"``, through the same ``register_fc_backend`` registry
  the engine resolves), and only if that also fails do the batch's
  requests surface a structured :class:`RequestError` via ``take``.
  Other buckets, and other batches of the same bucket, are untouched.
* **Circuit breaker** — per bucket: ``breaker_fail_streak`` consecutive
  primary failures trip it open, after which dispatches skip the
  primary entirely (straight to the fallback — degraded, not broken;
  with no fallback they fail fast) until a half-open probe after
  ``breaker_cooldown_s`` finds the primary healthy again.
* **Deadlines** — a request may carry a deadline (per-request
  ``deadline_s`` or the server default); ``poll``/``drain`` shed
  queued requests that can no longer be answered in time (their
  ``take`` raises ``RequestError(reason="deadline")``) instead of
  spending device compute on answers nobody is waiting for.
* **Fault injection** — pass ``faults=``
  :class:`~repro.serve.faults.FaultPlan` to wrap the *primary* engine
  callables with a deterministic chaos schedule (exceptions, NaN
  poisoning, latency spikes); the fallback path stays clean, which is
  exactly what makes injected chaos recoverable and testable.

Every non-happy path increments a counter in the metrics ``faults``
section (rejected/shed/deadline-miss/degraded/failed/breaker-opened),
so a chaos trace's report quantifies the damage.

Thread model: admission and polling may come from different threads
(queue state is lock-protected); engine execution runs outside the lock
so submissions keep landing while a batch is in flight.  Single-threaded
drivers just call ``submit``/``poll``/``drain`` in a loop.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from .breaker import CircuitBreaker
from .buckets import Bucket, BucketSet
from .errors import (AdmissionError, QueueFullError, RequestError,
                     UnknownRequestError, ValidationError)
from .metrics import ServeMetrics
from .queue import AdmissionQueue, key_data


class _PoisonedOutput(RuntimeError):
    """Internal: the engine returned non-finite values for a request's
    valid rows — a fault even though nothing raised."""


class PCNServer:
    """Continuous-batching front end over a :class:`PCNEngine`.

    Parameters
    ----------
    engine:    a ``repro.engine.PCNEngine`` (any mode/backend/mesh —
               with a mesh, every bucket batch must divide the data
               axis, validated at construction).
    params:    the engine params served to every request.
    buckets:   a :class:`BucketSet` (or iterable of :class:`Bucket`).
    timeout_s: max queue-wait of a lane's oldest request before a
               partial batch fires.
    clock:     injectable monotonic clock (tests pass a fake one to make
               timeout/deadline/breaker policy deterministic).
    warmup:    compile every bucket at construction (one engine
               compilation per bucket; the first traffic batch then hits
               the jit cache).  ``False`` compiles lazily on each
               bucket's first dispatch.
    max_lane_depth: per-bucket queue bound; a submit into a full lane
               sheds with :class:`QueueFullError` (None = unbounded).
    deadline_s: default per-request deadline (seconds from arrival);
               ``submit(..., deadline_s=)`` overrides per request.
               None = requests never expire.
    fallback:  FC backend name for the one-shot degraded retry when a
               dispatch fails (``None`` disables: failures surface
               immediately).  The fallback engine compiles lazily, per
               bucket, on first use.
    breaker_fail_streak / breaker_cooldown_s: per-bucket circuit
               breaker: consecutive primary failures to trip, and how
               long it stays open before a half-open probe.
    faults:    optional :class:`~repro.serve.faults.FaultPlan`; wraps
               the primary engine callables with a deterministic chaos
               schedule (the fallback path is never wrapped).
    validate:  run the payload guard (NaN/Inf/dtype) on every submit.
    """

    def __init__(self, engine, params, buckets, *, timeout_s: float = 0.01,
                 clock=time.monotonic, warmup: bool = True, seed: int = 0,
                 max_lane_depth: int | None = None,
                 deadline_s: float | None = None,
                 fallback: str | None = "reference",
                 breaker_fail_streak: int = 3,
                 breaker_cooldown_s: float = 1.0,
                 faults=None, validate: bool = True):
        import jax
        self.engine = engine
        self.params = params
        self.buckets = (buckets if isinstance(buckets, BucketSet)
                        else BucketSet(buckets))
        if engine.mesh is not None:
            n_data = int(dict(engine.mesh.shape).get("data", 1))
            bad = [b for b in self.buckets if b.batch % max(n_data, 1)]
            if bad:
                raise ValueError(
                    f"buckets {bad} do not divide over the engine's "
                    f"{n_data}-way data mesh; use batch sizes that are "
                    f"multiples of {n_data}")
        self.timeout_s = float(timeout_s)
        self.clock = clock
        self.deadline_s = deadline_s
        self.fallback = fallback
        self.faults = faults
        self.queue = AdmissionQueue(self.buckets,
                                    max_lane_depth=max_lane_depth,
                                    validate=validate)
        self.metrics = ServeMetrics()
        self.breakers: dict[tuple[int, int], CircuitBreaker] = {
            b.key: CircuitBreaker(breaker_fail_streak, breaker_cooldown_s,
                                  clock=clock)
            for b in self.buckets}
        self._base_key = jax.random.PRNGKey(seed)
        self._results: dict[int, object] = {}   # ndarray | RequestError
        self._callables: dict[tuple[int, int], object] = {}
        self._fallback_engine = None
        self._fallback_callables: dict[tuple[int, int], object] = {}
        self._lock = threading.Lock()
        if warmup:
            for b in self.buckets:
                self._callable_for(b)

    # -- compilation seam ---------------------------------------------------

    def _callable_for(self, bucket: Bucket):
        """Per-bucket compiled callable (engine seam; compiles on first
        use of the bucket, cached thereafter).  With a fault plan, the
        returned callable is the chaos-wrapped one."""
        fn = self._callables.get(bucket.key)
        if fn is None:
            fn = self.engine.bucket_callable(self.params, bucket.batch,
                                             bucket.n_points)
            if self.faults is not None:
                fn = self.faults.wrap(fn)
            self._callables[bucket.key] = fn
        return fn

    def _fallback_callable_for(self, bucket: Bucket):
        """The degraded-path callable: same spec/mode/mesh, FC backend
        swapped to ``self.fallback`` through the registry seam.  Built
        and compiled lazily — healthy serving never pays for it (the
        first degraded dispatch of a bucket absorbs the compile; that
        cost lands in its service time, visibly)."""
        fn = self._fallback_callables.get(bucket.key)
        if fn is None:
            if self._fallback_engine is None:
                eng = self.engine
                self._fallback_engine = type(eng)(
                    eng.spec, mode=eng.mode, fc_backend=self.fallback,
                    isl_kw=eng.isl_kw, kernel_kw=eng.kernel_kw,
                    mesh=eng.mesh)
            fn = self._fallback_engine.bucket_callable(
                self.params, bucket.batch, bucket.n_points)
            self._fallback_callables[bucket.key] = fn
        return fn

    @property
    def compile_count(self) -> int:
        """Distinct *primary*-engine executables built so far (one per
        bucket; the lazy fallback engine has its own cache)."""
        return self.engine.compile_count

    # -- admission ----------------------------------------------------------

    def submit(self, xyz, feats=None, key=None, *,
               deadline_s: float | None = None) -> int:
        """Admit one cloud; returns its request id.  Fires immediately
        if this request fills its bucket's batch.

        Raises the structured admission taxonomy: :class:`ValidationError`
        (NaN/Inf, bad shape/dtype), :class:`AdmissionError` (no bucket
        fits), :class:`QueueFullError` (lane at its depth bound) — each
        counted in the metrics ``faults`` section.

        ``deadline_s`` (seconds from now; default: the server-level
        ``deadline_s``) marks when the answer stops being useful:
        ``poll``/``drain`` shed the request once it expires.
        """
        import jax
        now = self.clock()
        ttl = self.deadline_s if deadline_s is None else deadline_s
        t_deadline = None if ttl is None else now + ttl
        with self._lock:
            if key is None:
                key = jax.random.fold_in(self._base_key,
                                         self.queue._next_rid)
            try:
                req = self.queue.submit(xyz, feats, key, now, t_deadline)
            except QueueFullError:
                self.metrics.record_rejection("shed_queue_full")
                raise
            except ValidationError:
                self.metrics.record_rejection("rejected_invalid")
                raise
            except AdmissionError:
                # bucket-policy refusal (empty / beyond the size ceiling)
                self.metrics.record_rejection("rejected_invalid")
                raise
            fire = (len(self.queue.lane(req.bucket)) >= req.bucket.batch)
            reqs = self.queue.take(req.bucket, req.bucket.batch) \
                if fire else None
        if fire:
            self._fire(req.bucket, reqs)
        return req.rid

    # -- dispatch -----------------------------------------------------------

    def _shed_expired(self) -> list[int]:
        """Drop queued requests past their deadline; each becomes a
        ``RequestError(reason="deadline")`` outcome and a
        ``deadline_miss`` count."""
        now = self.clock()
        with self._lock:
            shed = self.queue.shed_expired(now)
            for r in shed:
                self.metrics.record_shed()
                self._results[r.rid] = RequestError(
                    r.rid, "deadline", bucket=r.bucket.key)
        return [r.rid for r in shed]

    def poll(self) -> list[int]:
        """Shed expired requests, then fire every lane that is due
        (full, or oldest request past the timeout); returns the rids
        resolved by this call (answered, failed, or shed)."""
        done: list[int] = self._shed_expired()
        for bucket in self.buckets:
            while True:
                now = self.clock()
                with self._lock:
                    lane = self.queue.lane(bucket)
                    full = len(lane) >= bucket.batch
                    timed_out = (len(lane) > 0 and
                                 now - lane[0].t_arrival >= self.timeout_s)
                    reqs = self.queue.take(bucket, bucket.batch) \
                        if (full or timed_out) else None
                if not reqs:
                    break
                done += self._fire(bucket, reqs)
        return done

    def drain(self) -> list[int]:
        """Shed expired requests, then fire everything still queued
        regardless of timeout (end of a trace / shutdown).  Afterwards
        ``pending() == 0``: every admitted rid has an outcome."""
        done: list[int] = self._shed_expired()
        for bucket in self.buckets:
            while True:
                with self._lock:
                    reqs = self.queue.take(bucket, bucket.batch)
                if not reqs:
                    break
                done += self._fire(bucket, reqs)
        return done

    # -- execution ----------------------------------------------------------

    def _build_batch(self, bucket: Bucket, reqs):
        import jax
        from repro.engine import Batch

        n_fill = bucket.batch - len(reqs)
        feat_dim = self.engine.spec.in_feats
        clouds = [r.xyz for r in reqs] + [
            np.zeros((0, 3), np.float32)] * n_fill
        feats = None
        if feat_dim > 3:
            feats = [r.feats for r in reqs] + [
                np.zeros((0, feat_dim), np.float32)] * n_fill
        fill_key = key_data(jax.random.PRNGKey(0))
        keys = np.stack([r.key for r in reqs]
                        + [fill_key] * n_fill).astype(np.uint32)
        return Batch.from_clouds(clouds, feats=feats, key=keys,
                                 n_pad=bucket.n_points)

    def _run(self, fn, batch, reqs) -> dict[int, np.ndarray]:
        """Execute one callable and slice out per-request rows,
        checking every valid row is finite (a backend returning NaN is
        a fault even when nothing raised)."""
        import jax
        out = fn(batch)
        jax.block_until_ready(out)
        out = np.asarray(out)
        rows: dict[int, np.ndarray] = {}
        for i, r in enumerate(reqs):
            row = out[i]
            # seg heads return (N, n_classes); valid prefix only
            row = row[:r.n_points] if row.ndim == 2 else row
            if not np.isfinite(row).all():
                raise _PoisonedOutput(
                    f"non-finite output for rid {r.rid} "
                    f"(bucket {bucket_str(r.bucket)})")
            rows[r.rid] = row
        return rows

    def _fire(self, bucket: Bucket, reqs) -> list[int]:
        """Pad ``reqs`` to the bucket shape and run the engine behind
        the bucket's circuit breaker: primary (unless the breaker is
        open), one-shot fallback retry on failure, structured
        :class:`RequestError` outcomes if both sides fail.  Records
        metrics and stashes per-request outcomes."""
        batch = self._build_batch(bucket, reqs)
        br = self.breakers[bucket.key]
        t_dispatch = self.clock()
        rows = None
        err: Exception | None = None
        try_primary = br.allow_primary()
        if try_primary:
            opened_before = br.open_count
            try:
                rows = self._run(self._callable_for(bucket), batch, reqs)
                br.record_success()
            except Exception as e:      # noqa: BLE001 — converted to a
                err = e                 # RequestError / fallback below
                br.record_failure()
                if br.open_count > opened_before:
                    with self._lock:
                        self.metrics.record_breaker_opened()
        degraded = False
        if rows is None and self.fallback is not None:
            try:
                rows = self._run(self._fallback_callable_for(bucket),
                                 batch, reqs)
                degraded = True
            except Exception as e:      # noqa: BLE001 — both sides down;
                err = err or e          # surfaces as RequestError below
        t_done = self.clock()
        with self._lock:
            if rows is not None:
                self.metrics.record_dispatch(
                    bucket, [(r.rid, r.n_points, r.t_arrival)
                             for r in reqs],
                    t_dispatch, t_done, degraded=degraded)
                self._results.update(rows)
            else:
                if not try_primary and self.fallback is None:
                    reason = "circuit_open"
                elif isinstance(err, _PoisonedOutput):
                    reason = "poisoned_output"
                else:
                    reason = "engine"
                self.metrics.record_failed_dispatch(len(reqs))
                for r in reqs:
                    self._results[r.rid] = RequestError(
                        r.rid, reason, bucket=bucket.key,
                        cause=None if err is None else repr(err),
                        degraded_attempted=(try_primary
                                            and self.fallback is not None))
        return [r.rid for r in reqs]

    # -- responses ----------------------------------------------------------

    def take(self, rid: int) -> np.ndarray:
        """Pop the outcome for ``rid`` (each resolved exactly once).

        Returns the logits for an answered request; raises its
        :class:`RequestError` for a failed/shed one (also popped —
        failures are observed exactly once, like responses); raises
        :class:`UnknownRequestError` (a ``KeyError``) with a diagnosis
        when there is nothing to pop: still pending, already taken, or
        never submitted."""
        with self._lock:
            if rid in self._results:
                out = self._results.pop(rid)
            elif rid in self.queue.pending_rids():
                raise UnknownRequestError(
                    rid, "still pending — poll()/drain() until "
                         "ready(rid) before taking")
            elif isinstance(rid, int) and 0 <= rid < self.queue._next_rid:
                raise UnknownRequestError(
                    rid, "already taken (outcomes pop on first take — "
                         "exactly-once semantics)")
            else:
                raise UnknownRequestError(
                    rid, "never submitted to this server")
        if isinstance(out, RequestError):
            raise out
        return out

    def ready(self, rid: int) -> bool:
        """An outcome (response *or* structured failure) is available."""
        with self._lock:
            return rid in self._results

    def failed(self, rid: int) -> bool:
        """The available outcome is a :class:`RequestError` (peek —
        does not consume it)."""
        with self._lock:
            return isinstance(self._results.get(rid), RequestError)

    def pending(self) -> int:
        with self._lock:
            return self.queue.pending()

    def report(self, **extra) -> dict:
        """Serving report (see :meth:`ServeMetrics.report`) annotated
        with the bucket config, compile count, per-bucket breaker
        states and the fault plan (if any)."""
        return self.metrics.report(
            buckets=[list(b.key) for b in self.buckets],
            timeout_ms=1e3 * self.timeout_s,
            compile_count=self.compile_count,
            engine=repr(self.engine),
            fallback=self.fallback,
            breakers={bucket_str(k): br.snapshot()
                      for k, br in self.breakers.items()},
            fault_plan=(None if self.faults is None
                        else self.faults.summary()),
            **extra)


def bucket_str(key) -> str:
    return f"{key[0]}x{key[1]}"
