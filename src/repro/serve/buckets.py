"""Size buckets: the fixed set of (batch, n_points) shapes the server
compiles.

The engine compiles one executable per input *shape* (spec/mode/backend
are static, ``n_valid`` is traced data — PR-2/PR-3 contract), so ragged
traffic must be quantized onto a small set of pre-compiled shapes or
every new cloud size triggers a fresh XLA compile.  A :class:`Bucket` is
one such shape: up to ``batch`` clouds, each padded to ``n_points``
rows.  :class:`BucketSet` owns the policy: a request of ``n`` points
maps to the *tightest* bucket (smallest ``n_points >= n``), which bounds
per-request padding waste by the gap between adjacent bucket sizes.

``BucketSet.plan`` derives bucket edges from an observed/expected size
distribution (quantile edges, rounded up to an alignment that keeps the
Pallas lane padding effective), for callers that don't hand-pick sizes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Sequence

import numpy as np

from .errors import AdmissionError  # noqa: F401  (canonical home moved
                                    # to serve/errors.py; re-exported
                                    # here for pre-taxonomy importers)


@dataclass(frozen=True)
class Bucket:
    """One pre-compiled batch shape: up to ``batch`` clouds padded to
    ``n_points`` rows each."""
    batch: int
    n_points: int

    def __post_init__(self):
        if self.batch < 1 or self.n_points < 1:
            raise ValueError(f"bucket needs batch >= 1 and n_points >= 1, "
                             f"got ({self.batch}, {self.n_points})")

    @property
    def key(self) -> tuple[int, int]:
        return (self.batch, self.n_points)

    def __str__(self):
        return f"({self.batch}x{self.n_points})"


class BucketSet:
    """An ordered set of buckets plus the request -> bucket policy."""

    def __init__(self, buckets: Iterable[Bucket]):
        bs = sorted(buckets, key=lambda b: b.n_points)
        if not bs:
            raise ValueError("BucketSet needs at least one bucket")
        sizes = [b.n_points for b in bs]
        if len(set(sizes)) != len(sizes):
            raise ValueError(f"duplicate bucket n_points in {sizes}")
        self.buckets: tuple[Bucket, ...] = tuple(bs)

    @property
    def max_points(self) -> int:
        return self.buckets[-1].n_points

    def bucket_for(self, n: int) -> Bucket:
        """Tightest admissible bucket for an ``n``-point cloud (smallest
        ``n_points >= n``); raises :class:`AdmissionError` for n < 1 or
        n beyond the largest bucket."""
        if n < 1:
            raise AdmissionError(
                f"cannot serve a {n}-point cloud (need n >= 1)")
        for b in self.buckets:
            if b.n_points >= n:
                return b
        raise AdmissionError(
            f"cloud has {n} points but the largest bucket is "
            f"{self.max_points}; add a larger bucket or downsample the "
            f"request")

    @staticmethod
    def make(n_sizes: Sequence[int], batch: int) -> "BucketSet":
        """Uniform-batch bucket set from explicit pad sizes."""
        return BucketSet(Bucket(batch, int(n)) for n in n_sizes)

    @staticmethod
    def plan(sizes: Sequence[int], *, n_buckets: int = 2, batch: int = 4,
             align: int = 64) -> "BucketSet":
        """Derive bucket edges from a sample of request sizes: quantile
        edges (equal request mass per bucket), each rounded up to a
        multiple of ``align`` so the padded shapes stay lane-friendly.
        The top edge always covers ``max(sizes)``."""
        if len(sizes) == 0:
            raise ValueError("plan needs a non-empty size sample")
        qs = np.quantile(np.asarray(sizes, np.float64),
                         [(i + 1) / n_buckets for i in range(n_buckets)])
        edges = sorted({int(-(-max(q, 1) // align) * align) for q in qs})
        return BucketSet(Bucket(batch, e) for e in edges)

    def __iter__(self):
        return iter(self.buckets)

    def __len__(self):
        return len(self.buckets)

    def __repr__(self):
        return f"BucketSet[{', '.join(map(str, self.buckets))}]"
