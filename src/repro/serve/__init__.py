"""Continuous-batching PCN serving — the async request layer.

Turns the engine's kernel/sharding wins into user-facing latency on
ragged real-world traffic: variable-size clouds are admitted one at a
time, quantized onto a small set of pre-compiled (batch, n_points)
buckets, coalesced into padded :class:`~repro.engine.Batch`es (the PR-2
``n_valid`` machinery keeps padded execution numerically exact) and
fired on batch-full or timeout, with per-request p50/p95/p99 latency,
throughput and padding-waste reporting.

    from repro import engine, serve

    eng = engine.PCNEngine(spec, mode="lpcn", fc_backend="pallas")
    params = eng.init(jax.random.PRNGKey(0))
    server = serve.PCNServer(eng, params,
                             serve.BucketSet.make([512, 1024], batch=4),
                             timeout_s=0.01)
    rid = server.submit(xyz)          # (N, 3), any N <= largest bucket
    server.poll()                     # fire due batches (timeout path)
    logits = server.take(rid)         # answered exactly once
    print(server.report())            # percentiles, throughput, waste

The failure-handling layer hardens this for an adverse world: a typed
error taxonomy (:mod:`~repro.serve.errors`) behind an admission guard
(NaN/Inf/dtype validation, size ceilings, bounded lanes with
shed-on-full), per-bucket circuit breakers with a one-shot
``"reference"``-backend fallback for failed batches, per-request
deadlines with poll-time shedding, and a deterministic fault-injection
harness (:mod:`~repro.serve.faults`) so chaos replays are reproducible
in tests, ``launch/serve.py --faults`` and CI.

CLI: ``python -m repro.launch.serve --arch pointnet2_c --trace 64``.
"""
from .breaker import CircuitBreaker
from .buckets import Bucket, BucketSet
from .dispatcher import PCNServer
from .errors import (AdmissionError, QueueFullError, RequestError,
                     ServeError, UnknownRequestError, ValidationError)
from .faults import Fault, FaultPlan, InjectedFault
from .metrics import (FAULT_COUNTERS, DispatchRecord, RequestRecord,
                      ServeMetrics, percentile_summary)
from .queue import AdmissionQueue, Request
from .trace import TraceEvent, replay, synthetic_trace

__all__ = [
    "AdmissionError", "Bucket", "BucketSet", "PCNServer",
    "AdmissionQueue", "Request", "ServeMetrics", "RequestRecord",
    "DispatchRecord", "percentile_summary", "TraceEvent",
    "synthetic_trace", "replay",
    "ServeError", "ValidationError", "QueueFullError", "RequestError",
    "UnknownRequestError", "CircuitBreaker", "Fault", "FaultPlan",
    "InjectedFault", "FAULT_COUNTERS",
]
