"""Named-component registries — the engine's plug-in mechanism.

The paper's claim is that the Islandization Unit is a *plug-in* for any
PCN accelerator workflow; the software equivalent is that every swappable
stage of the building block — the sampler, the neighbor-search method and
the Feature-Computation backend — is resolved by name through a registry
instead of an ``if/elif`` chain.  Third-party code extends the engine with

    from repro.engine import register_sampler

    @register_sampler("my_sampler")
    def my_sampler(xyz, *, tree, n_centers, key):
        ...

Interfaces (all jit/vmap-safe, static shapes):

  sampler(xyz, *, tree, n_centers, key, n_valid)  -> (n_centers,) int32
  neighbor(xyz, centers, *, tree, k, radius,
           octree_level, n_valid)                 -> (S, K) int32
  fc backend: an :class:`FCBackend` (see core.pipeline) with ``dense`` and
  ``reuse`` callables — registered by ``core.pipeline`` ("reference") and
  ``repro.engine.fc`` ("pallas").

Ragged-batch contract: ``n_valid`` (None or a traced count) marks rows
>= n_valid of ``xyz`` as padding.  Samplers must never select them;
neighbor methods must never return them (slots that cannot be filled
with valid points are ``-1``, which the FC pools treat as empty).  The
batched engine (``engine.apply`` and friends) always passes ``n_valid``
— it is a traced per-cloud value there, even for full batches — so
components used through it MUST accept the kwarg; only the eager
per-cloud paths (``apply_single`` / ``lpcn_block`` without ``n_valid``)
omit it, keeping pre-ragged third-party components usable there.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import neighbor as nb
from . import sampling


class Registry:
    """A small name -> component table with clear failure modes."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict = {}

    def register(self, name: str, value=None):
        """Register ``value`` under ``name``; usable as a decorator."""
        def _add(v):
            if name in self._entries:
                raise ValueError(
                    f"duplicate {self.kind} {name!r}: already registered; "
                    f"pick a distinct name or remove the old entry first")
            self._entries[name] = v
            return v
        return _add if value is None else _add(value)

    def get(self, name: str):
        try:
            return self._entries[name]
        except KeyError:
            known = ", ".join(sorted(self._entries)) or "<none>"
            raise KeyError(
                f"unknown {self.kind} {name!r}; registered {self.kind}s: "
                f"{known}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def names(self) -> tuple:
        return tuple(sorted(self._entries))


SAMPLERS = Registry("sampler")
NEIGHBORS = Registry("neighbor")
FC_BACKENDS = Registry("fc_backend")


def register_sampler(name: str, fn=None):
    return SAMPLERS.register(name, fn)


def register_neighbor(name: str, fn=None):
    return NEIGHBORS.register(name, fn)


def register_fc_backend(name: str, backend=None):
    return FC_BACKENDS.register(name, backend)


# ---- default samplers (paper Fig. 6) ---------------------------------------

@register_sampler("fps")
def _fps(xyz, *, tree, n_centers, key, n_valid=None):
    del tree, key
    valid = None if n_valid is None else jnp.arange(xyz.shape[0]) < n_valid
    return sampling.farthest_point_sampling(xyz, n_centers, valid=valid)


@register_sampler("random")
def _random(xyz, *, tree, n_centers, key, n_valid=None):
    del tree
    return sampling.random_sampling(key, xyz.shape[0], n_centers, n_valid)


@register_sampler("morton")
def _morton(xyz, *, tree, n_centers, key, n_valid=None):
    del key
    return sampling.morton_strided_sampling(tree.order, n_centers, n_valid)


@register_sampler("all")
def _all(xyz, *, tree, n_centers, key, n_valid=None):
    """DGCNN: every point is a center.  Padding rows stay in the center
    list (static shape) — the block masks them via ``center_valid``."""
    del tree, key, n_valid
    return jnp.arange(xyz.shape[0], dtype=jnp.int32)


# ---- default neighbor methods (the four DS baselines + ball query) ---------

@register_neighbor("pointacc")
def _pointacc(xyz, centers, *, tree, k, radius, octree_level, n_valid=None):
    del tree, radius, octree_level
    return nb.knn_bruteforce(xyz, centers, k, n_valid)


@register_neighbor("hgpcn")
def _hgpcn(xyz, centers, *, tree, k, radius, octree_level, n_valid=None):
    del radius
    # density-adaptive narrowing level: expected >= k points within the
    # 27-voxel neighborhood (keeps HgPCN in the accurate class)
    lvl = max(1, min(octree_level,
                     int(math.log(max(xyz.shape[0] / k, 2), 8))))
    return nb.knn_octree(tree, xyz, centers, k, level=lvl, n_valid=n_valid)


@register_neighbor("edgepc")
def _edgepc(xyz, centers, *, tree, k, radius, octree_level, n_valid=None):
    del radius, octree_level
    return nb.knn_morton_window(tree, xyz, centers, k, n_valid=n_valid)


@register_neighbor("crescent")
def _crescent(xyz, centers, *, tree, k, radius, octree_level, n_valid=None):
    del tree, radius, octree_level
    return nb.knn_kdtree_approx(xyz, centers, k, n_valid=n_valid)


@register_neighbor("ball")
def _ball(xyz, centers, *, tree, k, radius, octree_level, n_valid=None):
    del tree, octree_level
    return nb.ball_query(xyz, centers, radius, k, n_valid)


def get_fc_backend(name: str):
    """Resolve an FC backend, loading the kernel-backed ones on demand
    (``repro.engine.fc`` registers "pallas" on import)."""
    if name not in FC_BACKENDS:
        try:
            import repro.engine.fc  # noqa: F401  (registers backends)
        except ImportError as e:
            raise ImportError(
                f"fc_backend {name!r} is not registered and the kernel "
                f"backends (repro.engine.fc) failed to import: {e}") from e
    return FC_BACKENDS.get(name)
