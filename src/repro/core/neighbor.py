"""Neighbor Search Module — accurate and approximate neighbor gathering.

The four baseline accelerators differ only in this step (paper §VI-A):
  * accurate: PointACC (brute-force rank), HgPCN (octree-narrowed rank)
  * approximate: EdgePC (Morton-window), Crescent (tree-approximate)
All four are implemented so the Islandization Unit can be benchmarked as a
plug-in on top of each, exactly as the paper does.

Ragged-batch contract: every method takes an optional ``n_valid`` count
and then never returns a padding row as a neighbor.  The accurate
methods mark slots they cannot fill with valid points as ``-1`` (e.g.
k > n_valid, or a ball query whose radius holds no valid point); the
window/bucket approximations degrade to repeating valid candidates.
Downstream (hub scheduling, both FC dataflows) treats ``-1`` as an empty
slot that is excluded from caches, pools and workload counters.  Like the
samplers, all methods are shape-stable: the result on a padded cloud with
``n_valid = n`` equals the result on the unpadded (n, 3) prefix.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import morton
from .octree import LinearOctree


def pairwise_sqdist(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(S,3),(N,3) -> (S,N) squared distances (the DSU distance array)."""
    return jnp.sum((a[:, None, :] - b[None, :, :]) ** 2, axis=-1)


def masked_sqdist(centers: jnp.ndarray, points: jnp.ndarray,
                  n_valid=None) -> jnp.ndarray:
    """(S, N) squared distances with padding columns pinned to +inf so no
    rank/top-k ever selects an invalid point."""
    d = pairwise_sqdist(centers, points)
    if n_valid is None:
        return d
    col_ok = jnp.arange(points.shape[0])[None, :] < n_valid
    return jnp.where(col_ok, d, jnp.inf)


def masked_bounds(points: jnp.ndarray, n_valid=None):
    """Bounding box of the valid prefix (padding rows excluded, so
    arbitrary padding content cannot shift Morton quantization)."""
    valid = None if n_valid is None else \
        jnp.arange(points.shape[0]) < n_valid
    return morton.masked_bounds(points, valid)


@partial(jax.jit, static_argnames=("k",))
def knn_bruteforce(points: jnp.ndarray, centers: jnp.ndarray, k: int,
                   n_valid=None) -> jnp.ndarray:
    """Accurate KNN (PointACC's ranking kernel): (S, k) int32 indices into
    ``points``, nearest first; ``-1`` for slots beyond the valid count."""
    d = masked_sqdist(centers, points, n_valid)
    neg, idx = jax.lax.top_k(-d, k)
    idx = idx.astype(jnp.int32)
    if n_valid is not None:
        idx = jnp.where(jnp.isfinite(neg), idx, -1)
    return idx


@partial(jax.jit, static_argnames=("k",))
def ball_query(points: jnp.ndarray, centers: jnp.ndarray, radius: float,
               k: int, n_valid=None) -> jnp.ndarray:
    """PointNet++ Ball Query: first k points within ``radius``; slots past
    the in-radius count repeat the first in-radius point (reference
    semantics of the original CUDA kernel, including the empty-radius
    fallback to point 0 when unmasked).  With ``n_valid``, padding rows
    never count as in-radius and a center whose radius contains zero
    *valid* points gets an all ``-1`` row (the FC pools zero-fill such
    subsets)."""
    d = pairwise_sqdist(centers, points)  # (S, N)
    inb = d <= radius * radius
    if n_valid is not None:
        inb &= jnp.arange(points.shape[0])[None, :] < n_valid
    # rank in-radius points by original index order (first-k semantics)
    big = jnp.asarray(points.shape[0], jnp.int32)
    ranked = jnp.where(inb, jnp.arange(points.shape[0], dtype=jnp.int32)[None, :], big)
    idx = jnp.argsort(ranked, axis=-1)[:, :k].astype(jnp.int32)
    got = jnp.take_along_axis(ranked, idx, axis=-1) < big
    first = idx[:, :1]
    if n_valid is not None:
        first = jnp.where(got[:, :1], first, -1)
    return jnp.where(got, idx, first)


@partial(jax.jit, static_argnames=("k", "window"))
def knn_morton_window(tree: LinearOctree, points: jnp.ndarray,
                      centers: jnp.ndarray, k: int, window: int = 128,
                      n_valid=None) -> jnp.ndarray:
    """EdgePC-style approximate KNN: candidates = a window of ``window``
    points around the center's position in Morton order; exact KNN within
    the window.  (S, k) indices into ``points``.

    With ``n_valid`` the window slides over the valid prefix of a
    valid-first tree (``octree.build(..., n_valid=...)`` sorts padding to
    the back with sentinel codes), so candidates are always valid; a
    short prefix degrades to repeated candidates, never to padding.
    """
    n = tree.codes.shape[0]
    lo, hi = masked_bounds(points, n_valid)
    ccodes = morton.morton_codes(centers, tree.depth, lo=lo, hi=hi)
    pos = jnp.searchsorted(tree.codes, ccodes)
    count = n if n_valid is None else n_valid
    start = jnp.clip(pos - window // 2, 0, jnp.maximum(count - window, 0))
    cand_sorted = start[:, None] + jnp.arange(window)[None, :]   # (S, W)
    cand = tree.order[jnp.clip(cand_sorted, 0, count - 1)]       # (S, W)
    cpts = points[cand]                                          # (S, W, 3)
    d = jnp.sum((cpts - centers[:, None, :]) ** 2, axis=-1)
    _, j = jax.lax.top_k(-d, k)
    return jnp.take_along_axis(cand, j, axis=-1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("k", "level"))
def knn_octree(tree: LinearOctree, points: jnp.ndarray,
               centers: jnp.ndarray, k: int, level: int = 6,
               n_valid=None) -> jnp.ndarray:
    """HgPCN-style accurate-with-narrowing KNN: candidates = the center's
    octree node + its 26 neighbors at ``level`` (guaranteed superset for
    radius < voxel side); exact rank within.  Falls back to global top-k
    distance through masking (non-candidates get +inf).  Padding rows are
    pinned to +inf in both the narrowed and fallback distance arrays;
    unfillable slots return ``-1``."""
    n = tree.codes.shape[0]
    lo, hi = masked_bounds(points, n_valid)
    ccodes = morton.morton_codes(centers, tree.depth, lo=lo, hi=hi)
    ckeys = morton.node_key(ccodes, level, tree.depth)
    from .octree import adjacent_node_keys
    nkeys = adjacent_node_keys(ckeys, level, tree.depth)         # (S, 27)
    shift = jnp.uint32(3 * (tree.depth - level))
    pkeys = tree.codes >> shift                                  # (N,)
    # mask: point belongs to one of the 27 candidate nodes (padding rows
    # carry sentinel codes whose shifted key exceeds every real node key)
    member = (pkeys[None, :, None] == nkeys[:, None, :]).any(-1)  # (S, N)
    d_true = pairwise_sqdist(centers, points[tree.order])
    if n_valid is not None:
        sorted_ok = jnp.arange(n)[None, :] < n_valid
        member &= sorted_ok
        d_true = jnp.where(sorted_ok, d_true, jnp.inf)
    d = jnp.where(member, d_true, jnp.inf)
    # fall back to true distance where fewer than k valid candidates exist
    enough = member.sum(-1, keepdims=True) >= k
    d = jnp.where(enough, d, d_true)
    neg, j = jax.lax.top_k(-d, k)
    out = tree.order[j].astype(jnp.int32)
    if n_valid is not None:
        out = jnp.where(jnp.isfinite(neg), out, -1)
    return out


@partial(jax.jit, static_argnames=("k", "leaf"))
def knn_kdtree_approx(points: jnp.ndarray, centers: jnp.ndarray, k: int,
                      leaf: int = 64, n_valid=None) -> jnp.ndarray:
    """Crescent-style approximate KNN: median-split KD buckets (built by
    recursive argsort at trace time -> a static permutation), search only
    the center's bucket and the adjacent bucket.  Approximate by design.
    Padding rows sort to the back with sentinel codes and the buckets
    cover only the valid prefix."""
    n = points.shape[0]
    # Build a balanced KD ordering with numpy-free lax: we emulate with
    # Morton order as the bucketization (Crescent's delta-approximation of
    # tree search maps to locality-preserving bucketing on TPU).
    lo, hi = masked_bounds(points, n_valid)
    codes = morton.morton_codes(points, lo=lo, hi=hi)
    if n_valid is not None:
        codes = jnp.where(jnp.arange(n) < n_valid, codes,
                          jnp.uint32(morton.SENTINEL))
    order = jnp.argsort(codes)
    ccodes = morton.morton_codes(centers, lo=lo, hi=hi)
    pos = jnp.searchsorted(codes[order], ccodes)
    count = n if n_valid is None else n_valid
    bucket = jnp.clip(pos // leaf, 0, jnp.maximum(count // leaf - 1, 0))
    start = jnp.clip(bucket * leaf - leaf // 2, 0,
                     jnp.maximum(count - 2 * leaf, 0))
    cand_sorted = start[:, None] + jnp.arange(2 * leaf)[None, :]
    cand = order[jnp.clip(cand_sorted, 0, count - 1)]
    d = jnp.sum((points[cand] - centers[:, None, :]) ** 2, axis=-1)
    _, j = jax.lax.top_k(-d, k)
    return jnp.take_along_axis(cand, j, axis=-1).astype(jnp.int32)


METHODS = {
    "pointacc": "knn_bruteforce",     # accurate, brute-force rank
    "hgpcn": "knn_octree",            # accurate, octree-narrowed
    "edgepc": "knn_morton_window",    # approximate, Morton window
    "crescent": "knn_kdtree_approx",  # approximate, tree buckets
}
