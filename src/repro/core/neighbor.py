"""Neighbor Search Module — accurate and approximate neighbor gathering.

The four baseline accelerators differ only in this step (paper §VI-A):
  * accurate: PointACC (brute-force rank), HgPCN (octree-narrowed rank)
  * approximate: EdgePC (Morton-window), Crescent (tree-approximate)
All four are implemented so the Islandization Unit can be benchmarked as a
plug-in on top of each, exactly as the paper does.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import morton
from .octree import LinearOctree


def pairwise_sqdist(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(S,3),(N,3) -> (S,N) squared distances (the DSU distance array)."""
    return jnp.sum((a[:, None, :] - b[None, :, :]) ** 2, axis=-1)


@partial(jax.jit, static_argnames=("k",))
def knn_bruteforce(points: jnp.ndarray, centers: jnp.ndarray, k: int
                   ) -> jnp.ndarray:
    """Accurate KNN (PointACC's ranking kernel): (S, k) int32 indices into
    ``points``, nearest first."""
    d = pairwise_sqdist(centers, points)
    _, idx = jax.lax.top_k(-d, k)
    return idx.astype(jnp.int32)


@partial(jax.jit, static_argnames=("k",))
def ball_query(points: jnp.ndarray, centers: jnp.ndarray, radius: float,
               k: int) -> jnp.ndarray:
    """PointNet++ Ball Query: first k points within ``radius``; slots past
    the in-radius count repeat the first in-radius point (reference
    semantics of the original CUDA kernel)."""
    d = pairwise_sqdist(centers, points)  # (S, N)
    inb = d <= radius * radius
    # rank in-radius points by original index order (first-k semantics)
    big = jnp.asarray(points.shape[0], jnp.int32)
    ranked = jnp.where(inb, jnp.arange(points.shape[0], dtype=jnp.int32)[None, :], big)
    idx = jnp.argsort(ranked, axis=-1)[:, :k].astype(jnp.int32)
    got = jnp.take_along_axis(ranked, idx, axis=-1) < big
    first = idx[:, :1]
    return jnp.where(got, idx, first)


@partial(jax.jit, static_argnames=("k", "window"))
def knn_morton_window(tree: LinearOctree, points: jnp.ndarray,
                      centers: jnp.ndarray, k: int, window: int = 128
                      ) -> jnp.ndarray:
    """EdgePC-style approximate KNN: candidates = a window of ``window``
    points around the center's position in Morton order; exact KNN within
    the window.  (S, k) indices into ``points``."""
    n = tree.codes.shape[0]
    ccodes = morton.morton_codes(centers, tree.depth,
                                 lo=points.min(0), hi=points.max(0))
    pos = jnp.searchsorted(tree.codes, ccodes)
    start = jnp.clip(pos - window // 2, 0, max(n - window, 0))
    cand_sorted = start[:, None] + jnp.arange(window)[None, :]   # (S, W)
    cand = tree.order[jnp.clip(cand_sorted, 0, n - 1)]           # (S, W)
    cpts = points[cand]                                          # (S, W, 3)
    d = jnp.sum((cpts - centers[:, None, :]) ** 2, axis=-1)
    _, j = jax.lax.top_k(-d, k)
    return jnp.take_along_axis(cand, j, axis=-1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("k", "level"))
def knn_octree(tree: LinearOctree, points: jnp.ndarray,
               centers: jnp.ndarray, k: int, level: int = 6
               ) -> jnp.ndarray:
    """HgPCN-style accurate-with-narrowing KNN: candidates = the center's
    octree node + its 26 neighbors at ``level`` (guaranteed superset for
    radius < voxel side); exact rank within.  Falls back to global top-k
    distance through masking (non-candidates get +inf)."""
    ccodes = morton.morton_codes(centers, tree.depth,
                                 lo=points.min(0), hi=points.max(0))
    ckeys = morton.node_key(ccodes, level, tree.depth)
    from .octree import adjacent_node_keys
    nkeys = adjacent_node_keys(ckeys, level, tree.depth)         # (S, 27)
    shift = jnp.uint32(3 * (tree.depth - level))
    pkeys = tree.codes >> shift                                  # (N,)
    # mask: point belongs to one of the 27 candidate nodes
    member = (pkeys[None, :, None] == nkeys[:, None, :]).any(-1)  # (S, N)
    d = pairwise_sqdist(centers, points[tree.order])
    d = jnp.where(member, d, jnp.inf)
    # fall back to true distance where fewer than k candidates exist
    enough = member.sum(-1, keepdims=True) >= k
    d = jnp.where(enough, d, pairwise_sqdist(centers, points[tree.order]))
    _, j = jax.lax.top_k(-d, k)
    return tree.order[j].astype(jnp.int32)


@partial(jax.jit, static_argnames=("k", "leaf"))
def knn_kdtree_approx(points: jnp.ndarray, centers: jnp.ndarray, k: int,
                      leaf: int = 64) -> jnp.ndarray:
    """Crescent-style approximate KNN: median-split KD buckets (built by
    recursive argsort at trace time -> a static permutation), search only
    the center's bucket and the adjacent bucket.  Approximate by design."""
    n = points.shape[0]
    # Build a balanced KD ordering with numpy-free lax: we emulate with
    # Morton order as the bucketization (Crescent's delta-approximation of
    # tree search maps to locality-preserving bucketing on TPU).
    codes = morton.morton_codes(points)
    order = jnp.argsort(codes)
    ccodes = morton.morton_codes(centers, lo=points.min(0), hi=points.max(0))
    pos = jnp.searchsorted(codes[order], ccodes)
    bucket = jnp.clip(pos // leaf, 0, max(n // leaf - 1, 0))
    start = jnp.clip(bucket * leaf - leaf // 2, 0, max(n - 2 * leaf, 0))
    cand_sorted = start[:, None] + jnp.arange(2 * leaf)[None, :]
    cand = order[jnp.clip(cand_sorted, 0, n - 1)]
    d = jnp.sum((points[cand] - centers[:, None, :]) ** 2, axis=-1)
    _, j = jax.lax.top_k(-d, k)
    return jnp.take_along_axis(cand, j, axis=-1).astype(jnp.int32)


METHODS = {
    "pointacc": "knn_bruteforce",     # accurate, brute-force rank
    "hgpcn": "knn_octree",            # accurate, octree-narrowed
    "edgepc": "knn_morton_window",    # approximate, Morton window
    "crescent": "knn_kdtree_approx",  # approximate, tree buckets
}
