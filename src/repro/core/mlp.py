"""Shared point-MLP of the FC step (the paper's systolic-array workload).

Two activation placements (paper §VI-E):
  * ``per_layer`` — ReLU after every layer but the last (PointNet++
    default); delta compensation is approximate.
  * ``block_end`` — all layers linear, one activation applied *after*
    pooling (DGCNN(c) / PointVector-L style); compensation is exact.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclass
class Dense:
    w: jnp.ndarray
    b: jnp.ndarray

    def tree_flatten(self):
        return (self.w, self.b), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclass
class MLP:
    layers: list  # [Dense]
    activation: str = "per_layer"  # per_layer | block_end

    def tree_flatten(self):
        return (self.layers,), (self.activation,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])

    @property
    def f_in(self) -> int:
        return self.layers[0].w.shape[0]

    @property
    def f_out(self) -> int:
        return self.layers[-1].w.shape[1]

    def flops_per_point(self) -> int:
        return sum(2 * l.w.shape[0] * l.w.shape[1] for l in self.layers)


def init_mlp(key: jax.Array, dims: list[int],
             activation: str = "per_layer",
             dtype=jnp.float32) -> MLP:
    layers = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (a, b), dtype) * jnp.sqrt(2.0 / a)
        layers.append(Dense(w=w, b=jnp.zeros((b,), dtype)))
    return MLP(layers=layers, activation=activation)


def apply_mlp(mlp: MLP, x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., f_in) -> (..., f_out)."""
    n = len(mlp.layers)
    for i, l in enumerate(mlp.layers):
        x = x @ l.w + l.b
        if mlp.activation == "per_layer" and i < n - 1:
            x = jax.nn.relu(x)
    return x


def post_pool_activation(mlp: MLP, x: jnp.ndarray) -> jnp.ndarray:
    if mlp.activation == "block_end":
        return jax.nn.relu(x)
    return x
