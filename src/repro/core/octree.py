"""Linear octree over Morton-sorted points (TPU-native octree-search engine).

The paper's hardware keeps the Input Octree / Sampled Octree / Hub Octrees in
BRAM and walks them with two pipelined Octree-Search Engines.  The linear
octree gives the same queries as array primitives:

  * ``node of point at depth d``      -> shift of its Morton code
  * ``points inside node``            -> contiguous slice of the sorted array
                                         found with two ``searchsorted``
  * ``membership test`` (Hub-Octree
    hit/miss of Overlap Detection)    -> ``searchsorted`` + equality check
  * ``adjacent nodes`` (Partitioning
    Module's round-based gathering)   -> decode key, +/-1 on each axis,
                                         re-encode (26-connectivity)

Everything is jittable; a numpy mirror lives in the analytics path.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from . import morton


@jax.tree_util.register_pytree_node_class
@dataclass
class LinearOctree:
    """Morton-sorted point index — the Input/Sampled Octree of the paper.

    Attributes:
      codes:  (N,) uint32 Morton codes, sorted ascending.
      order:  (N,) int32 permutation: codes[i] belongs to points[order[i]].
      depth:  quantization depth used for the codes.
    """
    codes: jnp.ndarray
    order: jnp.ndarray
    depth: int

    def tree_flatten(self):
        return (self.codes, self.order), (self.depth,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    # -- queries ------------------------------------------------------------

    def node_keys(self, level: int) -> jnp.ndarray:
        """Per sorted point: its octree-node key at ``level``."""
        return morton.node_key(self.codes, level, self.depth)

    def node_range(self, key: jnp.ndarray, level: int):
        """[start, end) range in the sorted array of node ``key`` at
        ``level``.  Works for batched keys."""
        shift = jnp.uint32(3 * (self.depth - level))
        lo = (key.astype(jnp.uint32) << shift)
        hi = ((key.astype(jnp.uint32) + jnp.uint32(1)) << shift)
        start = jnp.searchsorted(self.codes, lo, side="left")
        end = jnp.searchsorted(self.codes, hi, side="left")
        return start, end

    def contains(self, query_codes: jnp.ndarray) -> jnp.ndarray:
        """Exact membership of full-depth codes (Overlap Detection hit
        test).  Returns bool mask, plus the index of the hit (or -1)."""
        pos = jnp.searchsorted(self.codes, query_codes, side="left")
        pos = jnp.clip(pos, 0, self.codes.shape[0] - 1)
        hit = self.codes[pos] == query_codes
        return hit, jnp.where(hit, pos, -1)


def build(points: jnp.ndarray, depth: int = morton.MAX_DEPTH,
          lo=None, hi=None, n_valid=None) -> LinearOctree:
    """Build the linear octree for a point cloud (N, 3).

    ``n_valid`` marks rows >= n_valid as padding: their codes become the
    uint32 sentinel (larger than any 30-bit Morton code), so the sorted
    order is *valid-first* — ``order[:n_valid]`` equals the order built
    on the unpadded prefix — and the quantization box is computed from
    valid rows only (arbitrary padding content cannot shift it).
    """
    if n_valid is not None and lo is None and hi is None:
        lo, hi = morton.masked_bounds(
            points, jnp.arange(points.shape[0]) < n_valid)
    codes = morton.morton_codes(points, depth, lo, hi)
    if n_valid is not None:
        codes = jnp.where(jnp.arange(points.shape[0]) < n_valid, codes,
                          jnp.uint32(morton.SENTINEL))
    order = jnp.argsort(codes)
    return LinearOctree(codes=codes[order], order=order.astype(jnp.int32),
                        depth=depth)


def prune(tree: LinearOctree, keep_sorted_idx: jnp.ndarray) -> LinearOctree:
    """The paper's Pruning Module: Sampled Octree = Input Octree restricted
    to the sampled (central) points.  ``keep_sorted_idx`` indexes the sorted
    arrays."""
    return LinearOctree(codes=tree.codes[keep_sorted_idx],
                        order=tree.order[keep_sorted_idx], depth=tree.depth)


@partial(jax.jit, static_argnames=("level", "depth"))
def adjacent_node_keys(keys: jnp.ndarray, level: int,
                       depth: int = morton.MAX_DEPTH) -> jnp.ndarray:
    """26-connectivity neighbor node keys (+ self) of octree nodes.

    keys: (...,) uint32 node keys at ``level``.  Returns (..., 27) uint32.
    Out-of-bounds neighbors are replaced by the node's own key (harmless
    duplicates for the BFS gathering use-case).
    """
    side = 1 << level
    # A node key at `level` is itself a Morton code over `level` bits/axis.
    xyz = morton.decode(keys.astype(jnp.uint32)).astype(jnp.int32)  # (...,3)
    offs = jnp.stack(jnp.meshgrid(jnp.arange(-1, 2), jnp.arange(-1, 2),
                                  jnp.arange(-1, 2), indexing="ij"),
                     axis=-1).reshape(27, 3)
    nxyz = xyz[..., None, :] + offs  # (..., 27, 3)
    valid = jnp.all((nxyz >= 0) & (nxyz < side), axis=-1)
    nxyz = jnp.clip(nxyz, 0, side - 1).astype(jnp.uint32)
    nkeys = morton.encode(nxyz)
    return jnp.where(valid, nkeys, keys[..., None].astype(jnp.uint32))
