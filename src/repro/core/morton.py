"""Morton (Z-order) codes — the octree linearization used throughout L-PCN.

The paper's Octree-Search Engines traverse a pointer octree keyed by Morton
codes [35].  On TPU we use the *linear octree* equivalent: points are sorted
by Morton code once; every octree node at depth d is then a contiguous range
of the sorted array, and octree search becomes binary search
(``jnp.searchsorted``) over the keys — fully vectorized, no pointer chasing.

Hardware adaptation note (DESIGN.md §2): TPUs have no native 64-bit integer
lanes, so codes are uint32 with 10 bits/axis (1024^3 voxels).  That bounds
octree depth at 10 — ample for the paper's workloads (islandization uses
level <= 8; point identity is by index, not by code, so code collisions in
ultra-dense clouds only make two points share a voxel, never corrupt
identity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

MAX_DEPTH = 10  # 10 bits per axis -> 30-bit codes in uint32
SENTINEL = 0xFFFFFFFF  # > any 30-bit code


def _part1by2(x: jnp.ndarray) -> jnp.ndarray:
    """Spread the low 10 bits of ``x`` so there are two zero bits between
    each original bit (uint32 in/out)."""
    x = x.astype(jnp.uint32) & jnp.uint32(0x3FF)
    x = (x | (x << jnp.uint32(16))) & jnp.uint32(0x030000FF)
    x = (x | (x << jnp.uint32(8))) & jnp.uint32(0x0300F00F)
    x = (x | (x << jnp.uint32(4))) & jnp.uint32(0x030C30C3)
    x = (x | (x << jnp.uint32(2))) & jnp.uint32(0x09249249)
    return x


def _compact1by2(x: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`_part1by2`."""
    x = x.astype(jnp.uint32) & jnp.uint32(0x09249249)
    x = (x | (x >> jnp.uint32(2))) & jnp.uint32(0x030C30C3)
    x = (x | (x >> jnp.uint32(4))) & jnp.uint32(0x0300F00F)
    x = (x | (x >> jnp.uint32(8))) & jnp.uint32(0x030000FF)
    x = (x | (x >> jnp.uint32(16))) & jnp.uint32(0x3FF)
    return x


def masked_bounds(points: jnp.ndarray, valid: jnp.ndarray | None = None):
    """(lo, hi) bounding box over the rows where ``valid`` is True (bool
    mask; None = all rows).  The padding-safe bbox every masked Morton
    quantization shares: excluded rows cannot shift the box, so codes of
    valid points are identical with and without padding."""
    if valid is None:
        return points.min(0), points.max(0)
    ok = valid[:, None]
    return (jnp.where(ok, points, jnp.inf).min(0),
            jnp.where(ok, points, -jnp.inf).max(0))


def quantize(points: jnp.ndarray, depth: int = MAX_DEPTH,
             lo: jnp.ndarray | None = None,
             hi: jnp.ndarray | None = None) -> jnp.ndarray:
    """Quantize float xyz points into integer voxel coordinates at ``depth``.

    points: (..., 3) float.  Returns (..., 3) uint32 in [0, 2**depth).
    ``lo``/``hi`` give the bounding box; default = per-cloud min/max.
    """
    if lo is None:
        lo = points.reshape(-1, 3).min(axis=0)
    if hi is None:
        hi = points.reshape(-1, 3).max(axis=0)
    extent = jnp.maximum(jnp.max(hi - lo), 1e-9)
    n = (1 << depth) - 1
    scaled = (points - lo) / extent * n
    return jnp.clip(scaled, 0, n).astype(jnp.uint32)


def encode(ivox: jnp.ndarray) -> jnp.ndarray:
    """Interleave integer voxel coords (..., 3) uint32 -> Morton uint32."""
    x = _part1by2(ivox[..., 0])
    y = _part1by2(ivox[..., 1])
    z = _part1by2(ivox[..., 2])
    return x | (y << jnp.uint32(1)) | (z << jnp.uint32(2))


def decode(codes: jnp.ndarray) -> jnp.ndarray:
    """Morton uint32 -> (..., 3) uint32 voxel coordinates."""
    x = _compact1by2(codes)
    y = _compact1by2(codes >> jnp.uint32(1))
    z = _compact1by2(codes >> jnp.uint32(2))
    return jnp.stack([x, y, z], axis=-1).astype(jnp.uint32)


def morton_codes(points: jnp.ndarray, depth: int = MAX_DEPTH,
                 lo=None, hi=None) -> jnp.ndarray:
    """points (..., 3) float -> Morton codes (...,) uint32 at ``depth``."""
    return encode(quantize(points, depth, lo, hi))


def node_key(codes: jnp.ndarray, depth: int, full_depth: int = MAX_DEPTH
             ) -> jnp.ndarray:
    """Octree-node key at ``depth`` of a point coded at ``full_depth``:
    drop the trailing 3*(full_depth-depth) bits."""
    shift = jnp.uint32(3 * (full_depth - depth))
    return codes >> shift


# ---------------------------------------------------------------------------
# numpy twins (used by analytics / dataset tooling; bit-identical)
# ---------------------------------------------------------------------------

def _np_part1by2(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32) & np.uint32(0x3FF)
    x = (x | (x << np.uint32(16))) & np.uint32(0x030000FF)
    x = (x | (x << np.uint32(8))) & np.uint32(0x0300F00F)
    x = (x | (x << np.uint32(4))) & np.uint32(0x030C30C3)
    x = (x | (x << np.uint32(2))) & np.uint32(0x09249249)
    return x


def np_morton_codes(points: np.ndarray, depth: int = MAX_DEPTH,
                    lo=None, hi=None) -> np.ndarray:
    pts = np.asarray(points, dtype=np.float64)
    if lo is None:
        lo = pts.reshape(-1, 3).min(axis=0)
    if hi is None:
        hi = pts.reshape(-1, 3).max(axis=0)
    extent = max(float(np.max(np.asarray(hi) - np.asarray(lo))), 1e-9)
    n = (1 << depth) - 1
    iv = np.clip((pts - lo) / extent * n, 0, n).astype(np.uint32)
    return (_np_part1by2(iv[..., 0])
            | (_np_part1by2(iv[..., 1]) << np.uint32(1))
            | (_np_part1by2(iv[..., 2]) << np.uint32(2)))
