"""The L-PCN Building Block: Data Structuring → Islandization → Feature
Computation (paper Fig. 2/5/13), as one composable JAX module.

``lpcn_block`` runs a full PCN building block for one cloud and returns
(center_xyz, center_features, workload report).  Execution modes:

  * ``traditional`` — every subset fully fetched + computed (the baseline
    every accelerator in Fig. 16 uses for its FCU);
  * ``lpcn`` — Octree-based Islandization + Hub-based Scheduling: pool MLP
    once per island (hub-relative), compensated reuse for cached positions,
    compact overflow buffer for the rest.  FLOPs genuinely shrink: the MLP
    runs on (H·C + overflow_budget + fallback) points, not S·K.

Block kinds:  ``sa``  — Set Abstraction (PointNet++/PointNeXt/PointVector),
MLP input [p − c, f];  ``edge`` — EdgeConv (DGCNN), MLP input [f_j − f_i,
f_i].  Delta compensation handles both (delta_comp.py).

The Pallas kernels (kernels/gather_mlp, kernels/hub_reuse) implement the
same two dataflows for the MXU; this file is their jnp oracle and the
default CPU path.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable

import jax
import jax.numpy as jnp

from . import octree as oct
from .delta_comp import compensation
from .hub_schedule import Schedule, build_schedule
from .islandize import Islands, islandize
from .mlp import MLP, apply_mlp, post_pool_activation
from .registry import FC_BACKENDS, NEIGHBORS, SAMPLERS, get_fc_backend
from .workload import WorkloadReport, analyze

BIG = 3.4e38


@dataclass(frozen=True)
class LPCNConfig:
    """Hyper-parameters of one building block (paper defaults)."""
    n_centers: int = 512
    k: int = 32
    sampler: str = "fps"              # any registered sampler
    neighbor: str = "pointacc"        # any registered neighbor method
    radius: float = 0.2               # ball query radius
    mode: str = "lpcn"                # traditional | lpcn
    block_kind: str = "sa"            # sa | edge
    island_size: int = 32             # subsets per island (paper default)
    island_capacity: int = 64         # island-list rows (2x headroom)
    cache_capacity_x: float = 2.0     # hub cache = x * k (paper: 2x)
    compensation: str = "linear"      # linear | mlp
    octree_level: int = 4
    hub_select: str = "random"
    overflow_frac: float = 0.5        # compact overflow buffer / (M*K)
    fc_backend: str = "reference"     # any registered FC backend

    @property
    def cache_capacity(self) -> int:
        return int(self.cache_capacity_x * self.k)


@dataclass(frozen=True)
class FCBackend:
    """A Feature-Computation dataflow implementation (the paper's FCU).

    ``dense`` is the traditional path — subset-normalize, MLP, max-pool —
    returning (S, F_out) pooled pre-activation features.  ``reuse`` is the
    Islandization Unit's pool-MLP + compensated reuse-gather returning
    (H, M, F_out) per-subset pooled reuse partials, ``-BIG`` where a subset
    has no cached position.  Both must be jit/vmap-safe; the "reference"
    backend is pure jnp, the "pallas" backend (repro.engine.fc) routes the
    same dataflows through the kernels in repro.kernels.

    dense(mlp, kind, xyz, feats, nbr_idx, centers_xyz, center_feats,
          nbr_valid)
    reuse(mlp, pool_in, slot, comp, live)

    ``dense_batched`` / ``reuse_batched`` (optional) are the natively
    batched entry points used by the batch-first engine: the same
    dataflows with a leading (B,) axis on every array operand, expected
    to present the whole cloud stack to the accelerator as ONE schedule
    (e.g. one pallas_call with the batch folded into the kernel grid).
    They additionally take ``kernel_kw`` — an opaque dict of tuning knobs
    (tile sizes, VMEM budget) threaded down from ``engine.apply``.  When
    None, the engine falls back to ``jax.vmap`` of the per-cloud entry
    (the vmap-of-kernels path, kept for A/B measurement).

    dense_batched(mlp, kind, xyz, feats, nbr_idx, centers_xyz,
                  center_feats, nbr_valid, kernel_kw=None)
    reuse_batched(mlp, pool_in, slot, comp, live, kernel_kw=None)

    Ragged-batch contract: ``nbr_valid`` (S, K) bool (None = all valid)
    masks neighbor slots out of the max-pool (-> -BIG before the pool);
    a subset with zero valid slots yields an all-zero feature row, never
    -BIG/NaN.  ``reuse`` treats ``slot < 0`` as empty and additionally
    ANDs the optional ``live`` (H, M, K) mask (cache-slot liveness).
    """
    name: str
    dense: Callable
    reuse: Callable
    dense_batched: Callable | None = None
    reuse_batched: Callable | None = None


def dense_batched(backend: FCBackend, mlp, kind, xyz, feats, nbr_idx,
                  centers_xyz, center_feats=None, nbr_valid=None,
                  kernel_kw=None):
    """Batched dense FC through ``backend``: native entry when available,
    else vmap of the per-cloud entry (one kernel dispatch per cloud)."""
    if backend.dense_batched is not None:
        return backend.dense_batched(mlp, kind, xyz, feats, nbr_idx,
                                     centers_xyz, center_feats, nbr_valid,
                                     kernel_kw=kernel_kw)
    return jax.vmap(
        lambda x, f, n, c, cf, nv: backend.dense(mlp, kind, x, f, n, c,
                                                 cf, nv),
        in_axes=(0, 0, 0, 0, None if center_feats is None else 0,
                 None if nbr_valid is None else 0),
    )(xyz, feats, nbr_idx, centers_xyz, center_feats, nbr_valid)


def reuse_batched(backend: FCBackend, mlp, pool_in, slot, comp, live=None,
                  kernel_kw=None):
    """Batched reuse FC through ``backend``: native entry when available,
    else vmap of the per-cloud entry."""
    if backend.reuse_batched is not None:
        return backend.reuse_batched(mlp, pool_in, slot, comp, live,
                                     kernel_kw=kernel_kw)
    return jax.vmap(
        lambda p, s, c, l: backend.reuse(mlp, p, s, c, l),
        in_axes=(0, 0, 0, None if live is None else 0),
    )(pool_in, slot, comp, live)


def data_structuring(cfg: LPCNConfig, xyz: jnp.ndarray,
                     key: jax.Array, n_valid=None
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """DS step: sample centers, gather neighbors (both registry-resolved).
    Returns (center_idx (S,), nbr_idx (S, K)).

    ``n_valid`` (traced count or None) marks rows >= n_valid of ``xyz``
    as padding: the octree sorts them last, samplers never select them
    and neighbor methods never return them (unfillable slots are -1).
    The kwarg is forwarded to the registered components only when set;
    note the batched engine always sets it (a traced per-cloud count), so
    components registered for use through ``engine.apply`` must accept
    ``n_valid`` — a clear TypeError points at the offender otherwise.
    """
    tree = oct.build(xyz, n_valid=n_valid)
    kw = {} if n_valid is None else {"n_valid": n_valid}
    try:
        cidx = SAMPLERS.get(cfg.sampler)(
            xyz, tree=tree, n_centers=cfg.n_centers, key=key, **kw)
    except TypeError as e:
        if kw and "n_valid" in str(e):
            raise TypeError(
                f"sampler {cfg.sampler!r} does not accept n_valid, which "
                f"the batched engine always passes; add n_valid=None to "
                f"its signature (see core.registry docstring)") from e
        raise
    centers = xyz[cidx]
    try:
        nbr = NEIGHBORS.get(cfg.neighbor)(
            xyz, centers, tree=tree, k=cfg.k, radius=cfg.radius,
            octree_level=cfg.octree_level, **kw)
    except TypeError as e:
        if kw and "n_valid" in str(e):
            raise TypeError(
                f"neighbor {cfg.neighbor!r} does not accept n_valid, "
                f"which the batched engine always passes; add "
                f"n_valid=None to its signature (see core.registry "
                f"docstring)") from e
        raise
    return cidx, nbr


def _center_vec(kind: str, centers_xyz, center_feats):
    """The per-subset vector the MLP input is normalized against."""
    return centers_xyz if kind == "sa" else center_feats


def _point_inputs(kind: str, xyz, feats, ids, center_vec):
    """MLP inputs for gathered point ids (..., ) against per-... center_vec.

    sa:   [xyz[ids] - c, feats[ids]]
    edge: [feats[ids] - c, c]
    """
    if kind == "sa":
        rel = xyz[ids] - center_vec
        return jnp.concatenate([rel, feats[ids]], axis=-1)
    rel = feats[ids] - center_vec
    return jnp.concatenate([rel, jnp.broadcast_to(center_vec, rel.shape)],
                           axis=-1)


def _subset_inputs(kind, xyz, feats, nbr_idx, centers_xyz, center_feats):
    """(S, K, f_in) MLP inputs for all subsets (dense/traditional path)."""
    cv = _center_vec(kind, centers_xyz, center_feats)
    return _point_inputs(kind, xyz, feats, nbr_idx, cv[:, None, :])


def _dense_reference(mlp: MLP, kind, xyz, feats, nbr_idx, centers_xyz,
                     center_feats=None, nbr_valid=None):
    """jnp oracle of the dense FC dataflow (kernels/gather_mlp).  Invalid
    neighbor slots are -BIG before the pool; fully-empty subsets pool to
    an all-zero row."""
    ids = nbr_idx if nbr_valid is None else jnp.where(nbr_valid, nbr_idx, 0)
    x = _subset_inputs(kind, xyz, feats, ids, centers_xyz, center_feats)
    y = apply_mlp(mlp, x)                                 # (S, K, Fout)
    if nbr_valid is None:
        return y.max(axis=1)                              # (S, Fout)
    pooled = jnp.where(nbr_valid[..., None], y, -BIG).max(axis=1)
    return jnp.where(nbr_valid.any(axis=1)[:, None], pooled, 0.0)


def _reuse_reference(mlp: MLP, pool_in, slot, comp, live=None):
    """jnp oracle of the reuse dataflow (kernels/hub_reuse): pool MLP,
    slot-gather, + comp, masked max over K.  -> (H, M, Fout), -BIG where a
    subset has no cached position.  ``live`` (H, M, K) further masks
    positions whose cache slot is not actually resident."""
    C = pool_in.shape[1]
    y = apply_mlp(mlp, pool_in)                           # (H, C, Fout)
    safe = jnp.clip(slot, 0, C - 1)
    g = jnp.take_along_axis(
        y, safe.reshape(y.shape[0], -1, 1), axis=1
    ).reshape(slot.shape + (y.shape[-1],))                # (H, M, K, Fout)
    g = g + comp[:, :, None, :]
    ok = slot >= 0 if live is None else (slot >= 0) & live
    g = jnp.where(ok[..., None], g, -BIG)
    return jnp.max(g, axis=2)


FC_BACKENDS.register("reference", FCBackend(
    name="reference", dense=_dense_reference, reuse=_reuse_reference))


def fc_traditional(mlp: MLP, xyz, feats, nbr_idx, centers_xyz,
                   center_feats=None, kind: str = "sa",
                   backend: FCBackend | None = None, nbr_valid=None):
    """Baseline FC: full MLP on all S*K gathered points, then max-pool.
    ``nbr_valid`` (S, K) bool masks ragged-batch -1 neighbor slots out of
    the pool (empty subsets become zero rows)."""
    backend = backend or FC_BACKENDS.get("reference")
    pooled = backend.dense(mlp, kind, xyz, feats, nbr_idx, centers_xyz,
                           center_feats, nbr_valid)
    return post_pool_activation(mlp, pooled)


def _lpcn_reuse_inputs(mlp: MLP, xyz, feats, nbr_idx, centers_xyz,
                       islands: Islands, sched: Schedule, cfg: LPCNConfig,
                       center_feats=None):
    """Per-cloud jnp prep of the ``backend.reuse`` operands.

    Returns (pool_in (H, C, fin), comp (H, M, Fout), slot_live (H, M, K),
    sub_vec (H, M, Dc)); ``sub_vec`` is reused by the overflow/merge step.
    """
    S = nbr_idx.shape[0]
    H, M = islands.members.shape
    K = nbr_idx.shape[1]
    C = sched.pool_ids.shape[1]
    kind = cfg.block_kind

    cvec = _center_vec(kind, centers_xyz, center_feats)   # (S, Dc)
    hub_vec = cvec[islands.hub]                           # (H, Dc)

    # --- pool inputs (hub-relative), one eval per cached unique point ----
    pids = jnp.clip(sched.pool_ids, 0, xyz.shape[0] - 1)  # (H, C)
    pool_in = _point_inputs(kind, xyz, feats, pids, hub_vec[:, None, :])
    pool_live = sched.pool_ids >= 0

    # --- per-subset compensation (one Δ per non-hub subset) --------------
    mem = jnp.clip(islands.members, 0, S - 1)             # (H, M)
    sub_vec = cvec[mem]                                   # (H, M, Dc)
    delta = hub_vec[:, None, :] - sub_vec                 # (H, M, Dc)
    comp = compensation(mlp, delta, cfg.compensation, kind)  # (H, M, Fout)

    safe_slot = jnp.clip(sched.reuse_slot, 0, C - 1)
    slot_live = jnp.take_along_axis(
        pool_live, safe_slot.reshape(H, M * K), axis=1).reshape(H, M, K)
    return pool_in, comp, slot_live, sub_vec


def _lpcn_merge(mlp: MLP, xyz, feats, nbr_idx, islands: Islands,
                sched: Schedule, cfg: LPCNConfig, sub_vec, slot_live,
                reuse_pooled):
    """Overflow compute + max-merge with the reuse partials + scatter to
    center order.  Returns (out (S, Fout) *without* the dense fallback
    substituted, fb (S,) bool fallback rows)."""
    S, K = nbr_idx.shape
    H, M = islands.members.shape
    Fout = mlp.f_out
    kind = cfg.block_kind
    slot = sched.reuse_slot                               # (H, M, K)
    reuse_ok = (slot >= 0) & slot_live

    # --- compact overflow compute (never-cached positions) ---------------
    B = max(int(cfg.overflow_frac * M * K), K)            # overflow budget
    # only live positions (real subset row AND a valid gathered point)
    # are ever computed — ragged -1 slots stay out of the overflow queue
    need = (~reuse_ok) & sched.pos_live                   # (H, M, K)

    def island_overflow(need_h, ids_h, sub_vec_h):
        flatneed = need_h.reshape(-1)
        prio = jnp.where(flatneed, jnp.arange(M * K), M * K)
        takepos = jnp.argsort(prio)[:B]                   # overflow slots
        taken = flatneed[takepos]
        ids = ids_h.reshape(-1)[takepos]
        ids = jnp.clip(ids, 0, xyz.shape[0] - 1)
        row = jnp.clip(takepos // K, 0, M - 1)
        x = _point_inputs(kind, xyz, feats, ids, sub_vec_h[row])
        return takepos, taken, x

    mem = jnp.clip(islands.members, 0, S - 1)             # (H, M)
    ids_hmk = jnp.where(sched.pos_live, nbr_idx[mem], 0)
    takepos, taken, ox = jax.vmap(island_overflow)(
        need, ids_hmk, sub_vec)                           # (H,B),(H,B),(H,B,fin)
    o_out = apply_mlp(mlp, ox)                            # (H, B, Fout)

    # scatter overflow results into their own (H, M*K, Fout) canvas and
    # pool; max-pool commutes, so max(reuse_pooled, overflow_pooled) equals
    # pooling the combined position set
    over = jnp.full((H, M * K, Fout), -BIG, o_out.dtype)
    oidx = jnp.where(taken, takepos, M * K)               # drop untaken
    over = over.at[jnp.arange(H)[:, None], oidx].set(
        jnp.where(taken[..., None], o_out, -BIG), mode="drop")
    over_pooled = over.reshape(H, M, K, Fout).max(axis=2)
    pooled = jnp.maximum(reuse_pooled, over_pooled)       # (H, M, Fout)
    # merge-boundary guard: any subset both of whose sides stayed at the
    # -BIG merge identity zero-fills (mirrors gather_mlp's empty-subset
    # handling).  This subsumes the no-live-position case (empty ball
    # query on a nearly-empty ragged cloud) AND protects all-cached
    # subsets whose overflow side is empty against a reuse partial that
    # came back -BIG — the sentinel must never leak past the merge.
    pooled = jnp.where(pooled > -BIG / 2, pooled, 0.0)

    # rows whose overflow exceeded the budget fall back to the dense path
    covered = jnp.zeros((H, M * K), bool)
    covered = covered.at[jnp.arange(H)[:, None], oidx].set(taken, mode="drop")
    uncovered_row = (need.reshape(H, M * K) & ~covered
                     ).reshape(H, M, K).any(-1)           # (H, M)

    # --- scatter per-subset results to center order -----------------------
    out = jnp.zeros((S, Fout), pooled.dtype)
    rows_ok = sched.subset_valid
    tgt = jnp.where(rows_ok, islands.members, S)
    out = out.at[tgt.reshape(-1)].set(pooled.reshape(-1, Fout), mode="drop")

    # --- dense fallback rows: solo subsets + budget-exhausted rows --------
    fb = jnp.zeros((S,), bool).at[tgt.reshape(-1)].set(
        uncovered_row.reshape(-1), mode="drop") | islands.solo
    return out, fb


def fc_lpcn(mlp: MLP, xyz, feats, nbr_idx, centers_xyz,
            islands: Islands, sched: Schedule, cfg: LPCNConfig,
            center_feats=None, backend: FCBackend | None = None,
            nbr_valid=None):
    """Islandized FC: pool-MLP + compensated reuse + compact overflow.

    The two MXU-heavy dataflows — the dense path and the pool-MLP +
    reuse-gather — go through ``backend``; overflow/fallback bookkeeping
    is shared jnp.  Returns (S, Fout) center features — same contract as
    fc_traditional.  Ragged-batch slots (``sched.pos_live`` False) are
    neither reused nor computed; a subset with zero live positions pools
    to a zero row.
    """
    backend = backend or get_fc_backend(cfg.fc_backend)
    pool_in, comp, slot_live, sub_vec = _lpcn_reuse_inputs(
        mlp, xyz, feats, nbr_idx, centers_xyz, islands, sched, cfg,
        center_feats)
    reuse_pooled = backend.reuse(mlp, pool_in, sched.reuse_slot, comp,
                                 slot_live)               # (H, M, Fout)
    out, fb = _lpcn_merge(mlp, xyz, feats, nbr_idx, islands, sched, cfg,
                          sub_vec, slot_live, reuse_pooled)
    h_dense = backend.dense(mlp, cfg.block_kind, xyz, feats, nbr_idx,
                            centers_xyz, center_feats, nbr_valid)
    out = jnp.where(fb[:, None], h_dense, out)
    return post_pool_activation(mlp, out)


def fc_traditional_batched(mlp: MLP, xyz, feats, nbr_idx, centers_xyz,
                           center_feats=None, kind: str = "sa",
                           backend: FCBackend | None = None,
                           nbr_valid=None, kernel_kw=None):
    """Batched :func:`fc_traditional`: every array carries a leading (B,)
    axis; the MXU-heavy dense dataflow goes through the backend's batched
    entry point (ONE kernel dispatch for the whole cloud stack)."""
    backend = backend or FC_BACKENDS.get("reference")
    pooled = dense_batched(backend, mlp, kind, xyz, feats, nbr_idx,
                           centers_xyz, center_feats, nbr_valid, kernel_kw)
    return post_pool_activation(mlp, pooled)


def fc_lpcn_batched(mlp: MLP, xyz, feats, nbr_idx, centers_xyz,
                    islands: Islands, sched: Schedule, cfg: LPCNConfig,
                    center_feats=None, backend: FCBackend | None = None,
                    nbr_valid=None, kernel_kw=None):
    """Batched :func:`fc_lpcn`: every array operand (including the
    ``islands`` / ``sched`` pytrees) carries a leading (B,) axis.

    The per-cloud jnp bookkeeping (reuse-operand prep, overflow compute,
    merge + scatter) is vmapped; the two MXU-heavy dataflows go through
    the backend's batched entry points so the whole cloud stack reaches
    the systolic array as ONE schedule per call site."""
    backend = backend or get_fc_backend(cfg.fc_backend)
    pool_in, comp, slot_live, sub_vec = jax.vmap(
        lambda x, f, n, c, isl, sch, cf: _lpcn_reuse_inputs(
            mlp, x, f, n, c, isl, sch, cfg, cf),
        in_axes=(0, 0, 0, 0, 0, 0, None if center_feats is None else 0),
    )(xyz, feats, nbr_idx, centers_xyz, islands, sched, center_feats)
    reuse_pooled = reuse_batched(backend, mlp, pool_in, sched.reuse_slot,
                                 comp, slot_live, kernel_kw)
    out, fb = jax.vmap(
        lambda x, f, n, isl, sch, sv, sl, rp: _lpcn_merge(
            mlp, x, f, n, isl, sch, cfg, sv, sl, rp)
    )(xyz, feats, nbr_idx, islands, sched, sub_vec, slot_live, reuse_pooled)
    h_dense = dense_batched(backend, mlp, cfg.block_kind, xyz, feats,
                            nbr_idx, centers_xyz, center_feats, nbr_valid,
                            kernel_kw)
    out = jnp.where(fb[..., None], h_dense, out)
    return post_pool_activation(mlp, out)


@dataclass
class BlockOutput:
    center_idx: jnp.ndarray
    center_xyz: jnp.ndarray
    features: jnp.ndarray
    islands: Islands | None
    schedule: Schedule | None
    nbr_idx: jnp.ndarray
    report: WorkloadReport | None = None
    center_valid: jnp.ndarray | None = None   # (S,) bool; None = all valid


@jax.tree_util.register_pytree_node_class
@dataclass
class BlockStructure:
    """Geometric stage of one building block: everything the FC stage
    needs that depends only on coordinates + RNG (never on features).

    Registered as a pytree so a vmapped structure pass can emit stacked
    (B, …) structures for the batched FC stage (``islands``/``schedule``
    are None in traditional mode; ``center_valid``/``nbr_valid`` are None
    when the cloud has no padding — both statically consistent across a
    batch).
    """
    center_idx: jnp.ndarray                   # (S,)
    center_xyz: jnp.ndarray                   # (S, 3)
    nbr: jnp.ndarray                          # (S, K)
    islands: Islands | None
    schedule: Schedule | None
    center_valid: jnp.ndarray | None          # (S,) bool
    nbr_valid: jnp.ndarray | None             # (S, K) bool

    def tree_flatten(self):
        return ((self.center_idx, self.center_xyz, self.nbr, self.islands,
                 self.schedule, self.center_valid, self.nbr_valid), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def structure_block(cfg: LPCNConfig, xyz: jnp.ndarray, key: jax.Array,
                    n_valid=None) -> BlockStructure:
    """Stage 1 of a building block: DS → octree → islandize → hub-schedule
    on ONE cloud.  Pure geometry — the emitted :class:`BlockStructure` is
    reusable across any feature tensor (and any FC backend)."""
    kds, kisl = jax.random.split(key)
    cidx, nbr = data_structuring(cfg, xyz, kds, n_valid=n_valid)
    centers_xyz = xyz[cidx]
    center_valid = None if n_valid is None else cidx < n_valid
    nbr_valid = None if n_valid is None else nbr >= 0
    if cfg.mode == "traditional":
        return BlockStructure(cidx, centers_xyz, nbr, None, None,
                              center_valid, nbr_valid)
    n_hubs = max(int(cidx.shape[0]) // cfg.island_size, 1)
    if center_valid is None:
        n_hubs_valid = None
    else:
        n_hubs_valid = jnp.maximum(
            center_valid.sum() // cfg.island_size, 1)
    isl = islandize(centers_xyz, n_hubs, level=cfg.octree_level,
                    capacity=cfg.island_capacity,
                    hub_select=cfg.hub_select, key=kisl,
                    center_valid=center_valid, n_hubs_valid=n_hubs_valid)
    sched = build_schedule(isl, nbr, cfg.cache_capacity)
    return BlockStructure(cidx, centers_xyz, nbr, isl, sched,
                          center_valid, nbr_valid)


def compute_block_features(cfg: LPCNConfig, mlp: MLP, xyz, feats,
                           st: BlockStructure,
                           backend: FCBackend | None = None) -> jnp.ndarray:
    """Stage 2 of a building block: Feature Computation on ONE cloud over
    a pre-built :class:`BlockStructure`.  -> (S, Fout), padding centers
    zeroed."""
    backend = backend or get_fc_backend(cfg.fc_backend)
    center_feats = feats[st.center_idx]
    if cfg.mode == "traditional":
        f = fc_traditional(mlp, xyz, feats, st.nbr, st.center_xyz,
                           center_feats, cfg.block_kind, backend=backend,
                           nbr_valid=st.nbr_valid)
    else:
        f = fc_lpcn(mlp, xyz, feats, st.nbr, st.center_xyz, st.islands,
                    st.schedule, cfg, center_feats, backend=backend,
                    nbr_valid=st.nbr_valid)
    if st.center_valid is not None:
        f = jnp.where(st.center_valid[:, None], f, 0.0)
    return f


def compute_block_features_batched(cfg: LPCNConfig, mlp: MLP, xyz, feats,
                                   st: BlockStructure,
                                   backend: FCBackend | None = None,
                                   kernel_kw=None,
                                   mesh=None) -> jnp.ndarray:
    """Batched stage 2: ``st`` holds stacked (B, …) structures (a vmapped
    :func:`structure_block`), ``xyz``/``feats`` are (B, N, ·).  The MXU
    dataflows run through the backend's batched entry points — one kernel
    dispatch per call site for the whole cloud stack.

    ``mesh`` (None = single device) re-constrains the block's (B, S,
    Fout) output along the mesh data axes, so consecutive blocks of a
    mesh-sharded forward hand features over without a GSPMD
    replicate/reshard at the block boundary."""
    backend = backend or get_fc_backend(cfg.fc_backend)
    center_feats = jnp.take_along_axis(
        feats, st.center_idx[..., None], axis=1)
    if cfg.mode == "traditional":
        f = fc_traditional_batched(mlp, xyz, feats, st.nbr, st.center_xyz,
                                   center_feats, cfg.block_kind,
                                   backend=backend,
                                   nbr_valid=st.nbr_valid,
                                   kernel_kw=kernel_kw)
    else:
        f = fc_lpcn_batched(mlp, xyz, feats, st.nbr, st.center_xyz,
                            st.islands, st.schedule, cfg, center_feats,
                            backend=backend, nbr_valid=st.nbr_valid,
                            kernel_kw=kernel_kw)
    if st.center_valid is not None:
        f = jnp.where(st.center_valid[..., None], f, 0.0)
    if mesh is not None:
        from repro.dist.sharding import shard_leading
        f = shard_leading(f, mesh)
    return f


def lpcn_block(cfg: LPCNConfig, mlp: MLP, xyz: jnp.ndarray,
               feats: jnp.ndarray, key: jax.Array,
               with_report: bool = False, n_valid=None) -> BlockOutput:
    """One full building block on a single cloud (N,3)/(N,F) — the two
    stages (:func:`structure_block` + :func:`compute_block_features`)
    fused, the eager per-cloud entry point.

    ``n_valid`` (traced count or None) marks rows >= n_valid as padding.
    With it set, the block is numerically equivalent to running the
    unpadded (n_valid, ·) prefix: padding is never sampled, gathered,
    islandized, cached or pooled, its feature rows come back zeroed
    (``center_valid`` marks them), and the workload report counts only
    real work.
    """
    st = structure_block(cfg, xyz, key, n_valid=n_valid)
    backend = get_fc_backend(cfg.fc_backend)
    f = compute_block_features(cfg, mlp, xyz, feats, st, backend=backend)
    report = (analyze(st.islands, st.schedule, cfg.k)
              if with_report and st.islands is not None else None)
    return BlockOutput(st.center_idx, st.center_xyz, f, st.islands,
                       st.schedule, st.nbr, report,
                       center_valid=st.center_valid)
