"""Result Delta Compensation (paper §IV-B1, Eq. 1).

Cached MLP results are relative to the hub center; a subset with center c_g
reuses them after adding the compensation for Δ = c_hub − c_g:

    w·(P − c_g) = w·(P − c_hub) + w·Δ

Block kinds:
  * ``sa``   (Set Abstraction, PointNet++/PointNeXt/PointVector): MLP input
    is [p − c, f]; only the 3 coordinate rows react to the center, so the
    compensation matrix is (Π_i W_i) restricted to rows 0:3.
  * ``edge`` (EdgeConv, DGCNN): MLP input is [f_j − f_i, f_i]; both halves
    react to the center feature f_i.  value(g) − value(hub) =
    Δ·(W[:D] − W[D:2D]) with Δ = f_hub − f_g, composed with later layers.

Modes (DESIGN.md §2):
  * ``linear`` — compose the linear parts; exact when activation is applied
    at block end (paper §VI-E: DGCNN(c), PointVector-L), first-order
    approximation otherwise.
  * ``mlp`` — feed the Δ-perturbed zero input through the full MLP like the
    paper's FCU dataflow (MLP(Δ-embedding) − MLP(0)); approximate through
    nonlinearities.
"""
from __future__ import annotations

import jax.numpy as jnp

from .mlp import MLP, apply_mlp


def comp_matrix(mlp: MLP, kind: str, d_center: int) -> jnp.ndarray:
    """(d_center, F_out) — composed linear action of a center shift Δ."""
    w0 = mlp.layers[0].w
    if kind == "sa":
        m = w0[:d_center]
    elif kind == "edge":
        m = w0[:d_center] - w0[d_center:2 * d_center]
    else:
        raise ValueError(f"unknown block kind: {kind}")
    for layer in mlp.layers[1:]:
        m = m @ layer.w
    return m


def _delta_embedding(delta: jnp.ndarray, kind: str, f_in: int
                     ) -> jnp.ndarray:
    """Embed Δ into the MLP input space (rest zero)."""
    d = delta.shape[-1]
    zeros = jnp.zeros(delta.shape[:-1] + (f_in - d,), delta.dtype)
    if kind == "sa":
        return jnp.concatenate([delta, zeros], axis=-1)
    if kind == "edge":
        # [Δ acting on (f_j - f_i); -Δ acting on f_i half]
        rest = jnp.zeros(delta.shape[:-1] + (f_in - 2 * d,), delta.dtype)
        return jnp.concatenate([delta, -delta, rest], axis=-1)
    raise ValueError(kind)


def compensation(mlp: MLP, delta: jnp.ndarray, mode: str,
                 kind: str = "sa") -> jnp.ndarray:
    """delta: (..., d_center) -> (..., F_out) additive adjustment."""
    if mode == "linear":
        return delta @ comp_matrix(mlp, kind, delta.shape[-1])
    if mode == "mlp":
        x = _delta_embedding(delta, kind, mlp.f_in)
        return apply_mlp(mlp, x) - apply_mlp(mlp, jnp.zeros_like(x))
    raise ValueError(f"unknown compensation mode: {mode}")
