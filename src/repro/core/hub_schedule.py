"""Hub-based Scheduling (paper §IV-B) — overlap detection + Hub Cache.

The FPGA processes an island temporally: hub subset first (fills the first K
Hub-Cache entries), then the remaining subsets in island-list order; each new
subset's points are probed against the dynamically updated Hub Octree
(hit -> reuse cached MLP result with delta compensation, miss -> compute,
insert into cache while capacity remains; no replacement within an island).

The TPU-native equivalent computes the *final cache contents and hit pattern
in closed form* (DESIGN.md §2): for the island's flattened point sequence
(subsets in island-list order, hub first) we mark first occurrences, assign
cache slots to the first ``cache_capacity`` distinct points in order, and
derive a ``reuse_slot`` map for every (subset, k) position.  Point identity
is the index into the input cloud — semantically identical to the paper's
Morton-code Hub-Octree probe (see tests/test_overlap_octree_equiv.py which
proves the equivalence against ``octree.contains``).

Results in the pool are stored *relative to the hub center* so every
non-hub subset needs exactly one compensation delta (c_hub - c_subset),
matching the paper's one-Δ-per-subset FCU dataflow.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from .islandize import Islands


@jax.tree_util.register_pytree_node_class
@dataclass
class Schedule:
    """Per-island reuse schedule (all arrays island-major).

    pool_ids:   (H, C) int32 — point ids resident in the Hub Cache at end of
                island (-1 = empty slot).  Slots 0..K-1 are the hub subset
                (paper: "first 32 entries").
    reuse_slot: (H, M, K) int32 — cache slot serving this position, or -1
                (position must be computed locally: cache overflow).
    is_first:   (H, M, K) bool — position is the first occurrence of its
                point in the island sequence (it *fills* its slot rather
                than hitting it; FLOP-counted as a compute, not a reuse).
    subset_valid: (H, M) bool — island-list row is a real subset.
    pos_live:   (H, M, K) bool — position holds a real gathered point (the
                row is a real subset AND the neighbor slot was filled with
                a valid point, i.e. its id >= 0).  Padding rows and
                unfillable ragged-batch slots are False: they never occupy
                cache slots, are never computed, and are excluded from
                workload counters.
    """
    pool_ids: jnp.ndarray
    reuse_slot: jnp.ndarray
    is_first: jnp.ndarray
    subset_valid: jnp.ndarray
    pos_live: jnp.ndarray

    def tree_flatten(self):
        return ((self.pool_ids, self.reuse_slot, self.is_first,
                 self.subset_valid, self.pos_live), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@partial(jax.jit, static_argnames=("cache_capacity",))
def build_schedule(islands: Islands, nbr_idx: jnp.ndarray,
                   cache_capacity: int) -> Schedule:
    """Derive the Hub-Cache schedule for every island.

    nbr_idx: (S, K) int32 — gathered point ids per subset (DSU output).
    cache_capacity: C — Hub-Cache entries (paper default 2x subset size).
    """
    H, M = islands.members.shape
    K = nbr_idx.shape[1]
    C = cache_capacity

    members = islands.members                                     # (H, M)
    valid_row = members >= 0
    safe_members = jnp.clip(members, 0, nbr_idx.shape[0] - 1)
    ids = nbr_idx[safe_members]                                   # (H, M, K)
    ids = jnp.where(valid_row[..., None], ids, -1)

    def per_island(ids_hmk):
        """ids_hmk: (M, K) -> schedule slices for one island."""
        flat = ids_hmk.reshape(-1)                                # (M*K,)
        n = flat.shape[0]
        seq = jnp.arange(n)
        # sort by (id, seq): group occurrences of the same point together
        order = jnp.lexsort((seq, flat))
        sflat = flat[order]
        first_in_group = jnp.concatenate(
            [jnp.array([True]), sflat[1:] != sflat[:-1]])
        # leader position (in original sequence) of each group.  Propagate
        # the group-start *sorted index* (monotonic, so a max-scan is a
        # correct segmented broadcast), then map through `order`.
        group_start = jax.lax.associative_scan(
            jnp.maximum, jnp.where(first_in_group, seq, 0))
        leader_seq = order[group_start]
        # scatter back to sequence order
        is_first = jnp.zeros((n,), bool).at[order].set(first_in_group)
        leader_of = jnp.zeros((n,), jnp.int32).at[order].set(
            leader_seq.astype(jnp.int32))
        # invalid positions (padding) never occupy or hit slots
        live = flat >= 0
        is_first = is_first & live
        # slot of a *leader* position: rank among leaders in sequence order
        slot_of_pos = jnp.where(is_first, jnp.cumsum(is_first) - 1, -1)
        cached_leader = is_first & (slot_of_pos < C)
        # per position: slot of its leader (or -1 if leader not cached)
        leader_slot = slot_of_pos[leader_of]
        leader_cached = cached_leader[leader_of]
        reuse = jnp.where(live & leader_cached, leader_slot, -1)
        # pool contents: ids of cached leaders, scattered by slot
        pool = jnp.full((C,), -1, jnp.int32)
        pool = pool.at[jnp.where(cached_leader, slot_of_pos, C)].set(
            jnp.where(cached_leader, flat, -1), mode="drop")
        return (pool, reuse.reshape(M, K).astype(jnp.int32),
                is_first.reshape(M, K))

    pool, reuse, first = jax.vmap(per_island)(ids)
    return Schedule(pool_ids=pool, reuse_slot=reuse, is_first=first,
                    subset_valid=valid_row, pos_live=ids >= 0)
