"""Sampling Module — central-point selection (paper Fig. 6).

Farthest Point Sampling is the standard PCN sampler (and the reason the
default PCN processing order is spatially *distant*, which L-PCN's
islandization undoes — paper §III-A).  Also provides random and grid
(Morton-strided) sampling used by the approximate-DS baselines.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("n_samples",))
def farthest_point_sampling(points: jnp.ndarray, n_samples: int,
                            start: int = 0) -> jnp.ndarray:
    """FPS over (N, 3) points -> (n_samples,) int32 indices.

    O(N * n_samples), the classic iterative algorithm: keep per-point
    distance-to-selected-set; each round pick the argmax and relax.
    """
    n = points.shape[0]
    min_d = jnp.full((n,), jnp.inf, dtype=points.dtype)

    def body(i, state):
        min_d, idx, last = state
        d = jnp.sum((points - points[last]) ** 2, axis=-1)
        min_d = jnp.minimum(min_d, d)
        nxt = jnp.argmax(min_d).astype(jnp.int32)
        idx = idx.at[i].set(nxt)
        return min_d, idx, nxt

    idx0 = jnp.zeros((n_samples,), jnp.int32).at[0].set(start)
    _, idx, _ = jax.lax.fori_loop(1, n_samples, body,
                                  (min_d, idx0, jnp.int32(start)))
    return idx


def random_sampling(key: jax.Array, n_points: int, n_samples: int
                    ) -> jnp.ndarray:
    """Uniform sample without replacement -> (n_samples,) int32 indices."""
    return jax.random.choice(key, n_points, (n_samples,),
                             replace=False).astype(jnp.int32)


def morton_strided_sampling(sorted_order: jnp.ndarray, n_samples: int
                            ) -> jnp.ndarray:
    """EdgePC-style approximate sampler: stride the Morton-sorted order
    (uniform coverage of space at near-zero cost)."""
    n = sorted_order.shape[0]
    pos = (jnp.arange(n_samples) * n) // n_samples
    return sorted_order[pos].astype(jnp.int32)
