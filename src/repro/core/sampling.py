"""Sampling Module — central-point selection (paper Fig. 6).

Farthest Point Sampling is the standard PCN sampler (and the reason the
default PCN processing order is spatially *distant*, which L-PCN's
islandization undoes — paper §III-A).  Also provides random and grid
(Morton-strided) sampling used by the approximate-DS baselines.

Ragged-batch contract: every sampler takes an optional validity argument
(``valid`` mask / ``n_valid`` count) and then selects **only valid
points**.  Selection is *shape-stable*: running a sampler on a padded
(N, 3) cloud with ``n_valid = n`` picks exactly the same indices as
running it on the unpadded (n, 3) prefix — the property the engine's
padded-batch == per-cloud oracle rests on.  Randomized selection uses
:func:`index_uniform` (per-index scores independent of N) instead of
``jax.random.choice`` (whose stream depends on the array length).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def index_uniform(key: jax.Array, n: int) -> jnp.ndarray:
    """(n,) uniform scores where score i depends only on ``(key, i)``.

    Unlike ``jax.random.uniform(key, (n,))`` — whose threefry counter
    layout couples every element to the total length — the score of index
    i here is identical for every array length, so masked top-k selection
    over a padded array matches the same selection on the unpadded prefix
    bit-for-bit.
    """
    keys = jax.vmap(partial(jax.random.fold_in, key))(jnp.arange(n))
    return jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)


@partial(jax.jit, static_argnames=("n_samples",))
def farthest_point_sampling(points: jnp.ndarray, n_samples: int,
                            start: int = 0,
                            valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """FPS over (N, 3) points -> (n_samples,) int32 indices.

    O(N * n_samples), the classic iterative algorithm: keep per-point
    distance-to-selected-set; each round pick the argmax and relax.

    ``valid`` (N,) bool masks padding rows out of the argmax (their
    distance is pinned at −inf, so they can never be selected); the seed
    ``start`` must index a valid point (0 always is — padding is a
    suffix).  If more samples than valid points are requested the argmax
    saturates and valid indices repeat.
    """
    n = points.shape[0]
    min_d = jnp.full((n,), jnp.inf, dtype=points.dtype)
    if valid is not None:
        min_d = jnp.where(valid, min_d, -jnp.inf)

    def body(i, state):
        min_d, idx, last = state
        d = jnp.sum((points - points[last]) ** 2, axis=-1)
        min_d = jnp.minimum(min_d, d)
        nxt = jnp.argmax(min_d).astype(jnp.int32)
        idx = idx.at[i].set(nxt)
        return min_d, idx, nxt

    idx0 = jnp.zeros((n_samples,), jnp.int32).at[0].set(start)
    _, idx, _ = jax.lax.fori_loop(1, n_samples, body,
                                  (min_d, idx0, jnp.int32(start)))
    return idx


def random_sampling(key: jax.Array, n_points: int, n_samples: int,
                    n_valid=None) -> jnp.ndarray:
    """Uniform sample without replacement -> (n_samples,) int32 indices.

    Implemented as top-``n_samples`` of per-index iid uniform scores
    (:func:`index_uniform`), which is a uniform draw without replacement
    AND shape-stable under padding: only indices < ``n_valid`` can be
    picked, and the picks match the unpadded run.  If ``n_samples``
    exceeds the valid count, the surplus slots repeat the first pick.
    """
    scores = index_uniform(key, n_points)
    if n_valid is not None:
        scores = jnp.where(jnp.arange(n_points) < n_valid, scores, jnp.inf)
    pick = jnp.argsort(scores)[:n_samples].astype(jnp.int32)
    if n_valid is not None:
        ok = jnp.arange(n_samples) < n_valid
        pick = jnp.where(ok, pick, pick[0])
    return pick


def morton_strided_sampling(sorted_order: jnp.ndarray, n_samples: int,
                            n_valid=None) -> jnp.ndarray:
    """EdgePC-style approximate sampler: stride the Morton-sorted order
    (uniform coverage of space at near-zero cost).

    With ``n_valid`` the stride runs over the valid prefix of a
    valid-first order (see ``octree.build(..., n_valid=...)``, which
    sorts padding rows to the back), never touching padding.
    """
    n = sorted_order.shape[0]
    count = n if n_valid is None else n_valid
    pos = (jnp.arange(n_samples) * count) // n_samples
    return sorted_order[jnp.clip(pos, 0, n - 1)].astype(jnp.int32)
