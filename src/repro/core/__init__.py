"""L-PCN core: the paper's primary contribution in JAX.

Octree-based Islandization (islandize.py) + Hub-based Scheduling
(hub_schedule.py) over a linear-octree substrate (morton.py, octree.py),
with the DS step (sampling.py, neighbor.py), delta compensation
(delta_comp.py), workload analytics (workload.py) and the composed
building block (pipeline.py).
"""
from .islandize import Islands, islandize
from .hub_schedule import Schedule, build_schedule
from .pipeline import (FCBackend, LPCNConfig, lpcn_block, fc_traditional,
                       fc_lpcn)
from .registry import (FC_BACKENDS, NEIGHBORS, SAMPLERS, Registry,
                       register_fc_backend, register_neighbor,
                       register_sampler)
from .workload import WorkloadReport, analyze, overlap_histogram
from .mlp import MLP, init_mlp, apply_mlp

__all__ = [
    "Islands", "islandize", "Schedule", "build_schedule", "LPCNConfig",
    "lpcn_block", "fc_traditional", "fc_lpcn", "FCBackend", "Registry",
    "SAMPLERS", "NEIGHBORS", "FC_BACKENDS", "register_sampler",
    "register_neighbor", "register_fc_backend", "WorkloadReport", "analyze",
    "overlap_histogram", "MLP", "init_mlp", "apply_mlp",
]
