"""Octree-based Islandization (paper §IV-A) — TPU-native implementation.

Partition the sampled point cloud (central points) into *Islands* of
spatially adjacent point subsets:

  Step 1  select Hub points among the sampled centers (random, as in the
          paper's Partitioning Module; FPS optional for better coverage);
  Step 2  round-based gathering of adjacent Sampled-Octree nodes around
          every hub (multi-source BFS over occupied voxels,
          26-connectivity).  A node reached in an earlier round is "nearer"
          (paper rule); same-round ties go to the hub with the smallest
          euclidean distance to the voxel center;
  Step 3  islands = point subsets whose centers share a Hub List — every
          center lands in exactly ONE island (partition property);
  Step 4  Island Lists: hub subset first, then BFS-round order (the paper's
          inside-to-outside processing order), padded to a fixed capacity.

All steps are jittable with static shapes.  Voxels are nodes of the linear
Sampled Octree at ``level`` (so "adjacent octree node" == adjacent occupied
voxel).  Centers whose island is already at capacity overflow into
``solo_centers`` and are processed without reuse (mirrors fixed hardware
capacity; counted honestly by the workload model).

Implementation detail vs. the paper: if the occupied-voxel graph is
disconnected and BFS saturates before every voxel is reached, remaining
voxels are assigned to the globally nearest hub (the paper's stopping rule
"until every central point belongs to a Hub List" assumes connectivity).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from . import morton
from .octree import adjacent_node_keys
from .sampling import farthest_point_sampling, index_uniform

UINT32_SENTINEL = jnp.uint32(0xFFFFFFFF)


@jax.tree_util.register_pytree_node_class
@dataclass
class Islands:
    """Result of islandization.

    members:  (H, M) int32 — center (subset) indices per island, hub at
              slot 0, -1 padding.  A center appears in at most one island.
    hub:      (H,) int32 — hub center index per island (== members[:, 0]).
    solo:     (S,) bool — centers that overflowed island capacity; processed
              without reuse.
    round_of: (S,) int32 — BFS round at which each center's voxel was
              gathered (0 = hub's own voxel).
    """
    members: jnp.ndarray
    hub: jnp.ndarray
    solo: jnp.ndarray
    round_of: jnp.ndarray

    def tree_flatten(self):
        return (self.members, self.hub, self.solo, self.round_of), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_islands(self) -> int:
        return self.members.shape[0]

    @property
    def capacity(self) -> int:
        return self.members.shape[1]


@partial(jax.jit,
         static_argnames=("n_hubs", "level", "capacity", "hub_select",
                          "max_rounds"))
def islandize(centers: jnp.ndarray, n_hubs: int, *, level: int = 4,
              capacity: int = 64, hub_select: str = "random",
              max_rounds: int = 32,
              key: jax.Array | None = None,
              center_valid: jnp.ndarray | None = None,
              n_hubs_valid=None) -> Islands:
    """Partition ``centers`` (S, 3) into ``n_hubs`` islands.

    ``capacity`` = max subsets per island (paper default: 32; we default to
    2x for headroom).  Returns :class:`Islands`.

    Ragged-batch contract: ``center_valid`` (S,) bool marks padding
    centers — they occupy no voxel, join no island and are never solo, so
    islands, schedules and workload counters on a padded cloud are
    identical to the unpadded run.  ``n_hubs_valid`` (traced count <=
    ``n_hubs``) keeps hub slots beyond the valid-center budget inert
    (no BFS seed, excluded from the nearest-hub fallback): a padded cloud
    grows exactly as many islands as its unpadded twin, with the
    remaining rows of ``members`` empty.  Hub selection is shape-stable
    (per-index scores / masked FPS), so the first ``n_hubs_valid`` hubs
    match the unpadded run's hubs one for one.
    """
    S = centers.shape[0]
    if key is None:
        key = jax.random.PRNGKey(0)
    hub_ok = (None if n_hubs_valid is None
              else jnp.arange(n_hubs) < n_hubs_valid)

    # ---- voxelization of the Sampled Octree at `level` -------------------
    clo, chi = morton.masked_bounds(centers, center_valid)
    codes = morton.morton_codes(centers, morton.MAX_DEPTH, lo=clo, hi=chi)
    ckeys = morton.node_key(codes, level, morton.MAX_DEPTH)        # (S,)
    if center_valid is not None:
        # padding centers never occupy a voxel
        ckeys = jnp.where(center_valid, ckeys, UINT32_SENTINEL)

    # unique occupied voxels, padded to S with UINT32_SENTINEL sentinels
    sort_keys = jnp.sort(ckeys)
    is_new = jnp.concatenate([jnp.array([True]),
                              sort_keys[1:] != sort_keys[:-1]])
    # unique keys compacted to the front, UINT32_SENTINEL sentinel padding
    # (codes are 63-bit so the sentinel can never collide with a real key)
    ukeys = jnp.sort(jnp.where(is_new, sort_keys, UINT32_SENTINEL))

    vox_of_center = jnp.searchsorted(ukeys, ckeys).astype(jnp.int32)  # (S,)

    # voxel center coordinates (for same-round nearest-hub tie-break)
    side = 1 << level
    vxyz = morton.decode(jnp.where(ukeys == UINT32_SENTINEL, jnp.uint32(0),
                                   ukeys)).astype(jnp.float32)
    extent = jnp.maximum(jnp.max(chi - clo), 1e-9)
    vcenter = clo + (vxyz + 0.5) / side * extent                     # (S, 3)

    # 27-neighborhood voxel ids (exact match into ukeys, else -1)
    nkeys = adjacent_node_keys(ukeys, level, morton.MAX_DEPTH)       # (S,27)
    npos = jnp.searchsorted(ukeys, nkeys).astype(jnp.int32)
    npos = jnp.clip(npos, 0, S - 1)
    nvalid = (ukeys[npos] == nkeys) & (nkeys != UINT32_SENTINEL)
    nbr = jnp.where(nvalid, npos, -1)                                # (S,27)

    # ---- Step 1: hub selection -------------------------------------------
    if hub_select == "fps":
        hub_idx = farthest_point_sampling(centers, n_hubs,
                                          valid=center_valid)
    else:  # random (paper default), via shape-stable per-index scores so
        # a padded cloud selects the same hubs as its unpadded twin
        scores = index_uniform(key, S)
        if center_valid is not None:
            scores = jnp.where(center_valid, scores, jnp.inf)
        hub_idx = jnp.argsort(scores)[:n_hubs]
    hub_idx = hub_idx.astype(jnp.int32)                              # (H,)
    hub_xyz = centers[hub_idx]                                       # (H, 3)
    hub_vox = vox_of_center[hub_idx]                                 # (H,)
    # inert hub slots scatter out of bounds (dropped)
    hub_tgt = hub_vox if hub_ok is None else jnp.where(hub_ok, hub_vox, S)

    # ---- Step 2: multi-source BFS over occupied voxels ---------------
    INF = jnp.float32(jnp.inf)
    assign0 = jnp.full((S,), -1, jnp.int32)
    # seed: hub voxels (later hub wins ties on the same voxel — rare)
    assign0 = assign0.at[hub_tgt].set(jnp.arange(n_hubs, dtype=jnp.int32),
                                      mode="drop")
    round0 = jnp.where(assign0 >= 0, 0, jnp.iinfo(jnp.int32).max)
    valid_vox = ukeys != UINT32_SENTINEL

    def bfs_round(r, state):
        assign, rnd = state
        # neighbor assignments from previous rounds only
        nass = jnp.where(nbr >= 0, assign[jnp.clip(nbr, 0, S - 1)], -1)
        nrnd = jnp.where(nbr >= 0, rnd[jnp.clip(nbr, 0, S - 1)],
                         jnp.iinfo(jnp.int32).max)
        frontier_nbr = (nass >= 0) & (nrnd < r)                      # (S,27)
        # distance from the candidate hub to this voxel's center
        cand_hub_xyz = hub_xyz[jnp.clip(nass, 0, n_hubs - 1)]        # (S,27,3)
        d = jnp.sum((cand_hub_xyz - vcenter[:, None, :]) ** 2, -1)
        d = jnp.where(frontier_nbr, d, INF)
        best = jnp.argmin(d, axis=-1)                                 # (S,)
        best_hub = jnp.take_along_axis(nass, best[:, None], 1)[:, 0]
        reach = (jnp.min(d, axis=-1) < INF) & (assign < 0) & valid_vox
        assign = jnp.where(reach, best_hub, assign)
        rnd = jnp.where(reach, r, rnd)
        return assign, rnd

    assign, vrnd = jax.lax.fori_loop(1, max_rounds + 1, bfs_round,
                                     (assign0, round0))

    # fallback: disconnected voxels -> globally nearest (real) hub
    unassigned = (assign < 0) & valid_vox
    d_all = jnp.sum((vcenter[:, None, :] - hub_xyz[None, :, :]) ** 2, -1)
    if hub_ok is not None:
        d_all = jnp.where(hub_ok[None, :], d_all, INF)
    nearest = jnp.argmin(d_all, axis=-1).astype(jnp.int32)
    assign = jnp.where(unassigned, nearest, assign)
    vrnd = jnp.where(unassigned, max_rounds + 1, vrnd)

    # ---- Step 3: per-center island id ------------------------------------
    island_of = assign[vox_of_center]                                # (S,)
    round_of = vrnd[vox_of_center].astype(jnp.int32)                 # (S,)
    if center_valid is not None:
        # padding centers route to the drop row of the member scatter
        island_of = jnp.where(center_valid, island_of, n_hubs)

    # ---- Step 4: Island Lists (hub first, then round order) --------------
    d_to_hub = jnp.sum((centers - hub_xyz[jnp.clip(island_of, 0, n_hubs - 1)]
                        ) ** 2, -1)
    hub_idx_tgt = hub_idx if hub_ok is None else jnp.where(hub_ok, hub_idx, S)
    is_hub = jnp.zeros((S,), bool).at[hub_idx_tgt].set(True, mode="drop")
    # sort key: (island, hub-first, round, distance)
    ordr = jnp.lexsort((d_to_hub, round_of.astype(jnp.float32),
                        (~is_hub).astype(jnp.int32), island_of))
    # rank within island
    sorted_isl = island_of[ordr]
    pos_in_isl = jnp.arange(S) - jnp.searchsorted(sorted_isl, sorted_isl)
    M = capacity
    fits = pos_in_isl < M
    members = jnp.full((n_hubs, M), -1, jnp.int32)
    # overflow entries are routed to row n_hubs (out of bounds -> dropped)
    members = members.at[jnp.where(fits, sorted_isl, n_hubs),
                         jnp.clip(pos_in_isl, 0, M - 1)].set(
        ordr.astype(jnp.int32), mode="drop")
    solo = jnp.zeros((S,), bool).at[ordr].set(~fits)
    if center_valid is not None:
        # padding centers are neither members nor solo
        solo &= center_valid

    return Islands(members=members, hub=hub_idx, solo=solo,
                   round_of=round_of)
