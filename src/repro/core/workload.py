"""Workload accounting — the paper's "theoretical workload optimization".

Counts, exactly as the paper defines them (§V, §VI-B):

  baseline (traditional, no reuse):
      feature fetches   = sum over subsets of K
      MLP point-evals   = sum over subsets of K

  L-PCN (Islandization Unit):
      feature fetches   = unique cached points per island (pool fills)
                        + positions whose point never got a cache slot
                          (capacity overflow -> fetched again)
      MLP point-evals   = the same computed positions
                        + one delta-compensation MLP eval per non-hub
                          subset (the paper's "one-time overhead of
                          supplementary computation", §VI-B)
      solo subsets (island-capacity overflow) count at baseline cost.

Derived:  fetch_saving = 1 - lpcn/baseline  (paper Fig. 15 green bars),
overall-memory saving folds in weight traffic (yellow bars), compute saving
(grey bars).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .hub_schedule import Schedule
from .islandize import Islands


@dataclass
class WorkloadReport:
    baseline_fetches: int
    lpcn_fetches: int
    baseline_mlp_evals: int
    lpcn_mlp_evals: int
    n_subsets: int
    n_islands_used: int
    k: int

    @property
    def fetch_saving(self) -> float:
        return 1.0 - self.lpcn_fetches / max(self.baseline_fetches, 1)

    @property
    def compute_saving(self) -> float:
        return 1.0 - self.lpcn_mlp_evals / max(self.baseline_mlp_evals, 1)

    def memory_saving(self, feat_bytes: int, weight_bytes: int,
                      tile_rows: int = 16) -> float:
        """Overall-memory-access saving (paper's yellow bars).  Weight
        traffic model: the systolic FCU re-streams the layer weights once
        per ``tile_rows`` input rows (output-stationary tiling), so weight
        bytes scale with ceil(rows/tile_rows)."""
        def total(fetches):
            wpasses = -(-fetches // tile_rows)
            return fetches * feat_bytes + wpasses * weight_bytes
        base = total(self.baseline_fetches)
        ours = total(self.lpcn_fetches)
        return 1.0 - ours / max(base, 1)

    def scaled(self, mlp_flops_per_point: int) -> dict:
        return dict(
            baseline_flops=self.baseline_mlp_evals * mlp_flops_per_point,
            lpcn_flops=self.lpcn_mlp_evals * mlp_flops_per_point,
        )

    def concrete(self) -> "WorkloadReport":
        """Materialize jnp counters into python ints."""
        g = lambda v: int(v) if hasattr(v, "item") else v
        return WorkloadReport(
            g(self.baseline_fetches), g(self.lpcn_fetches),
            g(self.baseline_mlp_evals), g(self.lpcn_mlp_evals),
            g(self.n_subsets), g(self.n_islands_used), self.k)

    @staticmethod
    def total(reports: list["WorkloadReport"]) -> "WorkloadReport":
        """Aggregate layer reports into a whole-network report."""
        rs = [r.concrete() for r in reports]
        return WorkloadReport(
            sum(r.baseline_fetches for r in rs),
            sum(r.lpcn_fetches for r in rs),
            sum(r.baseline_mlp_evals for r in rs),
            sum(r.lpcn_mlp_evals for r in rs),
            sum(r.n_subsets for r in rs),
            sum(r.n_islands_used for r in rs), rs[0].k if rs else 0)


def analyze(islands: Islands, sched: Schedule, k: int) -> WorkloadReport:
    """Exact workload counters for one DS layer.  Trace-safe: counters are
    jnp scalars under jit/vmap; call ``.item()`` on them (or run eagerly)
    for python ints."""
    live = sched.reuse_slot >= 0        # (H, M, K) cached positions
    first = sched.is_first              # fills (computed once)
    valid = sched.subset_valid          # (H, M)
    pos_valid = valid[..., None] & jnp.ones_like(first)

    n_rows = valid.sum()
    n_solo = islands.solo.sum()
    n_subsets = n_rows + n_solo

    computed_cached = (first & live).sum()               # pool fills
    overflow = (pos_valid & ~live).sum()                 # never cached

    # one delta-MLP eval per non-hub processed subset
    n_non_hub = jnp.maximum(valid.sum(-1) - 1, 0).sum()

    base = n_subsets * k
    lpcn_fetch = computed_cached + overflow + n_solo * k
    lpcn_mlp = computed_cached + overflow + n_non_hub + n_solo * k
    return WorkloadReport(
        baseline_fetches=base, lpcn_fetches=lpcn_fetch,
        baseline_mlp_evals=base, lpcn_mlp_evals=lpcn_mlp,
        n_subsets=n_subsets,
        n_islands_used=int((valid.any(-1)).sum()), k=k)


def overlap_histogram(nbr_idx: jnp.ndarray, centers: jnp.ndarray,
                      groups=(16, 16, 32)) -> dict:
    """Paper Fig. 4(b): per subset, sort all other subsets by center
    distance and measure gathered-point overlap ratio within distance
    groups (top-16 nearest, next 16, next 32, rest)."""
    S, K = nbr_idx.shape
    d = jnp.sum((centers[:, None] - centers[None, :]) ** 2, -1)
    d = d.at[jnp.arange(S), jnp.arange(S)].set(jnp.inf)
    order = jnp.argsort(d, axis=-1)                       # (S, S)
    eq = (nbr_idx[:, None, :, None] == nbr_idx[None, :, None, :])
    ov = eq.any(-1).sum(-1) / K                           # (S, S) overlap
    ov_sorted = jnp.take_along_axis(ov, order, axis=-1)
    out, lo = {}, 0
    for g in groups:
        seg = ov_sorted[:, lo:lo + g]
        out[f"near_{lo}_{lo+g}"] = (float(seg.mean()), float(seg.max()))
        lo += g
    rest = ov_sorted[:, lo:S - 1]
    out["rest"] = (float(rest.mean()), float(rest.max()))
    return out
