"""Workload accounting — the paper's "theoretical workload optimization".

Counts, exactly as the paper defines them (§V, §VI-B):

  baseline (traditional, no reuse):
      feature fetches   = sum over subsets of K
      MLP point-evals   = sum over subsets of K

  L-PCN (Islandization Unit):
      feature fetches   = unique cached points per island (pool fills)
                        + positions whose point never got a cache slot
                          (capacity overflow -> fetched again)
      MLP point-evals   = the same computed positions
                        + one delta-compensation MLP eval per non-hub
                          subset (the paper's "one-time overhead of
                          supplementary computation", §VI-B)
      solo subsets (island-capacity overflow) count at baseline cost.

Derived:  fetch_saving = 1 - lpcn/baseline  (paper Fig. 15 green bars),
overall-memory saving folds in weight traffic (yellow bars), compute saving
(grey bars).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .hub_schedule import Schedule
from .islandize import Islands


def _at_least_one(v):
    """max(v, 1) that also works on (B,)-shaped jnp/numpy counters."""
    if isinstance(v, (int, float)):
        return max(v, 1)
    return jnp.maximum(v, 1)


@jax.tree_util.register_pytree_node_class
@dataclass
class WorkloadReport:
    """Counter fields are jnp scalars as produced by ``analyze`` (batched
    (B,) arrays under vmap — registered as a pytree so engine runs can
    return stacked per-cloud reports); call ``.concrete()`` for python
    ints."""
    baseline_fetches: int
    lpcn_fetches: int
    baseline_mlp_evals: int
    lpcn_mlp_evals: int
    n_subsets: int
    n_islands_used: int
    k: int

    def tree_flatten(self):
        return ((self.baseline_fetches, self.lpcn_fetches,
                 self.baseline_mlp_evals, self.lpcn_mlp_evals,
                 self.n_subsets, self.n_islands_used), (self.k,))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux[0])

    @property
    def fetch_saving(self) -> float:
        return 1.0 - self.lpcn_fetches / _at_least_one(self.baseline_fetches)

    @property
    def compute_saving(self) -> float:
        return 1.0 - self.lpcn_mlp_evals / _at_least_one(
            self.baseline_mlp_evals)

    def memory_saving(self, feat_bytes: int, weight_bytes: int,
                      tile_rows: int = 16) -> float:
        """Overall-memory-access saving (paper's yellow bars).  Weight
        traffic model: the systolic FCU re-streams the layer weights once
        per ``tile_rows`` input rows (output-stationary tiling), so weight
        bytes scale with ceil(rows/tile_rows)."""
        def total(fetches):
            wpasses = -(-fetches // tile_rows)
            return fetches * feat_bytes + wpasses * weight_bytes
        base = total(self.baseline_fetches)
        ours = total(self.lpcn_fetches)
        return 1.0 - ours / _at_least_one(base)

    def scaled(self, mlp_flops_per_point: int) -> dict:
        return dict(
            baseline_flops=self.baseline_mlp_evals * mlp_flops_per_point,
            lpcn_flops=self.lpcn_mlp_evals * mlp_flops_per_point,
        )

    def concrete(self) -> "WorkloadReport":
        """Materialize jnp counters: python ints for scalars, numpy arrays
        for batched (B,) reports."""
        import numpy as np

        def g(v):
            if not hasattr(v, "item"):
                return v
            arr = np.asarray(v)
            return int(arr) if arr.ndim == 0 else arr
        return WorkloadReport(
            g(self.baseline_fetches), g(self.lpcn_fetches),
            g(self.baseline_mlp_evals), g(self.lpcn_mlp_evals),
            g(self.n_subsets), g(self.n_islands_used), self.k)

    @classmethod
    def sum_counters(cls, reports) -> "WorkloadReport":
        """Trace-safe aggregation: sum the pytree counter children;
        layers may differ in k (aux), the first layer's is kept."""
        flats = [r.tree_flatten()[0] for r in reports]
        return cls.tree_unflatten(
            (reports[0].k,), [sum(xs) for xs in zip(*flats)])

    @staticmethod
    def total(reports: list["WorkloadReport"]) -> "WorkloadReport":
        """Aggregate layer reports into a whole-network report."""
        if not reports:
            return WorkloadReport(0, 0, 0, 0, 0, 0, 0)
        return WorkloadReport.sum_counters(
            [r.concrete() for r in reports])


def analyze(islands: Islands, sched: Schedule, k: int) -> WorkloadReport:
    """Exact workload counters for one DS layer.  Trace-safe: counters are
    jnp scalars under jit/vmap; call ``.item()`` on them (or run eagerly)
    for python ints."""
    live = sched.reuse_slot >= 0        # (H, M, K) cached positions
    first = sched.is_first              # fills (computed once)
    valid = sched.subset_valid          # (H, M)
    # positions holding a real point (excludes ragged-batch -1 slots, so
    # padding never inflates fetch/eval counters)
    pos_valid = valid[..., None] & sched.pos_live

    n_rows = valid.sum()
    n_solo = islands.solo.sum()
    n_subsets = n_rows + n_solo

    computed_cached = (first & live).sum()               # pool fills
    overflow = (pos_valid & ~live).sum()                 # never cached

    # one delta-MLP eval per non-hub processed subset
    n_non_hub = jnp.maximum(valid.sum(-1) - 1, 0).sum()

    base = n_subsets * k
    lpcn_fetch = computed_cached + overflow + n_solo * k
    lpcn_mlp = computed_cached + overflow + n_non_hub + n_solo * k
    return WorkloadReport(
        baseline_fetches=base, lpcn_fetches=lpcn_fetch,
        baseline_mlp_evals=base, lpcn_mlp_evals=lpcn_mlp,
        n_subsets=n_subsets,
        n_islands_used=(valid.any(-1)).sum(), k=k)


def overlap_histogram(nbr_idx: jnp.ndarray, centers: jnp.ndarray,
                      groups=(16, 16, 32)) -> dict:
    """Paper Fig. 4(b): per subset, sort all other subsets by center
    distance and measure gathered-point overlap ratio within distance
    groups (top-16 nearest, next 16, next 32, rest)."""
    S, K = nbr_idx.shape
    d = jnp.sum((centers[:, None] - centers[None, :]) ** 2, -1)
    d = d.at[jnp.arange(S), jnp.arange(S)].set(jnp.inf)
    order = jnp.argsort(d, axis=-1)                       # (S, S)
    eq = (nbr_idx[:, None, :, None] == nbr_idx[None, :, None, :])
    ov = eq.any(-1).sum(-1) / K                           # (S, S) overlap
    ov_sorted = jnp.take_along_axis(ov, order, axis=-1)
    out, lo = {}, 0
    for g in groups:
        seg = ov_sorted[:, lo:lo + g]
        out[f"near_{lo}_{lo+g}"] = (float(seg.mean()), float(seg.max()))
        lo += g
    rest = ov_sorted[:, lo:S - 1]
    out["rest"] = (float(rest.mean()), float(rest.max()))
    return out
