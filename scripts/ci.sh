#!/usr/bin/env bash
# Tier-1 verification + a ~30s engine smoke benchmark + a padding-
# equivalence smoke (the ragged-batch contract, see tests/test_padding.py
# for the full oracle) + serving smokes (ragged trace, chaos fault
# injection, overload shed — see tests/test_serve.py) + a mesh-sharded
# engine smoke (8 forced host devices, subprocess — see
# tests/test_distributed.py for the full equivalence suite).
#
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== repro.dist collection check =="
# the four modules that used to skip via importorskip("repro.dist") must
# now collect real tests (PR 5 reconstructed the subsystem)
collected=$(python -m pytest --collect-only -q tests/test_substrate.py \
    tests/test_distributed.py tests/test_lm_smoke.py \
    tests/test_train_ckpt.py 2>/dev/null | tail -1 || true)
echo "$collected"
# must be a positive count ("no tests collected" / errors fail here)
if ! echo "$collected" | grep -qE '^[1-9][0-9]* tests? collected'; then
  echo "formerly-skipped tier-1 modules no longer collect"; exit 1
fi

echo "== padding-equivalence smoke =="
python - <<'EOF'
import numpy as np, jax, jax.numpy as jnp
from dataclasses import replace
from repro import engine
from repro.data.synthetic import make_cloud
from repro.engine import Batch, BlockSpec
from repro.models import pointnet2

spec = replace(pointnet2.POINTNET2_C, blocks=(
    BlockSpec(48, 8, (16, 32)), BlockSpec(16, 8, (32, 48))))
params = engine.init(jax.random.PRNGKey(0), spec)
rng = np.random.default_rng(0)
clouds = [np.asarray(make_cloud(rng, n), np.float32) for n in (96, 72, 60)]
keys = jax.random.split(jax.random.PRNGKey(1), 3)
batch = Batch.from_clouds(clouds, key=keys)
for mode in ("traditional", "lpcn"):
    out = engine.apply(params, batch, spec=spec, mode=mode)
    for i, c in enumerate(clouds):
        ref, _ = engine.apply_single(params, jnp.asarray(c), jnp.asarray(c),
                                     keys[i], spec=spec, mode=mode)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
print("padding smoke ok: padded ragged batch == per-cloud unpadded "
      "(traditional + lpcn)")
EOF

echo "== batched-kernel smoke (interpret mode) =="
python - <<'EOF'
import numpy as np, jax, jax.numpy as jnp
from functools import partial
from dataclasses import replace
from repro import engine
from repro.data.synthetic import make_cloud
from repro.engine import Batch, BlockSpec
from repro.models import pointnet2

spec = replace(pointnet2.POINTNET2_C, blocks=(
    BlockSpec(48, 8, (16, 32)), BlockSpec(16, 8, (32, 48))))
params = engine.init(jax.random.PRNGKey(0), spec)
rng = np.random.default_rng(0)
xyz = jnp.asarray(np.stack([make_cloud(rng, 96) for _ in range(3)]))
batch = Batch.make(xyz, key=jax.random.PRNGKey(1),
                   n_valid=jnp.asarray([96, 70, 50], jnp.int32))
ref = engine.apply(params, batch, spec=spec, mode="lpcn",
                   fc_backend="reference")
pal = engine.apply(params, batch, spec=spec, mode="lpcn",
                   fc_backend="pallas")
np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                           rtol=1e-4, atol=1e-4)

# one pallas_call per FC call site, batch folded into the grid (the
# jaxpr walker is the repro.analysis one — shared with
# tests/test_batched_fc.py and the kernel linter: one implementation)
from repro.analysis import count_pallas_calls

jx = jax.make_jaxpr(partial(engine.apply, spec=spec, mode="lpcn",
                            fc_backend="pallas"))(params, batch)
grids = []
n = count_pallas_calls(jx.jaxpr, grids)
assert n == 2 * len(spec.blocks), (n, grids)
assert all(g[0] == 3 for g in grids), grids
print(f"batched-kernel smoke ok: pallas==reference on a ragged batch, "
      f"{n} pallas_calls for {len(spec.blocks)} blocks, grids={grids}")
EOF

echo "== static analysis gate (repro.analysis --strict) =="
# kernel / recompile / ragged-masking / repo lint over the full
# 4-model x 2-mode x 2-backend matrix + serve/dist entry points;
# unsuppressed error-severity findings fail CI.  The JSON report lands
# in results/ and is uploaded with the benchmark artifacts.
python -m repro.analysis --strict --json results/analysis_report.json
python - <<'EOF'
import json
rep = json.load(open("results/analysis_report.json"))
assert rep["summary"]["strict_ok"], rep["summary"]
assert rep["kernel_sites"], "analysis saw no pallas_call sites"
for row in rep["kernel_sites"]:
    assert row["footprint_bytes"] > 0 and len(row["grid"]) == 2, row
print(f"analysis gate ok: {len(rep['kernel_sites'])} kernel sites, "
      f"{rep['summary']['findings']} findings "
      f"({rep['summary']['suppressed']} suppressed, 0 errors)")
EOF

echo "== engine smoke benchmark =="
python -m benchmarks.run --quick --only engine --out results/engine_smoke.json
python - <<'EOF'
import json
rows = json.load(open("results/engine_smoke.json"))
assert rows, "engine smoke produced no rows"
for r in rows:
    assert "backend" in r and "batch" in r, r
ragged = [r for r in rows if r.get("ragged")]
assert ragged, "engine smoke missing the ragged-batch configuration"
for r in ragged:
    assert "n_valid" in r and "sizes" in r["n_valid"], r
print(f"engine smoke ok: {len(rows)} rows "
      f"(backends: {sorted({r['backend'] for r in rows})}, "
      f"{len(ragged)} ragged)")
EOF

echo "== tile-plan autotune smoke (tiny budget, 1 model x 1 shape) =="
# a from-scratch tune run: the cache file must be written, every
# promoted winner must carry provenance "autotuned" and re-pass the
# K001-K005 kernel lint at its own budget (what repro.analysis --strict
# holds traced calls to).  The workflow uploads results/tile_plans.json
# with the other benchmark artifacts.
rm -f results/tile_plans.json
python -m repro.launch.autotune --models pointnet2_c --reduced \
    --points 96 --batches 2 --budget 4 --reps 2 \
    --out results/tile_plans.json
python - <<'EOF'
import json
from repro.kernels import plans
from repro.launch import autotune

raw = json.load(open("results/tile_plans.json"))
assert raw["version"] == plans.VERSION, raw
assert raw["plans"], "autotune smoke promoted no plans"
variants = 0
for key, entry in raw["plans"].items():
    kernel, dimstr = key.split("|", 1)
    assert entry["provenance"] == "autotuned", (key, entry)
    assert plans.entry_error(kernel, entry) is None, (key, entry)
    if entry.get("variant") == "vmap":
        # a cell where the per-cloud vmap dispatch out-measured every
        # grid candidate: no grid knobs to lint (the per-cloud kernel
        # is covered by the analysis matrix)
        variants += 1
        continue
    dims = dict(kv.split("=") for kv in dimstr.split(","))
    dims = {k: int(v) for k, v in dims.items()}
    knobs = {"tile": entry[plans.TILE_FIELD[kernel]],
             "lanes": entry["lanes"],
             "vmem_budget_mb": entry["vmem_budget_mb"],
             "dimension_semantics": tuple(entry["dimension_semantics"])}
    findings = autotune.lint_knobs(kernel, dims, knobs)
    assert not findings, (key, [f.rule for f in findings])
print(f"autotune smoke ok: {len(raw['plans'])} plans promoted "
      f"({variants} vmap variants), all provenance=autotuned and "
      f"grid winners K001-K005 clean")
EOF

echo "== fc_kernel A/B benchmark (vmap vs heuristic vs autotuned) =="
python -m benchmarks.run --quick --only fc_kernel \
    --out results/fc_kernel_smoke.json
python - <<'EOF'
import json
rows = json.load(open("results/fc_kernel_smoke.json"))
batched = [r for r in rows if r.get("dispatch") == "batched_grid"]
vmap = [r for r in rows if r.get("dispatch") == "vmap"]
assert batched and vmap, "fc_kernel smoke missing an A/B side"
for r in batched:
    assert r["per_cloud_dispatches"] == 1, r
kern = [r for r in batched if "tile" in r]
assert kern, "fc_kernel smoke missing kernel-level tile plans"
for r in kern:
    assert "grid" in r and len(r["grid"]) == 2, r
    # provenance is observed from the plan the trace actually resolved
    expect = "autotuned" if "autotuned" in r["name"] else "heuristic"
    assert r["tile_provenance"] == expect, r
tuned = [r for r in kern if r["tile_provenance"] == "autotuned"]
assert tuned, "fc_kernel smoke has no autotuned rows"
curve = [r for r in rows if "speedup_curve" in r["name"]]
assert curve and all(r["curve"] for r in curve), \
    "fc_kernel smoke missing the speedup-vs-B curve rows"
eng_tuned = [r for r in rows if r.get("backend") == "pallas_autotuned"]
assert eng_tuned and all(r["tile_provenance"] == ["autotuned"]
                         for r in eng_tuned), eng_tuned
print(f"fc_kernel smoke ok: {len(rows)} rows "
      f"({len(vmap)} vmap vs {len(batched)} batched-grid, "
      f"{len(tuned)} autotuned kernel rows, "
      f"{len(eng_tuned)} autotuned engine rows)")
EOF

echo "== serve-trace smoke (continuous batching, ragged trace) =="
# a short synthetic ragged trace through launch/serve.py --trace: the
# admission queue / size buckets / timeout dispatcher end to end, with
# the report JSON landing in results/ (uploaded with the other
# benchmark artifacts by the workflow)
python -m repro.launch.serve --arch pointnet2_c --reduced --points 96 \
    --batch 2 --trace 16 --rate 300 --buckets 96,128 --timeout-ms 5 \
    --serve-json results/serve_trace_smoke.json
python - <<'EOF'
import json
rep = json.load(open("results/serve_trace_smoke.json"))
assert rep["requests"] == 16 and rep["answered"] == 16, rep
assert rep["throughput_rps"] > 0, rep
for name, lat in rep["latency_ms"].items():
    assert lat["p50"] <= lat["p95"] <= lat["p99"], (name, lat)
assert 0 <= rep["padding_waste_pct"] < 100, rep
# compile-once per bucket: the trace spans both buckets
assert rep["compile_count"] == len(rep["buckets"]) == 2, rep
print(f"serve smoke ok: {rep['requests']} requests, "
      f"{rep['dispatches']} dispatches "
      f"({rep['partial_batches']} partial), "
      f"e2e p50/p95/p99 = {rep['latency_ms']['e2e']['p50']:.1f}/"
      f"{rep['latency_ms']['e2e']['p95']:.1f}/"
      f"{rep['latency_ms']['e2e']['p99']:.1f} ms, "
      f"waste {rep['padding_waste_pct']:.1f}%")
EOF

echo "== chaos-trace smoke (fault injection, degraded dispatch) =="
# same trace with a deterministic fault plan: step 1 raises inside the
# primary dispatch, step 3 NaN-poisons its output.  Both batches must
# be retried on the reference fallback and every request still
# answered — the hardened-serving acceptance walk, end to end through
# the CLI.  Exit code 0 is part of the contract: injected faults are
# handled, not propagated.
python -m repro.launch.serve --arch pointnet2_c --reduced --points 96 \
    --batch 2 --trace 16 --rate 300 --buckets 96,128 --timeout-ms 5 \
    --faults "fail@1,nan@3" \
    --serve-json results/serve_chaos_smoke.json
python - <<'EOF'
import json
rep = json.load(open("results/serve_chaos_smoke.json"))
assert rep["requests"] == 16 and rep["answered"] == 16, rep
assert rep["failed"] == 0 and rep["shed"] == 0, rep
fl = rep["faults"]
assert fl["degraded_dispatches"] == 2, fl          # both injected steps
assert fl["failed_requests"] == 0, fl
assert len(rep["fault_plan"]["injected"]) == 2, rep["fault_plan"]
assert rep["breakers"], rep                        # breaker state in report
assert all(b["state"] == "closed" for b in rep["breakers"].values()), \
    rep["breakers"]
print(f"chaos smoke ok: {rep['answered']}/{rep['requests']} answered "
      f"despite injected {rep['fault_plan']['injected']}, "
      f"{fl['degraded_dispatches']} degraded dispatches, 0 failed")
EOF

echo "== async dispatch A/B smoke (sync vs in-flight overlap) =="
# the same 16-request chaos burst replayed twice: once with --sync
# (the fire path blocks through execution) and once with up to 4
# batches in flight.  Both modes must answer 16/16 with IDENTICAL
# fault accounting (fault draws happen at fire time in admission
# order either way), monotone percentiles, and async throughput must
# not lose to sync — at 256-point batches the overlap of host padding
# with device compute wins ~1.2x even on one core.  The combined A/B
# lands in results/serve_async_ab_smoke.json.
for mode in sync async; do
  if [ "$mode" = sync ]; then extra="--sync"; else extra="--max-in-flight 4"; fi
  python -m repro.launch.serve --arch pointnet2_c --reduced --points 256 \
      --batch 2 --trace 16 --rate 2000 --buckets 256,384 --timeout-ms 5 \
      --faults "fail@1,nan@3" $extra \
      --serve-json "results/serve_async_ab_${mode}.json"
done
python - <<'EOF'
import json
reps = {m: json.load(open(f"results/serve_async_ab_{m}.json"))
        for m in ("sync", "async")}
for m, rep in reps.items():
    assert rep["dispatch_mode"] == m, (m, rep["dispatch_mode"])
    assert rep["requests"] == 16 and rep["answered"] == 16, (m, rep)
    assert rep["failed"] == 0 and rep["shed"] == 0, (m, rep)
    for name, lat in rep["latency_ms"].items():
        assert lat["p50"] <= lat["p95"] <= lat["p99"], (m, name, lat)
# identical fault accounting: same trace -> same batches -> the
# injected steps hit the same dispatches in both modes
assert reps["sync"]["faults"] == reps["async"]["faults"], \
    (reps["sync"]["faults"], reps["async"]["faults"])
assert (reps["sync"]["fault_plan"]["injected"]
        == reps["async"]["fault_plan"]["injected"]), reps["async"]["fault_plan"]
rps_s = reps["sync"]["throughput_rps"]
rps_a = reps["async"]["throughput_rps"]
assert rps_a >= rps_s, \
    f"async {rps_a:.1f} rps lost to sync {rps_s:.1f} rps"
ov = reps["async"]["overlap"]
assert ov["inflight_depth_max"] <= 4, ov
assert reps["sync"]["overlap"]["inflight_depth_max"] <= 1, reps["sync"]["overlap"]
with open("results/serve_async_ab_smoke.json", "w") as fh:
    json.dump(reps, fh, indent=1)
print(f"async A/B smoke ok: 16/16 both modes, identical fault "
      f"accounting, async {rps_a:.1f} >= sync {rps_s:.1f} rps "
      f"({rps_a / rps_s:.2f}x), overlap {ov['overlap_pct']:.1f}% "
      f"depth<={ov['inflight_depth_max']}")
EOF

echo "== overload smoke (bounded lanes, shed-on-full backpressure) =="
# batch 4 with a 1-deep lane and a long timeout: the burst trace can
# admit only one request; the other 11 must shed with QueueFullError
# at submit (counted, never forever-pending) and the replay still
# completes with exit 0.
python -m repro.launch.serve --arch pointnet2_c --reduced --points 96 \
    --batch 4 --trace 12 --rate 2000 --buckets 96 --timeout-ms 200 \
    --max-queue 1 \
    --serve-json results/serve_overload_smoke.json
python - <<'EOF'
import json
rep = json.load(open("results/serve_overload_smoke.json"))
assert rep["requests"] == 1, rep           # latency stats: admitted only
assert rep["answered"] == 1, rep
assert rep["shed"] == 11, rep
assert rep["faults"]["shed_queue_full"] == 11, rep["faults"]
print(f"overload smoke ok: answered {rep['answered']}, shed "
      f"{rep['shed']} at a 1-deep lane (shed_queue_full="
      f"{rep['faults']['shed_queue_full']})")
EOF

echo "== sharded engine smoke (8 forced host devices, subprocess) =="
# runs in its own python process (like tests/test_distributed.py) so the
# forced fake device count cannot leak into any other step's jax
XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}" \
python - <<'PYEOF'
import numpy as np, jax, jax.numpy as jnp
from dataclasses import replace
from repro import engine
from repro.data.synthetic import make_cloud
from repro.engine import Batch, BlockSpec
from repro.launch.mesh import make_mesh
from repro.models import pointnet2

assert len(jax.devices()) == 8, jax.devices()
mesh = make_mesh((4, 2), ("data", "model"))
spec = replace(pointnet2.POINTNET2_C, blocks=(
    BlockSpec(32, 8, (16, 32)), BlockSpec(16, 8, (32, 48))))
params = engine.init(jax.random.PRNGKey(0), spec)
rng = np.random.default_rng(0)
xyz = jnp.asarray(np.stack([make_cloud(rng, 96) for _ in range(8)]))
batch = Batch.make(xyz, key=jax.random.PRNGKey(1),
                   n_valid=jnp.asarray([96, 70, 50, 96, 33, 80, 60, 90],
                                       jnp.int32))
for mode in ("traditional", "lpcn"):
    ref = engine.apply(params, batch, spec=spec, mode=mode)
    sh = engine.apply(params, batch, spec=spec, mode=mode, mesh=mesh)
    assert "data" in str(sh.sharding), sh.sharding
    np.testing.assert_allclose(np.asarray(sh), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
print("sharded smoke ok: 8-device mesh engine.apply == single-device on a "
      "ragged batch (traditional + lpcn), output sharded over 'data'")
PYEOF

echo "== dist benchmark smoke (sharded vs single-device throughput) =="
python -m benchmarks.run --quick --only dist --out results/dist_smoke.json
python - <<'PYEOF'
import json
rows = json.load(open("results/dist_smoke.json"))
tags = {r["name"].rsplit("_d", 1)[0] for r in rows}
assert {"dist_engine_single_device", "dist_engine_sharded"} <= tags, tags
for r in rows:
    assert "device_count" in r and "clouds_per_s_per_device" in r, r
sharded = [r for r in rows if r["mesh"]]
assert sharded and all(r["mesh"]["data"] == r["device_count"]
                       for r in sharded), sharded
print(f"dist smoke ok: {len(rows)} rows, device_count="
      f"{rows[0]['device_count']}, mesh shapes recorded")
PYEOF
