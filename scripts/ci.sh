#!/usr/bin/env bash
# Tier-1 verification + a ~30s engine smoke benchmark.
#
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== engine smoke benchmark =="
python -m benchmarks.run --quick --only engine --out results/engine_smoke.json
python - <<'EOF'
import json
rows = json.load(open("results/engine_smoke.json"))
assert rows, "engine smoke produced no rows"
for r in rows:
    assert "backend" in r and "batch" in r, r
print(f"engine smoke ok: {len(rows)} rows "
      f"(backends: {sorted({r['backend'] for r in rows})})")
EOF
